"""Archive query-serving front end: request queue → batched scans → ranked hits.

The index-side sibling of :class:`repro.serve.engine.ServeEngine`
(the "heavy traffic" north star): callers submit
:class:`QueryRequest`\\ s, the service drains the queue in fixed-size
request batches, runs each through the shared :class:`QueryEngine`
(whose candidate scans are themselves batched kernel dispatches), and
returns ranked hit lists with record excerpts. One engine instance is
shared across the queue so per-shard readers stay open and warm between
requests — the serving-loop equivalent of a KV cache.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

from .cdx import CdxIndex
from .query import HeaderFilter, PatternHit, QueryEngine

__all__ = ["IndexQueryService", "QueryRequest", "QueryResponse"]


@dataclass
class QueryRequest:
    """One search: a byte pattern plus optional header predicates.

    ``regex=True`` interprets ``pattern`` as a bytes regex source
    (served through :meth:`QueryEngine.search_regex`).
    """

    pattern: bytes
    filters: HeaderFilter | None = None
    top_k: int = 10
    prefilter: bool = True
    regex: bool = False

    def scan_key(self) -> tuple:
        """Identity of the *scan* this request needs (not of the
        response shaping — ``top_k`` ranks after the scan), i.e. what
        the serve gateway coalesces on."""
        return (self.pattern, self.regex, self.prefilter,
                None if self.filters is None else self.filters.key())


@dataclass
class QueryResponse:
    request: QueryRequest
    hits: list[PatternHit] = field(default_factory=list)
    total_matches: int = 0       # matched records before top_k truncation
    latency_s: float = 0.0


class IndexQueryService:
    """Drain query requests in batches against one shared engine."""

    def __init__(self, index: CdxIndex, *, batch_size: int = 8,
                 use_kernel: bool = True, interpret: bool = True,
                 engine: QueryEngine | None = None) -> None:
        self.engine = engine if engine is not None else QueryEngine(
            index, use_kernel=use_kernel, interpret=interpret)
        self.batch_size = max(1, batch_size)
        self._queue: list[QueryRequest] = []
        self.stats = {"requests": 0, "batches": 0, "hits_returned": 0,
                      "serve_s": 0.0}

    # -- request intake --------------------------------------------------
    def submit(self, request: QueryRequest) -> None:
        self._queue.append(request)

    def pending(self) -> int:
        return len(self._queue)

    # -- serving ---------------------------------------------------------
    def run_batch(self, requests: list[QueryRequest]) -> list[QueryResponse]:
        """Serve one batch of requests; hits ranked by match count."""
        responses = []
        for req in requests:
            t0 = time.perf_counter()
            if req.regex:
                hits = self.engine.search_regex(req.pattern, req.filters,
                                                prefilter=req.prefilter)
            else:
                hits = self.engine.search(req.pattern, req.filters,
                                          prefilter=req.prefilter)
            # rank: most matches first, index order breaks ties (stable)
            ranked = sorted(hits, key=lambda h: -h.n_matches)
            responses.append(QueryResponse(
                request=req, hits=ranked[:req.top_k],
                total_matches=len(hits),
                latency_s=time.perf_counter() - t0))
        self.stats["requests"] += len(requests)
        self.stats["batches"] += 1
        self.stats["hits_returned"] += sum(len(r.hits) for r in responses)
        self.stats["serve_s"] += sum(r.latency_s for r in responses)
        return responses

    def drain(self) -> list[QueryResponse]:
        """Serve everything queued, in submission order, batch by batch."""
        responses: list[QueryResponse] = []
        while self._queue:
            batch = self._queue[:self.batch_size]
            del self._queue[:self.batch_size]
            responses.extend(self.run_batch(batch))
        return responses

    def serve(self, requests: list[QueryRequest]) -> list[QueryResponse]:
        for req in requests:
            self.submit(req)
        return self.drain()

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        self.engine.close()

    def __enter__(self) -> "IndexQueryService":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
