"""CDX-style record index: build, merge, persist, random-access (DESIGN.md §7).

The paper's record-level compression "allows for constant-time random
access to all kinds of web data" — this module is the subsystem that
exercises the claim. An archive (or a sharded corpus) is swept **once**
with the optimized parser and every record's location and metadata are
captured into a compact binary *columnar* index:

    shard_id · offset · comp_len · uncomp_len · type · status ·
    uri · mime · adler32 digest · n-gram signature bitmap

Columns are numpy arrays (header predicates evaluate as vector compares
over the whole corpus, see :mod:`repro.index.query`); URIs/MIMEs live in
shared byte heaps addressed by offset columns, and the per-record
Bloom-style signature (:mod:`repro.index.signature`) lets pattern
queries skip decompression of records that cannot match.

Building fans out per shard through :func:`repro.core.parallel.map_shards`
(one picklable partial per shard, merged deterministically in shard
order); :class:`RandomAccessReader` then opens a shard at an indexed
offset and parses exactly one record — one seek, one member decode, one
record parse, independent of archive size. ``offset`` is the absolute
position in the *addressable* stream: the compressed file for gzip/LZ4
members, the raw file for uncompressed WARCs, and the decompressed
stream for zstd. zstd rows additionally store the compressed offset of
the frame containing the record (walked without decompression at build
time, :mod:`repro.core.warc.zstd_frames`), so random access seeks to the
containing frame and decompresses only from there instead of inflating
the whole shard on first read.
"""
from __future__ import annotations

import io
import os
import struct
import zlib
from dataclasses import dataclass

import numpy as np

from repro.core.warc.fastwarc import FastWARCIterator, read_record_at
from repro.core.warc.record import (
    RECORD_TYPE_FROM_VALUE,
    UNKNOWN_TYPE_VALUE,
    WarcRecord,
    WarcRecordType,
)
from repro.core.warc.streams import (
    ForwardWindow,
    ZstdStream,
    detect_compression,
)
from .signature import SIG_BITS, SIG_HASHES, SIG_NGRAM, signature_of

__all__ = [
    "CdxEntry",
    "CdxIndex",
    "NO_FRAME",
    "RandomAccessReader",
    "build_index",
    "verify_index",
]

_MAGIC = b"REPROCDX"
_VERSION = 2  # v2 adds the zstd frame columns (frame_off / frame_base)
_KIND_CODES = {"none": 0, "gzip": 1, "lz4": 2, "zstd": 3}
_KIND_NAMES = {v: k for k, v in _KIND_CODES.items()}

# rows without a usable compressed-frame mapping (legacy v1 zstd indexes,
# unwalkable frames) carry this sentinel: readers fall back to the
# decompress-whole-shard path
NO_FRAME = np.uint64(0xFFFFFFFFFFFFFFFF)


@dataclass
class CdxEntry:
    """One materialized index row (columnar storage is the truth)."""

    shard: str
    kind: str
    offset: int
    comp_len: int
    uncomp_len: int
    record_type: WarcRecordType
    status: int            # HTTP status, -1 when not an HTTP record
    uri: bytes
    mime: bytes
    digest: int            # adler32 of the record content block

    @property
    def digest_header(self) -> str:
        """WARC digest-header notation (``verify_digests_bulk`` input)."""
        return f"adler32:{self.digest:08x}"


class CdxIndex:
    """Columnar CDX index over one or many WARC shards."""

    def __init__(self, shard_paths: list[str], shard_kinds: list[str],
                 columns: dict[str, np.ndarray],
                 uri_heap: bytes, mime_heap: bytes,
                 *, sig_bits: int = SIG_BITS, sig_ngram: int = SIG_NGRAM,
                 sig_hashes: int = SIG_HASHES) -> None:
        self.shard_paths = list(shard_paths)
        self.shard_kinds = list(shard_kinds)
        self.shard_id = columns["shard_id"]
        self.offset = columns["offset"]
        self.comp_len = columns["comp_len"]
        self.uncomp_len = columns["uncomp_len"]
        self.rtype = columns["rtype"]
        self.status = columns["status"]
        self.digest = columns["digest"]
        self.signatures = columns["signatures"]
        # compressed-domain offset of the frame holding each record plus
        # that frame's decompressed base (zstd random access); identity
        # for member formats, NO_FRAME when unknown (legacy v1 indexes)
        if "frame_off" in columns:
            self.frame_off = columns["frame_off"]
            self.frame_base = columns["frame_base"]
        else:
            self.frame_off = self.offset.copy()
            self.frame_base = self.offset.copy()
            zstd_rows = np.asarray(
                [k == "zstd" for k in shard_kinds], bool)[self.shard_id]
            self.frame_off[zstd_rows] = NO_FRAME
            self.frame_base[zstd_rows] = NO_FRAME
        self.uri_off = columns["uri_off"]
        self.mime_off = columns["mime_off"]
        self.uri_heap = uri_heap
        self.mime_heap = mime_heap
        self.sig_bits = sig_bits
        self.sig_ngram = sig_ngram
        self.sig_hashes = sig_hashes
        # damage report of a tolerant build: LedgerEntry rows for every
        # byte range the sweep skipped (plus shard_quarantined entries).
        # In-memory only — not persisted by save()/load(); a reloaded
        # index starts with a clean slate.
        self.errors: list = []
        # observability: build_index attaches the merged ObsSnapshot of
        # its sweep (parent + pool + workers). In-memory only, like errors.
        self.obs = None
        self._uris: np.ndarray | None = None
        self._mimes: np.ndarray | None = None

    # -- access ----------------------------------------------------------
    def __len__(self) -> int:
        return int(self.offset.size)

    def uri(self, i: int) -> bytes:
        return self.uri_heap[self.uri_off[i]:self.uri_off[i + 1]]

    def mime(self, i: int) -> bytes:
        return self.mime_heap[self.mime_off[i]:self.mime_off[i + 1]]

    def uris(self) -> np.ndarray:
        """Fixed-width bytes array of URIs (built once; the query
        engine's URL-prefix predicate is a ``np.char`` vector compare)."""
        if self._uris is None:
            self._uris = np.array(
                [self.uri(i) for i in range(len(self))], dtype=np.bytes_)
        return self._uris

    def mimes(self) -> np.ndarray:
        """Fixed-width bytes array of MIME values (vector prefix filters)."""
        if self._mimes is None:
            self._mimes = np.array(
                [self.mime(i) for i in range(len(self))], dtype=np.bytes_)
        return self._mimes

    def frame_hint(self, i: int) -> tuple[int, int] | None:
        """``(frame_off, frame_base)`` for seek-to-frame reads of row ``i``,
        or ``None`` when no usable mapping is stored (legacy indexes)."""
        fo = int(self.frame_off[i])
        if np.uint64(fo) == NO_FRAME:
            return None
        return fo, int(self.frame_base[i])

    def entry(self, i: int) -> CdxEntry:
        i = int(i)
        sid = int(self.shard_id[i])
        return CdxEntry(
            shard=self.shard_paths[sid],
            kind=self.shard_kinds[sid],
            offset=int(self.offset[i]),
            comp_len=int(self.comp_len[i]),
            uncomp_len=int(self.uncomp_len[i]),
            record_type=RECORD_TYPE_FROM_VALUE.get(
                int(self.rtype[i]),
                RECORD_TYPE_FROM_VALUE[UNKNOWN_TYPE_VALUE]),
            status=int(self.status[i]),
            uri=self.uri(i),
            mime=self.mime(i),
            digest=int(self.digest[i]),
        )

    # -- persistence -----------------------------------------------------
    def save(self, path: str) -> int:
        """Write the binary columnar layout; returns bytes written.

        The column region is packed through the shared column codec
        (:mod:`repro.columnar.codec` — the same layer the derived
        columnar shards use); the v2 byte format is unchanged.
        """
        from repro.columnar.codec import pack_arrays

        n = len(self)
        out = io.BytesIO()
        out.write(_MAGIC)
        out.write(struct.pack("<IIIIIQ", _VERSION, self.sig_bits,
                              self.sig_ngram, self.sig_hashes,
                              len(self.shard_paths), n))
        for p, kind in zip(self.shard_paths, self.shard_kinds):
            raw = p.encode("utf-8")
            out.write(struct.pack("<IB", len(raw), _KIND_CODES[kind]))
            out.write(raw)
        pack_arrays(out, (self.shard_id, self.offset, self.comp_len,
                          self.uncomp_len, self.rtype, self.status,
                          self.digest, self.signatures, self.frame_off,
                          self.frame_base, self.uri_off, self.mime_off))
        out.write(struct.pack("<Q", len(self.uri_heap)))
        out.write(self.uri_heap)
        out.write(struct.pack("<Q", len(self.mime_heap)))
        out.write(self.mime_heap)
        blob = out.getvalue()
        with open(path, "wb") as f:
            f.write(blob)
        return len(blob)

    @classmethod
    def load(cls, path: str) -> "CdxIndex":
        with open(path, "rb") as f:
            blob = f.read()
        if blob[:8] != _MAGIC:
            raise ValueError(f"{path}: not a CDX index (bad magic)")
        version, bits, ngram, hashes, n_shards, n = struct.unpack_from(
            "<IIIIIQ", blob, 8)
        if version not in (1, _VERSION):  # v1 readable: frame cols absent
            raise ValueError(f"{path}: unsupported CDX version {version}")
        # signature geometry is a per-index build parameter — validate it
        # before trusting it to slice the column region
        if bits == 0 or bits % 64:
            raise ValueError(
                f"{path}: invalid signature width {bits} (need a positive "
                f"multiple of 64)")
        if ngram == 0 or hashes == 0:
            raise ValueError(
                f"{path}: invalid signature parameters "
                f"(ngram={ngram}, hashes={hashes})")
        from repro.columnar.codec import ArrayCursor

        pos = 8 + struct.calcsize("<IIIIIQ")
        shard_paths, shard_kinds = [], []
        for _ in range(n_shards):
            plen, kcode = struct.unpack_from("<IB", blob, pos)
            pos += struct.calcsize("<IB")
            shard_paths.append(blob[pos:pos + plen].decode("utf-8"))
            shard_kinds.append(_KIND_NAMES[kcode])
            pos += plen

        # the column region decodes through the shared column codec —
        # zero-copy views advancing one cursor, schema fixed by version
        cur = ArrayCursor(blob, pos)
        words = bits // 64
        columns = {
            "shard_id": cur.take(np.uint32, n),
            "offset": cur.take(np.uint64, n),
            "comp_len": cur.take(np.uint64, n),
            "uncomp_len": cur.take(np.uint64, n),
            "rtype": cur.take(np.uint16, n),
            "status": cur.take(np.int16, n),
            "digest": cur.take(np.uint32, n),
            "signatures": cur.take(np.uint64, n * words, (n, words)),
        }
        if version >= 2:
            columns["frame_off"] = cur.take(np.uint64, n)
            columns["frame_base"] = cur.take(np.uint64, n)
        # v1: constructor synthesizes identity/NO_FRAME frame columns
        columns["uri_off"] = cur.take(np.uint64, n + 1)
        columns["mime_off"] = cur.take(np.uint64, n + 1)
        pos = cur.pos
        (uri_len,) = struct.unpack_from("<Q", blob, pos)
        pos += 8
        uri_heap = blob[pos:pos + uri_len]
        pos += uri_len
        (mime_len,) = struct.unpack_from("<Q", blob, pos)
        pos += 8
        mime_heap = blob[pos:pos + mime_len]
        return cls(shard_paths, shard_kinds, columns, uri_heap, mime_heap,
                   sig_bits=bits, sig_ngram=ngram, sig_hashes=hashes)

    # -- merge -----------------------------------------------------------
    @classmethod
    def merge(cls, partials: list["CdxIndex"]) -> "CdxIndex":
        """Concatenate per-shard partial indexes (deterministic: input
        order is preserved; shard ids and heap offsets are rebased)."""
        if not partials:
            raise ValueError("nothing to merge")
        ref = partials[0]
        for p in partials[1:]:
            if (p.sig_bits, p.sig_ngram, p.sig_hashes) != (
                    ref.sig_bits, ref.sig_ngram, ref.sig_hashes):
                raise ValueError("signature parameter mismatch across partials")
        shard_paths: list[str] = []
        shard_kinds: list[str] = []
        cols: dict[str, list[np.ndarray]] = {k: [] for k in (
            "shard_id", "offset", "comp_len", "uncomp_len", "rtype",
            "status", "digest", "signatures", "frame_off", "frame_base")}
        uri_offs, mime_offs = [np.zeros(1, np.uint64)], [np.zeros(1, np.uint64)]
        uri_parts, mime_parts = [], []
        uri_base = mime_base = 0
        for p in partials:
            shard_base = len(shard_paths)
            shard_paths.extend(p.shard_paths)
            shard_kinds.extend(p.shard_kinds)
            cols["shard_id"].append(p.shard_id + np.uint32(shard_base))
            for name in ("offset", "comp_len", "uncomp_len", "rtype",
                         "status", "digest", "signatures", "frame_off",
                         "frame_base"):
                cols[name].append(getattr(p, name))
            uri_offs.append(p.uri_off[1:] + np.uint64(uri_base))
            mime_offs.append(p.mime_off[1:] + np.uint64(mime_base))
            uri_parts.append(p.uri_heap)
            mime_parts.append(p.mime_heap)
            uri_base += len(p.uri_heap)
            mime_base += len(p.mime_heap)
        merged = {name: np.concatenate(parts) for name, parts in cols.items()}
        merged["uri_off"] = np.concatenate(uri_offs)
        merged["mime_off"] = np.concatenate(mime_offs)
        out = cls(shard_paths, shard_kinds, merged,
                  b"".join(uri_parts), b"".join(mime_parts),
                  sig_bits=ref.sig_bits, sig_ngram=ref.sig_ngram,
                  sig_hashes=ref.sig_hashes)
        for p in partials:
            out.errors.extend(getattr(p, "errors", ()))
        return out


# --------------------------------------------------------------------------
# Builder (module-level worker: picklable under spawn, like core.parallel)
# --------------------------------------------------------------------------

def _record_span(record: WarcRecord) -> int:
    """Serialized record length in the decompressed stream (zstd tail)."""
    hdr = record._header_block  # raw block kept by the lazy-header parser
    hdr_len = len(hdr) if hdr else sum(
        len(n) + len(v) + 4 for n, v in record.headers.items_bytes()) + len(
            record.headers.status_line) + 2
    return hdr_len + 4 + record.content_length + 4


_FUSED_BATCH = 512           # records per fused-kernel flush
_FUSED_BATCH_BYTES = 32 << 20  # …or payload bytes, whichever trips first:
                               # pending borrowed views pin their arenas and
                               # the kernel pads a matching batch matrix, so
                               # MB-scale records must flush early


def _fused_supported(sig_bits: int, sig_ngram: int) -> bool:
    """Geometry the fused kernel path covers (else: host two-pass)."""
    from repro.kernels.digest_sig.digest_sig import HPAD

    return (sig_bits & (sig_bits - 1) == 0
            and 2 <= sig_ngram <= HPAD + 1)


def _index_shard(path: str, *, sig_bits: int = SIG_BITS,
                 sig_ngram: int = SIG_NGRAM,
                 sig_hashes: int = SIG_HASHES,
                 fused: bool = False,
                 batch_records: int = _FUSED_BATCH,
                 readahead: bool | None = None,
                 tolerant: bool = False) -> CdxIndex:
    """One-pass sweep of one shard into a single-shard partial index.

    ``fused=True`` computes digest + signature through the batched
    :func:`repro.kernels.digest_sig.digest_signature_batch` sweep:
    record payloads are borrowed zero-copy out of the parse arena
    (``content_view()`` — the pending batch pins its arenas, bounded by
    ``batch_records`` records *and* ``_FUSED_BATCH_BYTES`` payload
    bytes) and each payload byte is touched by exactly one
    kernel pass instead of the two host passes (adler, then n-gram).
    Falls back to the host path when the geometry is outside the
    kernel's support (non-power-of-two ``sig_bits``).

    Publishes per-stage wall time to the process obs registry
    (``index.stage.parse_us`` / ``digest_sig_us`` / ``frame_walk_us`` /
    ``assemble_us``) — under ``map_shards`` fan-out the per-worker
    registries merge into the build's snapshot, so serial vs parallel
    builds can be attributed stage-by-stage (EXPERIMENTS.md §Columnar:
    where the negative workers=2 scaling goes).
    """
    import time as _time

    from repro import obs as _obs

    t_sweep0 = _time.perf_counter()
    t_sig = 0.0
    with open(path, "rb") as f:
        kind = detect_compression(f.read(8))
    use_fused = fused and _fused_supported(sig_bits, sig_ngram)
    offsets: list[int] = []
    uncomp: list[int] = []
    rtypes: list[int] = []
    statuses: list[int] = []
    digests: list = []           # ints (host path) / uint32 arrays (fused)
    sigs: list[np.ndarray] = []  # (words,) rows (host) / (B, words) (fused)
    pending: list[np.ndarray] = []  # borrowed payload views awaiting a flush
    pending_bytes = 0
    uri_parts: list[bytes] = []
    mime_parts: list[bytes] = []
    uri_off = [0]
    mime_off = [0]
    last_span = 0

    def flush() -> None:
        nonlocal pending_bytes, t_sig
        from repro.kernels.digest_sig import digest_signature_batch

        t0 = _time.perf_counter()
        d, s = digest_signature_batch(pending, bits=sig_bits, n=sig_ngram,
                                      k=sig_hashes)
        t_sig += _time.perf_counter() - t0
        digests.append(d)
        sigs.append(s)
        pending.clear()  # releases the arena pins
        pending_bytes = 0

    # readahead (default auto): member inflate runs on a decoder thread
    # while this loop builds columns and flushes fused kernel batches —
    # the index build overlaps decompression with signature/digest work.
    # Pending borrowed views pin their member-arena slots exactly like
    # RecordBuffer arenas, so the batched flush stays aliasing-safe.
    it = FastWARCIterator(path, parse_http=True, readahead=readahead,
                          tolerant=tolerant)
    try:
        for record in it:
            content = record.content_view()
            offsets.append(record.stream_offset)
            uncomp.append(record.content_length)
            rtypes.append(int(record.record_type))
            http = record.http_headers
            status = (http.status_code if http is not None
                      and http.status_code is not None else -1)
            # hostile/malformed status lines ("HTTP/1.1 99999 ...") must
            # not kill the shard sweep: anything outside the int16 column
            # is as good as no status
            statuses.append(status if 0 <= status <= 0x7FFF else -1)
            if use_fused:
                pending.append(np.frombuffer(content, np.uint8))
                pending_bytes += record.content_length
                if len(pending) >= batch_records or \
                        pending_bytes >= _FUSED_BATCH_BYTES:
                    flush()
            else:
                t0 = _time.perf_counter()
                digests.append(zlib.adler32(content) & 0xFFFFFFFF)
                sigs.append(signature_of(content, bits=sig_bits,
                                         n=sig_ngram, k=sig_hashes))
                t_sig += _time.perf_counter() - t0
            uri = record.header_bytes(b"WARC-Target-URI:") or b""
            mime = (http.get_bytes(b"Content-Type", b"") if http is not None
                    else record.header_bytes(b"Content-Type:") or b"")
            uri_parts.append(uri)
            mime_parts.append(mime)
            uri_off.append(uri_off[-1] + len(uri))
            mime_off.append(mime_off[-1] + len(mime))
            last_span = _record_span(record)
        if use_fused and pending:
            flush()
    finally:
        it.close()  # a failed sweep must still join the decoder thread
    t_parse = _time.perf_counter() - t_sweep0 - t_sig
    t_frame0 = _time.perf_counter()
    n = len(offsets)
    off = np.asarray(offsets, np.uint64)
    # comp_len = distance to the next record in the addressable stream;
    # the tail record ends at the file size (member formats) or at its
    # own serialized span (zstd: addressable space is the decompressed
    # stream, whose total length the compressed file size says nothing
    # about)
    if n:
        end = (off[-1] + np.uint64(last_span)) if kind == "zstd" \
            else np.uint64(os.path.getsize(path))
        comp = np.diff(np.concatenate([off, [end]])).astype(np.uint64)
    else:
        comp = np.empty(0, np.uint64)
    # frame mapping: member formats address the compressed stream, so a
    # record's "frame" is itself; zstd offsets live in the decompressed
    # stream, so map each record onto the compressed frame containing it
    # (walked without decompression — see core.warc.zstd_frames)
    frame_off, frame_base = off.copy(), off.copy()
    if kind == "zstd" and n:
        import mmap

        from repro.core.warc.zstd_frames import frame_table
        try:
            with open(path, "rb") as f, \
                    mmap.mmap(f.fileno(), 0, access=mmap.ACCESS_READ) as mm:
                # the walk touches a few bytes per block header; mmap
                # keeps it O(1) resident even for multi-GB shards
                comp_offs, bases = frame_table(mm)
            which = np.searchsorted(bases, off, side="right") - 1
            frame_off = comp_offs[which]
            frame_base = bases[which]
        except (ValueError, RuntimeError):
            # unwalkable frames: index stays usable, reads fall back to
            # the decompress-whole-shard path
            frame_off = np.full(n, NO_FRAME, np.uint64)
            frame_base = np.full(n, NO_FRAME, np.uint64)
    t_assemble0 = _time.perf_counter()
    if use_fused:
        digest_col = (np.concatenate(digests) if digests
                      else np.empty(0, np.uint32))
        sig_col = (np.concatenate(sigs, axis=0) if sigs
                   else np.empty((0, sig_bits // 64), np.uint64))
    else:
        digest_col = np.asarray(digests, np.uint32)
        sig_col = (np.stack(sigs) if sigs
                   else np.empty((0, sig_bits // 64), np.uint64))
    columns = {
        "shard_id": np.zeros(n, np.uint32),
        "offset": off,
        "comp_len": comp,
        "uncomp_len": np.asarray(uncomp, np.uint64),
        "rtype": np.asarray(rtypes, np.uint16),
        "status": np.asarray(statuses, np.int16),
        "digest": digest_col,
        "signatures": sig_col,
        "frame_off": frame_off,
        "frame_base": frame_base,
        "uri_off": np.asarray(uri_off, np.uint64),
        "mime_off": np.asarray(mime_off, np.uint64),
    }
    out = CdxIndex([path], [kind], columns, b"".join(uri_parts),
                   b"".join(mime_parts), sig_bits=sig_bits,
                   sig_ngram=sig_ngram, sig_hashes=sig_hashes)
    reg = _obs.registry()
    reg.counter_add("index.shards", 1)
    reg.counter_add("index.records", n)
    reg.counter_add("index.stage.parse_us", int(t_parse * 1e6))
    reg.counter_add("index.stage.digest_sig_us", int(t_sig * 1e6))
    reg.counter_add("index.stage.frame_walk_us",
                    int((t_assemble0 - t_frame0) * 1e6))
    reg.counter_add("index.stage.assemble_us",
                    int((_time.perf_counter() - t_assemble0) * 1e6))
    if tolerant:
        # the damage ledger rides the (picklable) partial back to the
        # build_index parent, crossing the worker process boundary
        out.errors = list(it.error_ledger.entries())
    return out


def build_index(paths, *, workers: int = 0, sig_bits: int = SIG_BITS,
                sig_ngram: int = SIG_NGRAM,
                sig_hashes: int = SIG_HASHES,
                fused: bool | None = None,
                readahead: bool | None = None,
                tolerant: bool = False,
                supervise: bool = False) -> CdxIndex:
    """Index a sharded corpus: one parser sweep per shard, merged.

    ``workers > 0`` fans the per-shard sweeps out through
    :func:`repro.core.parallel.map_shards` (each partial is a picklable
    single-shard :class:`CdxIndex`); ``workers=0`` sweeps serially.
    Either way the merge is deterministic in shard order.

    ``fused`` selects the single-sweep digest+signature path (the
    batched :mod:`repro.kernels.digest_sig` kernel) over the two-pass
    host path; the two produce bit-identical columns. Default (None):
    fused for serial builds, host in worker processes — pool workers
    may fork before/without JAX and must not drag a fresh runtime up
    per shard. Geometries the kernel does not cover (non-power-of-two
    ``sig_bits``) silently use the host path.

    The signature geometry (``sig_bits``/``sig_ngram``/``sig_hashes``)
    is a **per-index build parameter**: it is persisted in the CDX
    header, validated on load, and every query against the index adapts
    to it — the module constants are only defaults. ``sig_bits`` must be
    a positive multiple of 64.

    ``readahead`` (default auto) runs member decompression on a decoder
    thread inside each sweep — serial builds overlap inflate with column
    assembly and fused kernel flushes; worker builds overlap it with the
    per-process sweep on top of the shard fan-out.

    ``tolerant`` sweeps each shard in recovery mode: damaged records are
    skipped (resynced past) instead of aborting the build, and every
    skipped byte range is reported on the returned index's ``errors``
    list (:class:`~repro.core.warc.errors.LedgerEntry` rows).
    ``supervise`` (with ``workers > 0``) retries worker deaths; a shard
    that keeps killing workers is dropped from the merge and reported as
    one ``shard_quarantined`` ledger entry covering the whole file.

    The returned index carries the build's merged observability
    snapshot on ``index.obs`` (:class:`~repro.obs.ObsSnapshot`): parent
    registry counters (kernel dispatches, pad waste, serial-sweep
    ingest stats) plus, for worker builds, pool transport/supervisor
    counters and every worker's published ``ingest.*`` counters.
    """
    import functools

    from repro.core.parallel import map_shards
    from repro.core.warc.errors import LedgerEntry

    if sig_bits <= 0 or sig_bits % 64:
        raise ValueError(f"sig_bits must be a positive multiple of 64, "
                         f"got {sig_bits}")
    if sig_ngram < 1 or sig_hashes < 1:
        raise ValueError("sig_ngram and sig_hashes must be >= 1")
    if fused is None:
        fused = workers == 0
    sweep = functools.partial(_index_shard, sig_bits=sig_bits,
                              sig_ngram=sig_ngram, sig_hashes=sig_hashes,
                              fused=fused, readahead=readahead,
                              tolerant=tolerant)
    paths = [str(p) for p in paths]
    partials, obs_snap = map_shards(sweep, paths, workers=workers,
                                    supervise=supervise, with_obs=True)
    live: list[CdxIndex] = []
    dropped: list[LedgerEntry] = []
    for path, part in zip(paths, partials):
        if part is None:  # quarantined by the pool supervisor
            try:
                size = os.path.getsize(path)
            except OSError:
                size = 0
            dropped.append(LedgerEntry(
                shard=path, offset=0, error_class="shard_quarantined",
                bytes_skipped=size,
                message="shard repeatedly killed indexing workers"))
            continue
        live.append(part)
    merged = CdxIndex.merge(live)
    merged.errors.extend(dropped)
    merged.obs = obs_snap
    return merged


# --------------------------------------------------------------------------
# Random access
# --------------------------------------------------------------------------

class RandomAccessReader:
    """Fetch single records from one shard by CDX offset.

    The shard is opened once; every :meth:`read` is one seek + one member
    decode + one record parse — cost independent of archive size (the
    benchmark harness measures this against sequential scan-to-offset).
    zstd shards have no compressed-domain member boundaries; when the
    caller supplies a ``frame`` hint (the v2 CDX stores one per record,
    see :meth:`CdxIndex.frame_hint`), the reader seeks straight to the
    containing compressed frame and decompresses only from there —
    without a hint it falls back to decompressing the stream once on
    first access (legacy v1 behaviour; reads then become in-memory
    seeks).
    """

    def __init__(self, path: str, *, parse_http: bool = True,
                 verify_digests: bool = False) -> None:
        self.path = path
        self._f = open(path, "rb")
        self.kind = detect_compression(self._f.read(8))
        self._f.seek(0)
        self._parse_http = parse_http
        self._verify = verify_digests
        self._zbuf: bytes | None = None

    def read(self, offset: int,
             frame: tuple[int, int] | None = None) -> WarcRecord | None:
        """Parse exactly the record starting at ``offset``.

        ``frame`` — optional ``(frame_off, frame_base)`` pair for zstd
        shards: the compressed offset of the frame containing the record
        and that frame's decompressed base. Ignored for member formats
        (their offsets already address the compressed stream).
        """
        if self.kind == "zstd":
            if frame is not None and self._zbuf is None:
                frame_off, frame_base = frame
                self._f.seek(int(frame_off))
                window = ForwardWindow(ZstdStream(self._f),
                                       base=int(frame_base))
                return read_record_at(window, int(offset),
                                      parse_http=self._parse_http,
                                      verify_digests=self._verify,
                                      shard=self.path)
            if self._zbuf is None:
                self._f.seek(0)
                self._zbuf = ZstdStream(self._f).read()
            return read_record_at(io.BytesIO(self._zbuf), int(offset),
                                  parse_http=self._parse_http,
                                  verify_digests=self._verify,
                                  shard=self.path)
        return read_record_at(self._f, int(offset),
                              parse_http=self._parse_http,
                              verify_digests=self._verify,
                              shard=self.path)

    def read_entry(self, entry: CdxEntry) -> WarcRecord | None:
        return self.read(entry.offset)

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        self._f.close()
        self._zbuf = None

    def __enter__(self) -> "RandomAccessReader":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()


def verify_index(index: CdxIndex, *, limit: int | None = None,
                 use_kernel: bool = True, interpret: bool = True,
                 check_signatures: bool = False) -> list[bool]:
    """Bulk-verify indexed adler32 digests against re-read record content.

    Every checked record is fetched through :class:`RandomAccessReader`
    and the whole batch is verified in batched kernel dispatches — one
    per width bucket, never one device call per record. Digest-only
    verification (the default) goes through ``verify_digests_bulk``;
    ``check_signatures=True`` routes the batch through the **fused**
    :func:`repro.kernels.digest_sig.digest_signature_batch` sweep — the
    same single-pass path the fused build uses — and additionally
    requires each re-computed n-gram signature to equal the stored
    signature row (both come out of the one sweep for free; computing
    the signature matrix just to discard it would make the digest-only
    case pay the full n-gram sweep). ``use_kernel=False`` runs
    everything on the host; a geometry the fused kernel does not cover
    keeps digest verification on the batched adler32 kernel and only
    the signature re-check falls back to the host.
    """
    from repro.core.warc.checksum import verify_digests_bulk

    n = len(index) if limit is None else min(limit, len(index))
    datas: list[bytes] = []
    readers: dict[int, RandomAccessReader] = {}
    try:
        for i in range(n):
            sid = int(index.shard_id[i])
            reader = readers.get(sid)
            if reader is None:
                reader = readers[sid] = RandomAccessReader(
                    index.shard_paths[sid], parse_http=False)
            record = reader.read(int(index.offset[i]),
                                 frame=index.frame_hint(i))
            datas.append(record.content if record is not None else b"")
    finally:
        for reader in readers.values():
            reader.close()
    expected = index.digest[:n].astype(np.uint32)
    if use_kernel and check_signatures and \
            _fused_supported(index.sig_bits, index.sig_ngram):
        from repro.kernels.digest_sig import digest_signature_batch

        # chunked exactly like the build's pending/flush loop: one
        # unbounded sweep would pad the whole corpus into int32 hash
        # matrices (~5-10x payload bytes resident) and OOM on big indexes
        ok = np.empty(n, bool)
        start = 0
        while start < n:
            end = start + 1
            nbytes = len(datas[start])
            while end < n and end - start < _FUSED_BATCH and \
                    nbytes < _FUSED_BATCH_BYTES:
                nbytes += len(datas[end])
                end += 1
            digests, sigs = digest_signature_batch(
                datas[start:end], bits=index.sig_bits, n=index.sig_ngram,
                k=index.sig_hashes, interpret=interpret)
            ok[start:end] = ((digests == expected[start:end])
                             & (sigs == index.signatures[start:end])
                             .all(axis=1))
            start = end
        return [bool(b) for b in ok]
    headers = [f"adler32:{int(d):08x}" for d in expected]
    results = verify_digests_bulk(datas, headers, use_kernel=use_kernel,
                                  interpret=interpret)
    if check_signatures:
        for i, data in enumerate(datas):
            sig = signature_of(data, bits=index.sig_bits,
                               n=index.sig_ngram, k=index.sig_hashes)
            results[i] = results[i] and bool(
                (sig == index.signatures[i]).all())
    return results
