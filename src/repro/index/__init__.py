"""``repro.index`` — CDX-style record index + archive query engine.

The subsystem that makes the paper's "constant-time random access"
claim executable at corpus scale (DESIGN.md §7):

* :mod:`.cdx` — binary columnar CDX index (build / merge / save / load)
  and :class:`RandomAccessReader` (one seek + one member decode + one
  record parse per lookup);
* :mod:`.signature` — per-record n-gram Bloom-style bitmaps, the
  decompress-avoidance pre-filter;
* :mod:`.query` — header-predicate + payload-pattern queries, candidate
  payloads scanned in batched ``find_pattern_mask_batch`` dispatches;
* :mod:`.service` — request-queue serving front end with ranked hits.

>>> from repro.index import build_index, QueryEngine, HeaderFilter
>>> index = build_index(["crawl-00.warc.gz"], workers=2)
>>> with QueryEngine(index) as engine:
...     hits = engine.search(b"archive", HeaderFilter(status=200))
"""
from .cdx import (
    CdxEntry,
    CdxIndex,
    RandomAccessReader,
    build_index,
    verify_index,
)
from .query import (
    HeaderFilter,
    PatternHit,
    QueryEngine,
    QueryPlan,
    full_scan_regex,
    full_scan_search,
    required_literals,
)
from .service import IndexQueryService, QueryRequest, QueryResponse
from . import signature

__all__ = [
    "CdxEntry",
    "CdxIndex",
    "HeaderFilter",
    "IndexQueryService",
    "PatternHit",
    "QueryEngine",
    "QueryPlan",
    "QueryRequest",
    "QueryResponse",
    "RandomAccessReader",
    "build_index",
    "full_scan_regex",
    "full_scan_search",
    "required_literals",
    "signature",
    "verify_index",
]
