"""Indexed archive query engine: header predicates + batched pattern scan.

The filter-first pipeline over a :class:`repro.index.cdx.CdxIndex`
(DESIGN.md §7). A query narrows the corpus in three strictly cheaper-
to-more-expensive stages:

1. **header predicates** — record type / HTTP status / MIME prefix /
   URL prefix evaluate as vector compares over the columnar index; no
   archive byte is touched.
2. **signature pre-filter** — the per-record n-gram bitmap
   (:mod:`repro.index.signature`) eliminates records that *cannot*
   contain the pattern; eliminated records are never decompressed.
3. **batched payload scan** — surviving candidates are fetched through
   per-shard :class:`~repro.index.cdx.RandomAccessReader`\\ s (offsets
   sorted for locality), gathered into ragged batches, and each batch
   goes through **one** :func:`repro.kernels.find_pattern_mask_batch`
   dispatch — the bulk consumer of the batched pattern kernel; the
   power-of-two width bucketing keeps repeated ragged batches on a
   bounded set of compiled shapes.

Stages 1–2 are reified as a :class:`QueryPlan` (``engine.plan`` /
``engine.plan_regex``): the candidate row set plus everything stage 3
needs to scan and verify one record. ``engine.execute`` runs a plan to
hits; the serve-layer gateway (:mod:`repro.serve.archive`) instead
*merges* the plans of concurrent queries and scans their candidates
through shared multi-pattern kernel dispatches — same verification
helpers, byte-identical hits.

**Regex queries** (``search_regex``) compile to this same shape: the
regex's required literal runs (extracted from the parsed pattern) drive
the signature pre-filter and the kernel scan, and surviving candidates
are host-verified with ``re`` — closing the pattern-literal-only gap
(the WarcSearcher workload). A regex with no usable literal degrades to
host ``re`` over the header-filtered candidates, still correct.

**Columnar path** (DESIGN.md §13): with a derived
:class:`repro.columnar.ColumnStore` attached (``attach_store`` /
``from_store``), stage 3 becomes ``execute_columnar`` — candidates are
grouped by the row-group that already holds their payload in the
kernels' packed layout, and each group is **one**
:func:`repro.kernels.find_pattern_mask_rowgroup` dispatch straight over
the mmapped matrix. No per-record seek, decompression, HTTP parse, or
ragged re-bucketing on the query path; payload bytes are materialized
only for candidates whose scan stage actually hit. Hits are
byte-identical to the CDX+seek path (the columnar bench gates on it).

``engine.stats`` records how much work each stage avoided (candidate
counts, records scanned, kernel dispatches) so the benchmarks can report
indexed-query vs full-scan speedups honestly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import TYPE_CHECKING

import numpy as np

from repro.core.warc.record import WarcRecordType
from .cdx import CdxIndex, RandomAccessReader
from .signature import candidate_mask

if TYPE_CHECKING:  # pragma: no cover - annotation only (no import cycle)
    from repro.columnar.store import ColumnStore

try:  # renamed in 3.11+; both expose the same parse tree
    from re import _parser as _sre_parse  # type: ignore[attr-defined]
except ImportError:  # pragma: no cover - Python < 3.11
    import sre_parse as _sre_parse  # type: ignore[no-redef]

__all__ = ["HeaderFilter", "PatternHit", "QueryEngine", "QueryPlan",
           "full_scan_search", "full_scan_regex", "required_literals"]

_DEFAULT_BATCH_RECORDS = 64
_DEFAULT_BATCH_BYTES = 4 << 20
_DEFAULT_SCAN_BLOCK = 8192  # kernel tile: few-KiB records pad ≤2×, not to
                            # the 64 KiB DEFAULT_BLOCK sized for whole shards
_COLUMNAR_DENSITY = 0.25  # candidate share above which scanning the whole
                          # row-group beats gathering candidates into a
                          # compact matrix (gather copies; whole-group reads
                          # the mapping in place)


@dataclass
class HeaderFilter:
    """Columnar header predicates (all optional, AND-combined).

    ``time_range`` — ``(lo, hi)`` epoch seconds, half-open — evaluates
    against the derived store's WARC-Date timestamp column and therefore
    needs a store-attached engine (the CDX index does not carry
    timestamps).
    """

    record_type: WarcRecordType | None = None
    status: int | None = None
    mime_prefix: bytes | None = None
    url_prefix: bytes | None = None
    time_range: tuple[int, int] | None = None

    def key(self) -> tuple:
        """Hashable identity (dataclass __hash__ is suppressed by eq)."""
        return (None if self.record_type is None else int(self.record_type),
                self.status, self.mime_prefix, self.url_prefix,
                self.time_range)


@dataclass
class PatternHit:
    """One matching record with its in-content match positions."""

    index_row: int
    shard: str
    offset: int
    uri: bytes
    n_matches: int
    positions: np.ndarray = field(repr=False)
    excerpt: bytes = b""


@dataclass
class QueryPlan:
    """Stages 1–2 of one query, reified: what to scan and how to verify.

    ``rows`` is the candidate set in fetch order (shard-grouped,
    offset-sorted). Stage 3 scans each candidate for ``kernel_pattern``
    on the device (``None`` → host-only scan), then
    :meth:`verify` maps a candidate's literal hits to its final match
    positions — full-literal compare for patterns longer than the kernel
    window, ``re`` for regex queries. Plans from *different* queries can
    be scanned through one shared kernel dispatch (the serve gateway
    does), because verification is per-plan.
    """

    pattern: bytes               # the query as submitted (literal / source)
    rows: np.ndarray             # candidate index rows, fetch order
    kernel_pattern: bytes | None  # device-scannable literal prefix
    literal: bytes | None        # full required literal (None: regex w/o one)
    regex: "re.Pattern | None" = None

    def verify(self, buf: bytes,
               literal_positions: np.ndarray) -> tuple[np.ndarray, int]:
        """Final match positions in ``buf`` + first-match byte length.

        ``literal_positions`` are the scan stage's hits for
        ``kernel_pattern`` (or for ``literal`` on the host path). The
        length is what excerpting needs — fixed for literal queries,
        the first match's span for regex.
        """
        if self.regex is not None:
            if self.literal is not None and literal_positions.size == 0:
                return np.empty(0, np.int64), 0
            matches = list(self.regex.finditer(buf))
            if not matches:
                return np.empty(0, np.int64), 0
            first = matches[0]
            return (np.asarray([m.start() for m in matches], np.int64),
                    max(first.end() - first.start(), 1))
        lit = self.literal if self.literal is not None else self.pattern
        positions = literal_positions
        if self.kernel_pattern is not None and len(lit) > len(
                self.kernel_pattern):
            # kernel scanned a prefix; confirm the (few) survivors
            positions = np.asarray(
                [p for p in positions if buf[p:p + len(lit)] == lit],
                np.int64)
        return positions, len(lit)

    @property
    def needs_host_scan(self) -> bool:
        """True when the scan stage itself must run on the host (no
        device-safe literal: all-zero prefix, or a literal-free regex)."""
        return self.kernel_pattern is None

    def host_scan(self, buf: bytes) -> np.ndarray:
        """Host-side scan-stage positions for one candidate payload —
        the ``literal_positions`` input :meth:`verify` expects. A
        literal-free regex has nothing to pre-scan for: a non-empty
        sentinel makes verify() run the regex on every candidate."""
        if self.regex is not None and self.literal is None:
            return np.zeros(1, np.int64)
        return host_positions(
            buf, self.literal if self.literal is not None else self.pattern)


def host_positions(buf: bytes, pattern: bytes) -> np.ndarray:
    """All (overlapping) occurrences of ``pattern`` — host scan path."""
    pos, i = [], buf.find(pattern)
    while i >= 0:
        pos.append(i)
        i = buf.find(pattern, i + 1)
    return np.asarray(pos, np.int64)


def required_literals(pattern: bytes, flags: int = 0) -> list[bytes]:
    """Literal byte runs every match of ``pattern`` must contain.

    Conservative walk of the parsed regex: top-level concatenation
    literals form runs; a group or a repeat with ``min >= 1`` is entered
    (its own requirements hold at least once); branches, classes,
    optional parts contribute nothing. Case-insensitive patterns return
    no literals (the bytes are not required as written). Soundness is
    what matters — every returned literal occurs in every match — since
    literals only *pre-filter*; ``re`` always confirms.
    """
    if flags & re.IGNORECASE:
        return []
    try:
        parsed = _sre_parse.parse(pattern, flags)
    except re.error:
        return []
    # inline flags ((?i)...) surface only after the parse
    if getattr(parsed.state, "flags", 0) & re.IGNORECASE:
        return []
    literals: list[bytes] = []

    def walk(ops) -> None:
        run = bytearray()

        def flush() -> None:
            if run:
                literals.append(bytes(run))
                run.clear()

        for op, args in ops:
            name = str(op)
            if name == "LITERAL" and args <= 0xFF:
                run.append(args)
                continue
            flush()
            if name in ("MAX_REPEAT", "MIN_REPEAT"):
                lo, _hi, sub = args
                if lo >= 1:
                    walk(sub)
            elif name == "SUBPATTERN":
                # scoped inline flags ((?i:...)) make the group's bytes
                # not-required-as-written: contribute nothing from it
                if not args[1] & re.IGNORECASE:
                    walk(args[3])
            elif name == "ATOMIC_GROUP":
                walk(args)
            # BRANCH / IN / ANY / AT / NOT_LITERAL / ...: no requirement
        flush()

    walk(parsed)
    return [lit for lit in literals if lit]


class QueryEngine:
    """Run header + pattern queries against an indexed corpus."""

    def __init__(self, index: CdxIndex, *,
                 store: "ColumnStore | None" = None,
                 batch_records: int = _DEFAULT_BATCH_RECORDS,
                 batch_bytes: int = _DEFAULT_BATCH_BYTES,
                 use_kernel: bool = True, interpret: bool = True,
                 scan_block: int = _DEFAULT_SCAN_BLOCK,
                 excerpt_bytes: int = 80) -> None:
        self.index = index
        self.batch_records = max(1, batch_records)
        self.batch_bytes = max(1, batch_bytes)
        self.scan_block = scan_block
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.excerpt_bytes = excerpt_bytes
        self._readers: dict[int, RandomAccessReader] = {}
        self._store: "ColumnStore | None" = None
        self.stats = {"queries": 0, "header_candidates": 0,
                      "sig_candidates": 0, "records_scanned": 0,
                      "bytes_scanned": 0, "kernel_dispatches": 0,
                      "batches": 0, "store_fetches": 0}
        if store is not None:
            self.attach_store(store)

    @classmethod
    def from_store(cls, store: "ColumnStore", **kwargs) -> "QueryEngine":
        """An engine running standalone on a derived store — planner
        stages over :meth:`~repro.columnar.ColumnStore.as_index`'s
        columns, scan stage over the store's row-groups. No CDX file
        and no archive readers involved."""
        engine = cls(store.as_index(), **kwargs)
        engine.attach_store(store, validate=False)
        return engine

    def attach_store(self, store: "ColumnStore",
                     validate: bool = True) -> None:
        """Attach a derived columnar store covering this engine's corpus.

        Attached, the engine routes ``execute`` through
        :meth:`execute_columnar` and serves ``_fetch`` from the store's
        row-groups (no seek/decompress) — the serve gateway inherits
        both for free. ``validate`` checks the store rows are 1:1 with
        the index rows (derive and CDX build share row order by
        construction; a store derived from a *different* corpus is
        rejected here rather than silently mis-scanned).
        """
        if validate:
            if len(store) != len(self.index):
                raise ValueError(
                    f"store has {len(store)} rows, index has "
                    f"{len(self.index)} — not the same corpus")
            if list(store.shard_paths) != list(self.index.shard_paths):
                raise ValueError("store and index cover different shards")
            if not np.array_equal(np.asarray(store.offset),
                                  np.asarray(self.index.offset)):
                raise ValueError("store row order does not match the "
                                 "index (offset columns differ)")
        self._store = store

    @property
    def store(self) -> "ColumnStore | None":
        return self._store

    # -- stage 1: header predicates (pure columnar) ----------------------
    def header_mask(self, flt: HeaderFilter | None) -> np.ndarray:
        """Boolean row mask from the metadata columns alone."""
        idx = self.index
        mask = np.ones(len(idx), dtype=bool)
        if flt is None:
            return mask
        if flt.record_type is not None:
            mask &= (idx.rtype.astype(np.int64)
                     & np.int64(int(flt.record_type))) != 0
        if flt.status is not None:
            # int64 compare: a bad user-supplied status (out of int16
            # range) selects nothing instead of raising OverflowError
            mask &= idx.status.astype(np.int64) == int(flt.status)
        if flt.mime_prefix is not None:
            mask &= np.char.startswith(idx.mimes(), bytes(flt.mime_prefix))
        if flt.url_prefix is not None:
            mask &= np.char.startswith(idx.uris(), bytes(flt.url_prefix))
        if flt.time_range is not None:
            if self._store is None:
                raise ValueError(
                    "time_range filters read the derived store's "
                    "timestamp column — attach_store() first (the CDX "
                    "index carries no WARC-Date)")
            lo, hi = flt.time_range
            ts = self._store.timestamp.astype(np.int64)
            mask &= (ts >= int(lo)) & (ts < int(hi))
        return mask

    def select(self, flt: HeaderFilter | None = None) -> np.ndarray:
        """Index rows satisfying the header predicates (sorted)."""
        return np.flatnonzero(self.header_mask(flt))

    # -- stages 1+2: plan construction -----------------------------------
    def _finish_plan(self, mask: np.ndarray, literals: list[bytes],
                     prefilter: bool) -> np.ndarray:
        """Apply the signature pre-filter and fix the fetch order."""
        self.stats["queries"] += 1
        self.stats["header_candidates"] += int(mask.sum())
        if prefilter:
            for lit in literals:
                mask &= candidate_mask(self.index.signatures, lit,
                                       n=self.index.sig_ngram,
                                       k=self.index.sig_hashes)
        rows = np.flatnonzero(mask)
        self.stats["sig_candidates"] += int(rows.size)
        # shard-grouped, offset-sorted fetch order for read locality
        order = np.lexsort((self.index.offset[rows],
                            self.index.shard_id[rows]))
        return rows[order]

    @staticmethod
    def _kernel_literal(literal: bytes) -> bytes | None:
        """Device-scannable prefix of a literal, or None (host scan)."""
        from repro.kernels.pattern_scan.pattern_scan import MAX_PATTERN

        kpat = literal[:MAX_PATTERN]
        # all-zero prefix: the kernel wrapper rejects it (zero padding
        # could false-positive); those rare queries scan on the host
        return kpat if any(kpat) else None

    def plan(self, pattern: bytes, flt: HeaderFilter | None = None, *,
             prefilter: bool = True) -> QueryPlan:
        """Stages 1+2 for a literal pattern query."""
        pattern = bytes(pattern)
        if not pattern:
            raise ValueError("empty pattern")
        rows = self._finish_plan(self.header_mask(flt), [pattern], prefilter)
        return QueryPlan(pattern=pattern, rows=rows,
                         kernel_pattern=self._kernel_literal(pattern),
                         literal=pattern)

    def plan_regex(self, regex: "bytes | re.Pattern",
                   flt: HeaderFilter | None = None, *,
                   prefilter: bool = True) -> QueryPlan:
        """Stages 1+2 for a regex query: required literals drive the
        pre-filter and the kernel scan; ``re`` verifies survivors."""
        compiled = regex if isinstance(regex, re.Pattern) else re.compile(
            regex)
        if not isinstance(compiled.pattern, bytes):
            raise TypeError("content scans need a bytes regex")
        literals = required_literals(compiled.pattern, compiled.flags
                                     & ~re.UNICODE)
        rows = self._finish_plan(self.header_mask(flt), literals, prefilter)
        scan_literal = max(literals, key=len) if literals else None
        return QueryPlan(
            pattern=compiled.pattern, rows=rows,
            kernel_pattern=(self._kernel_literal(scan_literal)
                            if scan_literal else None),
            literal=scan_literal, regex=compiled)

    # -- stage 3: execution ----------------------------------------------
    def search(self, pattern: bytes, flt: HeaderFilter | None = None, *,
               prefilter: bool = True) -> list[PatternHit]:
        """All records whose content block contains ``pattern``.

        Results are in index order. Candidates are fetched shard-by-shard
        in ascending offset order and scanned in ragged batches of at
        most ``batch_records`` records / ``batch_bytes`` bytes — each
        batch is one (bucketed) kernel dispatch, never one per record.
        """
        return self.execute(self.plan(pattern, flt, prefilter=prefilter))

    def search_regex(self, regex: "bytes | re.Pattern",
                     flt: HeaderFilter | None = None, *,
                     prefilter: bool = True) -> list[PatternHit]:
        """All records whose content block matches ``regex`` (bytes).

        ``n_matches``/``positions`` follow ``re.finditer`` semantics
        (non-overlapping matches).
        """
        return self.execute(self.plan_regex(regex, flt, prefilter=prefilter))

    def execute(self, plan: QueryPlan, *,
                columnar: bool | None = None) -> list[PatternHit]:
        """Run a plan's scan stage: fetch, batch, dispatch, verify.

        With a store attached the scan routes through
        :meth:`execute_columnar` (byte-identical hits); pass
        ``columnar=False`` to force the fetch-and-batch path, or
        ``columnar=True`` to require the store (raises if absent).
        """
        if columnar is None:
            columnar = self._store is not None
        if columnar:
            return self.execute_columnar(plan)
        hits: list[PatternHit] = []
        batch_rows: list[int] = []
        batch_bufs: list[bytes] = []
        pending = 0
        for r in plan.rows:
            content = self._fetch(int(r))
            batch_rows.append(int(r))
            batch_bufs.append(content)
            pending += len(content)
            if (len(batch_rows) >= self.batch_records
                    or pending >= self.batch_bytes):
                hits.extend(self._scan_batch(batch_rows, batch_bufs, plan))
                batch_rows, batch_bufs, pending = [], [], 0
        if batch_rows:
            hits.extend(self._scan_batch(batch_rows, batch_bufs, plan))
        hits.sort(key=lambda h: h.index_row)
        return hits

    # -- stage 3, columnar: kernels over mmapped row-groups ---------------
    def execute_columnar(self, plan: QueryPlan) -> list[PatternHit]:
        """Run a plan's scan stage against the attached derived store.

        Candidates are grouped by row-group; each group is one
        row-group kernel dispatch over its packed matrix — **dense**
        groups (candidate share ≥ ``_COLUMNAR_DENSITY`` of the group's
        live rows) scan the mmapped matrix in place, **sparse** groups
        gather just the candidate rows into a compact matrix first.
        Payload bytes are copied out only for candidates whose scan
        stage hit (verification / excerpting); everything else never
        leaves the mapping. Hits are byte-identical to :meth:`execute`.
        """
        store = self._store
        if store is None:
            raise ValueError("no columnar store attached — attach_store() "
                             "or QueryEngine.from_store()")
        hits: list[PatternHit] = []
        if plan.rows.size == 0:
            return hits
        from repro.kernels.bucketing import quantize_count
        from repro.kernels.pattern_scan import find_pattern_mask_rowgroup

        gids = store.rg_id[plan.rows].astype(np.int64)
        order = np.argsort(gids, kind="stable")
        ordered = plan.rows[order]
        bounds = np.flatnonzero(np.diff(gids[order])) + 1
        use_kernel = self.use_kernel and not plan.needs_host_scan
        # short-literal plans need no per-candidate verification: the
        # kernel positions are final and the excerpt window slices
        # straight out of the row-group matrix — no payload copy at all
        lit = plan.literal if plan.literal is not None else plan.pattern
        fast_literal = (plan.regex is None and plan.kernel_pattern is not None
                        and len(lit) <= len(plan.kernel_pattern))
        for chunk in np.split(ordered, bounds):
            g = int(store.rg_id[chunk[0]])
            lengths = store.length[chunk].astype(np.int64)
            self.stats["batches"] += 1
            self.stats["records_scanned"] += int(chunk.size)
            self.stats["bytes_scanned"] += int(lengths.sum())
            if not use_kernel:  # host scan: materialize each candidate
                for r in chunk:
                    buf = store.payload(int(r))
                    positions, first_len = plan.verify(buf,
                                                       plan.host_scan(buf))
                    if positions.size:
                        hits.append(self.make_hit(int(r), buf, positions,
                                                  first_len))
                continue
            live = int(store.rg_rows[g])
            if chunk.size >= _COLUMNAR_DENSITY * live:
                # dense: one dispatch over the whole mmapped matrix
                source, _, all_lens = store.rowgroup(g)
                masks = find_pattern_mask_rowgroup(
                    source, all_lens, plan.kernel_pattern,
                    interpret=self.interpret, trim=False)
                mask_rows = store.rg_row[chunk].astype(np.int64)
                mask_lens = all_lens
            else:
                # sparse: gather candidates into a compact matrix
                matrix, _, _ = store.rowgroup(g)
                sel = store.rg_row[chunk].astype(np.int64)
                source = np.zeros(
                    (quantize_count(chunk.size), matrix.shape[1]), np.uint8)
                source[:chunk.size] = matrix[sel]
                masks = find_pattern_mask_rowgroup(
                    source, lengths, plan.kernel_pattern,
                    interpret=self.interpret, trim=False)
                mask_rows = np.arange(chunk.size)
                mask_lens = lengths
            self.stats["kernel_dispatches"] += 1
            # one pass over the whole group mask instead of a
            # flatnonzero per candidate; the flat bool scan is ~10x
            # cheaper than a 2-D nonzero, and row-major order means each
            # candidate's positions stay one contiguous hit_cols run
            flat = np.flatnonzero(masks.view(bool))
            hit_rows, hit_cols = np.divmod(flat, masks.shape[1])
            # trim=False left windows past each row's true end in the
            # mask; drop them here on the compact hit list instead of
            # paying a full-matrix where-copy up front
            plen_k = len(plan.kernel_pattern)
            valid = hit_cols < np.maximum(
                mask_lens - plen_k + 1, 0)[hit_rows]
            hit_rows = hit_rows[valid]
            hit_cols = hit_cols[valid]
            starts = np.searchsorted(hit_rows, mask_rows, side="left")
            ends = np.searchsorted(hit_rows, mask_rows, side="right")
            for i in np.flatnonzero(ends > starts):
                r = int(chunk[i])
                lpos = hit_cols[starts[i]:ends[i]].astype(np.int64)
                if fast_literal:  # positions final; excerpt off the row
                    row = source[int(mask_rows[i])][:int(lengths[i])]
                    hits.append(self.make_hit(r, row, lpos, len(lit)))
                    continue
                buf = store.payload(r)
                positions, first_len = plan.verify(buf, lpos)
                if positions.size:
                    hits.append(self.make_hit(r, buf, positions,
                                              first_len))
        hits.sort(key=lambda h: h.index_row)
        return hits

    # -- internals -------------------------------------------------------
    def _fetch(self, row: int) -> bytes:
        if self._store is not None:  # row-group copy-out: no seek/inflate
            self.stats["store_fetches"] += 1
            return self._store.payload(row)
        sid = int(self.index.shard_id[row])
        reader = self._readers.get(sid)
        if reader is None:
            reader = self._readers[sid] = RandomAccessReader(
                self.index.shard_paths[sid], parse_http=False)
        record = reader.read(int(self.index.offset[row]),
                             frame=self.index.frame_hint(row))
        return record.content if record is not None else b""

    def make_hit(self, row: int, buf: bytes, positions: np.ndarray,
                 first_len: int) -> PatternHit:
        """Assemble one hit (shared with the serve gateway)."""
        first = int(positions[0])
        excerpt = bytes(buf[max(0, first - 16):
                            first + first_len + self.excerpt_bytes])
        sid = int(self.index.shard_id[row])
        return PatternHit(
            index_row=row, shard=self.index.shard_paths[sid],
            offset=int(self.index.offset[row]), uri=self.index.uri(row),
            n_matches=int(positions.size), positions=positions,
            excerpt=excerpt)

    def _scan_batch(self, rows: list[int], bufs: list[bytes],
                    plan: QueryPlan) -> list[PatternHit]:
        self.stats["batches"] += 1
        self.stats["records_scanned"] += len(rows)
        self.stats["bytes_scanned"] += sum(len(b) for b in bufs)
        if self.use_kernel and not plan.needs_host_scan:
            from repro.kernels.bucketing import dispatch_count
            from repro.kernels.pattern_scan import find_pattern_mask_batch

            masks = find_pattern_mask_batch(bufs, plan.kernel_pattern,
                                            block=self.scan_block,
                                            interpret=self.interpret)
            lit_positions = [np.flatnonzero(m) for m in masks]
            self.stats["kernel_dispatches"] += dispatch_count(
                [len(b) for b in bufs], self.scan_block)
        else:  # host fallback: plain bytes.find sweep (or regex verify-all)
            lit_positions = [plan.host_scan(buf) for buf in bufs]
        hits = []
        for row, buf, lpos in zip(rows, bufs, lit_positions):
            positions, first_len = plan.verify(buf, lpos)
            if positions.size:
                hits.append(self.make_hit(row, buf, positions, first_len))
        return hits

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        for reader in self._readers.values():
            reader.close()
        self._readers.clear()

    def __enter__(self) -> "QueryEngine":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()


def full_scan_search(paths, pattern: bytes) -> dict[tuple[str, int], int]:
    """Naive baseline: decompress + scan **every** record of every shard.

    Returns ``{(shard, offset): n_matches}`` for records containing the
    pattern — the oracle the property tests compare the indexed path
    against, and the benchmark's un-indexed comparison point.
    """
    from repro.core.warc.fastwarc import FastWARCIterator

    pattern = bytes(pattern)
    out: dict[tuple[str, int], int] = {}
    for path in paths:
        for record in FastWARCIterator(str(path), parse_http=False):
            content = record.content
            n, i = 0, content.find(pattern)
            while i >= 0:
                n += 1
                i = content.find(pattern, i + 1)
            if n:
                out[(str(path), record.stream_offset)] = n
    return out


def full_scan_regex(paths, regex: "bytes | re.Pattern"
                    ) -> dict[tuple[str, int], int]:
    """Regex oracle: ``re.finditer`` over every record of every shard."""
    from repro.core.warc.fastwarc import FastWARCIterator

    compiled = regex if isinstance(regex, re.Pattern) else re.compile(regex)
    out: dict[tuple[str, int], int] = {}
    for path in paths:
        for record in FastWARCIterator(str(path), parse_http=False):
            n = sum(1 for _ in compiled.finditer(record.content))
            if n:
                out[(str(path), record.stream_offset)] = n
    return out
