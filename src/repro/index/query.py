"""Indexed archive query engine: header predicates + batched pattern scan.

The filter-first pipeline over a :class:`repro.index.cdx.CdxIndex`
(DESIGN.md §7). A query narrows the corpus in three strictly cheaper-
to-more-expensive stages:

1. **header predicates** — record type / HTTP status / MIME prefix /
   URL prefix evaluate as vector compares over the columnar index; no
   archive byte is touched.
2. **signature pre-filter** — the per-record n-gram bitmap
   (:mod:`repro.index.signature`) eliminates records that *cannot*
   contain the pattern; eliminated records are never decompressed.
3. **batched payload scan** — surviving candidates are fetched through
   per-shard :class:`~repro.index.cdx.RandomAccessReader`\\ s (offsets
   sorted for locality), gathered into ragged batches, and each batch
   goes through **one** :func:`repro.kernels.find_pattern_mask_batch`
   dispatch — the bulk consumer of the batched pattern kernel; the
   power-of-two width bucketing keeps repeated ragged batches on a
   bounded set of compiled shapes.

``engine.stats`` records how much work each stage avoided (candidate
counts, records scanned, kernel dispatches) so the benchmarks can report
indexed-query vs full-scan speedups honestly.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.warc.record import WarcRecordType
from .cdx import CdxIndex, RandomAccessReader
from .signature import candidate_mask

__all__ = ["HeaderFilter", "PatternHit", "QueryEngine", "full_scan_search"]

_DEFAULT_BATCH_RECORDS = 64
_DEFAULT_BATCH_BYTES = 4 << 20
_DEFAULT_SCAN_BLOCK = 8192  # kernel tile: few-KiB records pad ≤2×, not to
                            # the 64 KiB DEFAULT_BLOCK sized for whole shards


@dataclass
class HeaderFilter:
    """Columnar header predicates (all optional, AND-combined)."""

    record_type: WarcRecordType | None = None
    status: int | None = None
    mime_prefix: bytes | None = None
    url_prefix: bytes | None = None


@dataclass
class PatternHit:
    """One matching record with its in-content match positions."""

    index_row: int
    shard: str
    offset: int
    uri: bytes
    n_matches: int
    positions: np.ndarray = field(repr=False)
    excerpt: bytes = b""


class QueryEngine:
    """Run header + pattern queries against an indexed corpus."""

    def __init__(self, index: CdxIndex, *,
                 batch_records: int = _DEFAULT_BATCH_RECORDS,
                 batch_bytes: int = _DEFAULT_BATCH_BYTES,
                 use_kernel: bool = True, interpret: bool = True,
                 scan_block: int = _DEFAULT_SCAN_BLOCK,
                 excerpt_bytes: int = 80) -> None:
        self.index = index
        self.batch_records = max(1, batch_records)
        self.batch_bytes = max(1, batch_bytes)
        self.scan_block = scan_block
        self.use_kernel = use_kernel
        self.interpret = interpret
        self.excerpt_bytes = excerpt_bytes
        self._readers: dict[int, RandomAccessReader] = {}
        self.stats = {"queries": 0, "header_candidates": 0,
                      "sig_candidates": 0, "records_scanned": 0,
                      "bytes_scanned": 0, "kernel_dispatches": 0,
                      "batches": 0}

    # -- stage 1: header predicates (pure columnar) ----------------------
    def header_mask(self, flt: HeaderFilter | None) -> np.ndarray:
        """Boolean row mask from the metadata columns alone."""
        idx = self.index
        mask = np.ones(len(idx), dtype=bool)
        if flt is None:
            return mask
        if flt.record_type is not None:
            mask &= (idx.rtype.astype(np.int64)
                     & np.int64(int(flt.record_type))) != 0
        if flt.status is not None:
            # int64 compare: a bad user-supplied status (out of int16
            # range) selects nothing instead of raising OverflowError
            mask &= idx.status.astype(np.int64) == int(flt.status)
        if flt.mime_prefix is not None:
            mask &= np.char.startswith(idx.mimes(), bytes(flt.mime_prefix))
        if flt.url_prefix is not None:
            mask &= np.char.startswith(idx.uris(), bytes(flt.url_prefix))
        return mask

    def select(self, flt: HeaderFilter | None = None) -> np.ndarray:
        """Index rows satisfying the header predicates (sorted)."""
        return np.flatnonzero(self.header_mask(flt))

    # -- stage 2+3: pattern search ---------------------------------------
    def search(self, pattern: bytes, flt: HeaderFilter | None = None, *,
               prefilter: bool = True) -> list[PatternHit]:
        """All records whose content block contains ``pattern``.

        Results are in index order. Candidates are fetched shard-by-shard
        in ascending offset order and scanned in ragged batches of at
        most ``batch_records`` records / ``batch_bytes`` bytes — each
        batch is one (bucketed) kernel dispatch, never one per record.
        """
        pattern = bytes(pattern)
        if not pattern:
            raise ValueError("empty pattern")
        mask = self.header_mask(flt)
        self.stats["queries"] += 1
        self.stats["header_candidates"] += int(mask.sum())
        if prefilter:
            mask &= candidate_mask(self.index.signatures, pattern,
                                   n=self.index.sig_ngram,
                                   k=self.index.sig_hashes)
        rows = np.flatnonzero(mask)
        self.stats["sig_candidates"] += int(rows.size)
        # shard-grouped, offset-sorted fetch order for read locality
        order = np.lexsort((self.index.offset[rows],
                            self.index.shard_id[rows]))
        hits: list[PatternHit] = []
        batch_rows: list[int] = []
        batch_bufs: list[bytes] = []
        pending = 0
        for r in rows[order]:
            content = self._fetch(int(r))
            batch_rows.append(int(r))
            batch_bufs.append(content)
            pending += len(content)
            if (len(batch_rows) >= self.batch_records
                    or pending >= self.batch_bytes):
                hits.extend(self._scan_batch(batch_rows, batch_bufs, pattern))
                batch_rows, batch_bufs, pending = [], [], 0
        if batch_rows:
            hits.extend(self._scan_batch(batch_rows, batch_bufs, pattern))
        hits.sort(key=lambda h: h.index_row)
        return hits

    # -- internals -------------------------------------------------------
    def _fetch(self, row: int) -> bytes:
        sid = int(self.index.shard_id[row])
        reader = self._readers.get(sid)
        if reader is None:
            reader = self._readers[sid] = RandomAccessReader(
                self.index.shard_paths[sid], parse_http=False)
        record = reader.read(int(self.index.offset[row]))
        return record.content if record is not None else b""

    @staticmethod
    def _host_positions(buf: bytes, pattern: bytes) -> np.ndarray:
        pos, i = [], buf.find(pattern)
        while i >= 0:
            pos.append(i)
            i = buf.find(pattern, i + 1)
        return np.asarray(pos, np.int64)

    def _scan_batch(self, rows: list[int], bufs: list[bytes],
                    pattern: bytes) -> list[PatternHit]:
        self.stats["batches"] += 1
        self.stats["records_scanned"] += len(rows)
        self.stats["bytes_scanned"] += sum(len(b) for b in bufs)
        if self.use_kernel:
            from repro.kernels.bucketing import bucket_width
            from repro.kernels.pattern_scan import find_pattern_mask_batch
            from repro.kernels.pattern_scan.pattern_scan import MAX_PATTERN

            # kernel scans the first MAX_PATTERN bytes; longer patterns
            # get their (few) candidate positions host-verified
            kpat = pattern[:MAX_PATTERN]
            if not any(kpat):  # all-zero prefix: kernel rejects, host scans
                positions = [self._host_positions(buf, pattern)
                             for buf in bufs]
            else:
                masks = find_pattern_mask_batch(bufs, kpat,
                                                block=self.scan_block,
                                                interpret=self.interpret)
                positions = [np.flatnonzero(m) for m in masks]
                if len(pattern) > len(kpat):
                    positions = [
                        np.asarray([p for p in pos
                                    if buf[p:p + len(pattern)] == pattern],
                                   np.int64)
                        for buf, pos in zip(bufs, positions)]
                self.stats["kernel_dispatches"] += len(
                    {bucket_width(len(b), self.scan_block) for b in bufs})
        else:  # host fallback: plain bytes.find sweep
            positions = [self._host_positions(buf, pattern) for buf in bufs]
        hits = []
        for row, buf, pos in zip(rows, bufs, positions):
            if pos.size == 0:
                continue
            first = int(pos[0])
            excerpt = bytes(buf[max(0, first - 16):
                                first + len(pattern) + self.excerpt_bytes])
            sid = int(self.index.shard_id[row])
            hits.append(PatternHit(
                index_row=row, shard=self.index.shard_paths[sid],
                offset=int(self.index.offset[row]), uri=self.index.uri(row),
                n_matches=int(pos.size), positions=pos, excerpt=excerpt))
        return hits

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        for reader in self._readers.values():
            reader.close()
        self._readers.clear()

    def __enter__(self) -> "QueryEngine":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()


def full_scan_search(paths, pattern: bytes) -> dict[tuple[str, int], int]:
    """Naive baseline: decompress + scan **every** record of every shard.

    Returns ``{(shard, offset): n_matches}`` for records containing the
    pattern — the oracle the property tests compare the indexed path
    against, and the benchmark's un-indexed comparison point.
    """
    from repro.core.warc.fastwarc import FastWARCIterator

    pattern = bytes(pattern)
    out: dict[tuple[str, int], int] = {}
    for path in paths:
        for record in FastWARCIterator(str(path), parse_http=False):
            content = record.content
            n, i = 0, content.find(pattern)
            while i >= 0:
                n += 1
                i = content.find(pattern, i + 1)
            if n:
                out[(str(path), record.stream_offset)] = n
    return out
