"""Per-record n-gram signatures: the query engine's decompress-avoidance
pre-filter (DESIGN.md §7).

At index time every record's content block is folded into a small
Bloom-style bitmap: each overlapping byte n-gram is hashed to ``k`` bit
positions which are set in an ``m``-bit signature. At query time a
pattern of length ≥ n is folded the same way; any record whose signature
is missing one of the pattern's bits **cannot** contain the pattern
(every substring occurrence implies all of its n-grams occur), so the
record is never even decompressed. False positives only cost a wasted
decompress + scan — correctness never depends on the filter.

Everything is vectorized: signatures are built with one rolling-hash
sweep per record (numpy, no per-byte Python), and candidate selection is
a single ``(N, words)`` bitwise-AND/compare over the whole index column.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.bucketing import as_u8

__all__ = [
    "SIG_BITS",
    "SIG_HASHES",
    "SIG_NGRAM",
    "SIG_WORDS",
    "candidate_mask",
    "fold_positions_rows",
    "pattern_bits",
    "positions_from_hashes",
    "signature_of",
]

SIG_BITS = 4096     # bitmap size m (bits): 512 B per record. Sized for the
                    # few-KiB records web archives actually hold — a few
                    # hundred distinct n-grams per record keeps fill ~15 %,
                    # so a 10-byte pattern's ~14 required bits reject
                    # non-matching records with high probability. (At 256
                    # bits the map saturates and filters nothing.)
SIG_WORDS = SIG_BITS // 64
SIG_NGRAM = 4       # n-gram length; patterns shorter than this skip the filter
SIG_HASHES = 2      # k bit positions per n-gram (Kirsch–Mitzenmacher)

_FNV_PRIME = np.uint32(0x01000193)
_MIX = np.uint32(0x9E3779B1)


def _ngram_hashes(buf: np.ndarray, n: int) -> np.ndarray:
    """uint32 polynomial hash of every overlapping n-gram (one sweep)."""
    m = buf.size - n + 1
    h = np.zeros(m, dtype=np.uint32)
    for j in range(n):  # unrolled over the (tiny, static) n-gram length
        h = h * _FNV_PRIME + buf[j:j + m].astype(np.uint32)
    return h


def _bit_positions(h: np.ndarray, bits: int, k: int) -> np.ndarray:
    """k derived bit indices per hash, flattened (double hashing) — the
    single-record face of :func:`positions_from_hashes`, delegated so the
    derivation cannot silently diverge between host and fused paths."""
    return positions_from_hashes(h, bits, k).ravel()


def _fold(positions: np.ndarray, bits: int) -> np.ndarray:
    sig = np.zeros(bits // 64, dtype=np.uint64)
    words = (positions >> np.uint32(6)).astype(np.intp)
    shifts = (positions & np.uint32(63)).astype(np.uint64)
    np.bitwise_or.at(sig, words, np.uint64(1) << shifts)
    return sig


def positions_from_hashes(h: np.ndarray, bits: int, k: int) -> np.ndarray:
    """``(k, …)`` bit positions from uint32 n-gram hashes (double hashing).

    Vectorized over any hash-array shape — the batch half of
    :func:`_bit_positions`, shared with the fused
    ``digest_signature_batch`` kernel wrapper so the device sweep and the
    host reference derive bit positions from identical arithmetic.
    """
    h = h.astype(np.uint32, copy=False)
    h2 = (h ^ (h >> np.uint32(15))) * _MIX
    pow2 = bits & (bits - 1) == 0  # power-of-two: mask beats modulo
    out = np.empty((k,) + h.shape, np.uint32)
    acc = h
    for j in range(k):  # incremental: k+1 passes, no (k, m) temporaries
        if j == 1:
            acc = h + h2
        elif j > 1:
            acc += h2
        out[j] = acc & np.uint32(bits - 1) if pow2 else acc % np.uint32(bits)
    return out


def fold_positions_rows(n_rows: int, row_ids: np.ndarray,
                        positions: np.ndarray, bits: int) -> np.ndarray:
    """Fold flat ``(row, bit-position)`` pairs into ``(n_rows, bits//64)``
    uint64 signatures — layout-identical to :func:`_fold`, but built via
    one flat boolean scatter + ``packbits`` so folding a whole record
    batch is a handful of vector ops instead of a per-position
    ``bitwise_or.at`` loop (the profiling whale of the two-pass index
    build).

    ``positions`` may be ``(m,)`` or ``(k, m)`` (one plane per hash —
    scattered plane-by-plane so no ``(k, m)`` int64 temporary is ever
    materialized); ``row_ids`` is the matching ``(m,)`` row index."""
    bitmap = np.zeros(n_rows * bits, np.uint8)
    if positions.size:
        base = row_ids.astype(np.int64, copy=False) * bits
        planes = positions if positions.ndim == 2 else positions[None, :]
        for plane in planes:
            bitmap[base + plane] = 1
    packed = np.packbits(bitmap.reshape(n_rows, bits), axis=1,
                         bitorder="little")
    return packed.view(np.uint64)


def signature_of(data, *, bits: int = SIG_BITS, n: int = SIG_NGRAM,
                 k: int = SIG_HASHES) -> np.ndarray:
    """``(bits // 64,)`` uint64 signature of one record's content bytes."""
    buf = as_u8(data)
    if buf.size < n:
        return np.zeros(bits // 64, dtype=np.uint64)
    return _fold(_bit_positions(_ngram_hashes(buf, n), bits, k), bits)


def pattern_bits(pattern, *, bits: int = SIG_BITS, n: int = SIG_NGRAM,
                 k: int = SIG_HASHES) -> np.ndarray | None:
    """Required-bits mask for a query pattern, or ``None`` when the
    pattern is shorter than the n-gram length (filter inapplicable)."""
    pat = as_u8(pattern)
    if pat.size < n:
        return None
    return _fold(_bit_positions(_ngram_hashes(pat, n), bits, k), bits)


def candidate_mask(signatures: np.ndarray, pattern, *, n: int = SIG_NGRAM,
                   k: int = SIG_HASHES) -> np.ndarray:
    """Boolean ``(N,)`` mask: which rows of a ``(N, words)`` signature
    column *may* contain ``pattern`` (exact for "cannot contain")."""
    required = pattern_bits(pattern, bits=signatures.shape[1] * 64, n=n, k=k)
    if required is None:  # short pattern: every record is a candidate
        return np.ones(signatures.shape[0], dtype=bool)
    return ((signatures & required[None, :]) == required[None, :]).all(axis=1)
