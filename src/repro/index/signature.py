"""Per-record n-gram signatures: the query engine's decompress-avoidance
pre-filter (DESIGN.md §7).

At index time every record's content block is folded into a small
Bloom-style bitmap: each overlapping byte n-gram is hashed to ``k`` bit
positions which are set in an ``m``-bit signature. At query time a
pattern of length ≥ n is folded the same way; any record whose signature
is missing one of the pattern's bits **cannot** contain the pattern
(every substring occurrence implies all of its n-grams occur), so the
record is never even decompressed. False positives only cost a wasted
decompress + scan — correctness never depends on the filter.

Everything is vectorized: signatures are built with one rolling-hash
sweep per record (numpy, no per-byte Python), and candidate selection is
a single ``(N, words)`` bitwise-AND/compare over the whole index column.
"""
from __future__ import annotations

import numpy as np

from repro.kernels.bucketing import as_u8

__all__ = [
    "SIG_BITS",
    "SIG_HASHES",
    "SIG_NGRAM",
    "SIG_WORDS",
    "candidate_mask",
    "pattern_bits",
    "signature_of",
]

SIG_BITS = 4096     # bitmap size m (bits): 512 B per record. Sized for the
                    # few-KiB records web archives actually hold — a few
                    # hundred distinct n-grams per record keeps fill ~15 %,
                    # so a 10-byte pattern's ~14 required bits reject
                    # non-matching records with high probability. (At 256
                    # bits the map saturates and filters nothing.)
SIG_WORDS = SIG_BITS // 64
SIG_NGRAM = 4       # n-gram length; patterns shorter than this skip the filter
SIG_HASHES = 2      # k bit positions per n-gram (Kirsch–Mitzenmacher)

_FNV_PRIME = np.uint32(0x01000193)
_MIX = np.uint32(0x9E3779B1)


def _ngram_hashes(buf: np.ndarray, n: int) -> np.ndarray:
    """uint32 polynomial hash of every overlapping n-gram (one sweep)."""
    m = buf.size - n + 1
    h = np.zeros(m, dtype=np.uint32)
    for j in range(n):  # unrolled over the (tiny, static) n-gram length
        h = h * _FNV_PRIME + buf[j:j + m].astype(np.uint32)
    return h


def _bit_positions(h: np.ndarray, bits: int, k: int) -> np.ndarray:
    """k derived bit indices per hash, flattened (double hashing)."""
    h2 = (h ^ (h >> np.uint32(15))) * _MIX
    idx = (h[None, :] + np.arange(k, dtype=np.uint32)[:, None] * h2[None, :])
    return (idx % np.uint32(bits)).ravel()


def _fold(positions: np.ndarray, bits: int) -> np.ndarray:
    sig = np.zeros(bits // 64, dtype=np.uint64)
    words = (positions >> np.uint32(6)).astype(np.intp)
    shifts = (positions & np.uint32(63)).astype(np.uint64)
    np.bitwise_or.at(sig, words, np.uint64(1) << shifts)
    return sig


def signature_of(data, *, bits: int = SIG_BITS, n: int = SIG_NGRAM,
                 k: int = SIG_HASHES) -> np.ndarray:
    """``(bits // 64,)`` uint64 signature of one record's content bytes."""
    buf = as_u8(data)
    if buf.size < n:
        return np.zeros(bits // 64, dtype=np.uint64)
    return _fold(_bit_positions(_ngram_hashes(buf, n), bits, k), bits)


def pattern_bits(pattern, *, bits: int = SIG_BITS, n: int = SIG_NGRAM,
                 k: int = SIG_HASHES) -> np.ndarray | None:
    """Required-bits mask for a query pattern, or ``None`` when the
    pattern is shorter than the n-gram length (filter inapplicable)."""
    pat = as_u8(pattern)
    if pat.size < n:
        return None
    return _fold(_bit_positions(_ngram_hashes(pat, n), bits, k), bits)


def candidate_mask(signatures: np.ndarray, pattern, *, n: int = SIG_NGRAM,
                   k: int = SIG_HASHES) -> np.ndarray:
    """Boolean ``(N,)`` mask: which rows of a ``(N, words)`` signature
    column *may* contain ``pattern`` (exact for "cannot contain")."""
    required = pattern_bits(pattern, bits=signatures.shape[1] * 64, n=n, k=k)
    if required is None:  # short pattern: every record is a candidate
        return np.ones(signatures.shape[0], dtype=bool)
    return ((signatures & required[None, :]) == required[None, :]).all(axis=1)
