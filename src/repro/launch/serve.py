"""Serving driver: restore a checkpoint and serve batched requests.

CLI counterpart of ``launch/train.py`` for the serving side — the same
``ServeEngine``/``decode_step`` the dry-run lowers at 32k-cache scale.

Usage::

    PYTHONPATH=src python -m repro.launch.serve --ckpt-dir /path/ckpts \
        --arch fastwarc_lm [--reduced] --prompt "the web " --prompt "..."
"""
from __future__ import annotations

import argparse

import jax

from repro.configs import get_spec
from repro.models import transformer as tf_mod
from repro.serve.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.step import init_train_state


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fastwarc_lm")
    ap.add_argument("--ckpt-dir", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--prompt", action="append", default=[])
    ap.add_argument("--max-new-tokens", type=int, default=64)
    ap.add_argument("--batch-size", type=int, default=4)
    ap.add_argument("--max-seq", type=int, default=512)
    ap.add_argument("--temperature", type=float, default=0.8)
    args = ap.parse_args()

    spec = get_spec(args.arch)
    cfg = spec.reduced if args.reduced else spec.config
    state = init_train_state(
        tf_mod.init_params(jax.random.PRNGKey(0), cfg),
        compact_state=getattr(cfg, "compact_opt_state", False))
    state, extras = ckpt.restore(args.ckpt_dir, state)
    print(f"restored step {extras.get('step', '?')} from {args.ckpt_dir}")

    engine = ServeEngine(cfg, state["params"], batch_size=args.batch_size,
                         max_seq=args.max_seq, temperature=args.temperature)
    prompts = args.prompt or ["the web archive "]
    requests = [Request(p.encode(), max_new_tokens=args.max_new_tokens)
                for p in prompts]
    for r in engine.serve(requests):
        print(f"\n>>> {r.prompt.decode()!r}\n{r.text.decode('utf-8', 'replace')}")
    s = engine.stats
    print(f"\n{s['tokens_generated']} tokens, "
          f"{s['tokens_generated']/max(s['decode_s'],1e-9):.1f} tok/s")


if __name__ == "__main__":
    main()
