"""Cell builder: (architecture × input-shape) → step fn + specs + shardings.

One entry point, :func:`build_cell`, used by

* the smoke tests — ``scale="reduced"`` + real (small) arrays on CPU;
* the dry-run    — ``scale="full"`` + ShapeDtypeStructs + mesh shardings;
* the drivers    — ``examples/`` and ``launch/train.py``.

The returned ``Cell`` carries everything needed to ``jax.jit(...).lower()``
the step for a mesh without allocating a single real parameter.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ArchSpec, ShapeSpec
from repro.models import gnn as gnn_mod
from repro.models import recsys as rec_mod
from repro.models import transformer as tf_mod
from repro.models.common import cross_entropy_loss
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step

from . import sharding as sh
from .mesh import batch_axes

S = jax.ShapeDtypeStruct


@dataclass
class Cell:
    arch_id: str
    shape_name: str
    kind: str
    step: Callable                     # step(*args)
    args_shapes: tuple                 # pytrees of ShapeDtypeStruct
    in_shardings: tuple | None = None
    out_shardings: Any = None
    make_inputs: Callable | None = None  # seed -> real args (smoke scale)
    notes: str = ""


def _reduced_dims(shape: ShapeSpec) -> dict:
    """Shrink the shape params to CPU-smoke scale."""
    p = dict(shape.params)
    scaled = {
        "seq_len": min(p.get("seq_len", 128), 128),
        "global_batch": min(p.get("global_batch", 4), 4),
        "batch": min(p.get("batch", 4), 4),
        "n_candidates": min(p.get("n_candidates", 64), 64),
        "n_nodes": min(p.get("n_nodes", 64), 64),
        "n_edges": min(p.get("n_edges", 256), 256),
        "batch_nodes": min(p.get("batch_nodes", 8), 8),
        "fanouts": [2, 2] if "fanouts" in p else None,
        "d_feat": min(p.get("d_feat", 12), 12),
        "n_classes": min(p.get("n_classes", 4), 4),
    }
    p.update({k: v for k, v in scaled.items() if k in p})
    return p


def build_cell(spec: ArchSpec, shape_name: str, mesh=None,
               scale: str = "full", cfg_override=None) -> Cell:
    shape = spec.shape(shape_name)
    if shape.skip_reason is not None and scale == "full":
        raise ValueError(
            f"{spec.arch_id}/{shape_name} is skipped: {shape.skip_reason}")
    cfg = cfg_override if cfg_override is not None else (
        spec.config if scale == "full" else spec.reduced)
    dims = dict(shape.params) if scale == "full" else _reduced_dims(shape)
    if spec.family == "lm":
        return _lm_cell(spec, shape, cfg, dims, mesh)
    if spec.family == "gnn":
        return _gnn_cell(spec, shape, cfg, dims, mesh, scale)
    if spec.family == "recsys":
        return _recsys_cell(spec, shape, cfg, dims, mesh)
    raise ValueError(spec.family)


# --------------------------------------------------------------------------
# LM cells
# --------------------------------------------------------------------------

def _lm_state_shapes(cfg) -> Any:
    return jax.eval_shape(
        lambda: init_train_state(
            tf_mod.init_params(jax.random.PRNGKey(0), cfg),
            compact_state=getattr(cfg, "compact_opt_state", False)))


def _lm_cell(spec, shape, cfg, dims, mesh) -> Cell:
    B = dims.get("global_batch", dims.get("batch", 2))
    L = dims["seq_len"]

    if shape.kind == "train":
        compact = getattr(cfg, "compact_opt_state", False)
        opt = AdamWConfig(total_steps=10_000, compact_state=compact)

        def loss(params, batch):
            return tf_mod.loss_fn(params, batch["tokens"], batch["labels"],
                                  cfg)
        step = make_train_step(
            loss, opt, n_microbatches=getattr(cfg, "train_microbatches", 1),
            accum_dtype=getattr(cfg, "grad_accum_dtype", "float32"))
        state_shapes = _lm_state_shapes(cfg)
        batch_shapes = {"tokens": S((B, L), jnp.int32),
                        "labels": S((B, L), jnp.int32)}
        in_sh = out_sh = None
        if mesh is not None:
            st_sh = sh.lm_state_shardings(mesh, state_shapes)
            bt = sh.lm_batch_sharding(mesh)
            in_sh = (st_sh, {"tokens": bt, "labels": bt})
            out_sh = (st_sh, {"loss": NamedSharding(mesh, P()),
                              "lr": NamedSharding(mesh, P()),
                              "grad_norm": NamedSharding(mesh, P())})

        def make_inputs(seed=0):
            rng = np.random.default_rng(seed)
            params = tf_mod.init_params(jax.random.PRNGKey(seed), cfg)
            state = init_train_state(
                params, compact_state=getattr(cfg, "compact_opt_state", False))
            toks = rng.integers(0, cfg.vocab, (B, L)).astype(np.int32)
            return (state, {"tokens": jnp.asarray(toks),
                            "labels": jnp.asarray(toks)})

        return Cell(spec.arch_id, shape.name, shape.kind, step,
                    (state_shapes, batch_shapes), in_sh, out_sh, make_inputs)

    if shape.kind == "prefill":
        def step(params, tokens):
            logits, _ = tf_mod.forward(params, tokens, cfg)
            return logits
        params_shapes = tf_mod.param_shapes(cfg)
        batch_shapes = S((B, L), jnp.int32)
        in_sh = None
        if mesh is not None:
            rule = sh.lm_param_rule(mesh)
            in_sh = (sh._spec_tree(mesh, params_shapes, rule),
                     sh.lm_batch_sharding(mesh))

        def make_inputs(seed=0):
            rng = np.random.default_rng(seed)
            params = tf_mod.init_params(jax.random.PRNGKey(seed), cfg)
            return (params,
                    jnp.asarray(rng.integers(0, cfg.vocab, (B, L)), jnp.int32))

        return Cell(spec.arch_id, shape.name, shape.kind, step,
                    (params_shapes, batch_shapes), in_sh, None, make_inputs)

    # decode: one token against a seq_len KV cache
    def step(params, cache, token):
        return tf_mod.decode_step(params, cache, token, cfg)
    params_shapes = tf_mod.param_shapes(cfg)
    cache_shapes = jax.eval_shape(
        lambda: tf_mod.init_cache(cfg, B, L))
    token_shapes = S((B,), jnp.int32)
    in_sh = out_sh = None
    if mesh is not None:
        # decode weights: TP-only when that fits in HBM — 2D (FSDP)
        # sharding makes every one-token step all-gather the weight
        # shards, which dominated the baseline decode roofline
        tp_param_bytes = 2 * cfg.param_count() / mesh.shape["model"]
        tp_fits = tp_param_bytes < 8 * 2**30
        rule = sh.lm_param_rule(mesh, fsdp=() if tp_fits else None)
        p_sh = sh._spec_tree(mesh, params_shapes, rule)
        c_sh = sh.lm_cache_shardings(mesh)
        t_sh = NamedSharding(mesh, P(batch_axes(mesh)))
        in_sh = (p_sh, c_sh, t_sh)
        out_sh = (NamedSharding(mesh, P(batch_axes(mesh), "model")), c_sh)

    def make_inputs(seed=0):
        params = tf_mod.init_params(jax.random.PRNGKey(seed), cfg)
        cache = tf_mod.init_cache(cfg, B, L, dtype=cfg.jnp_dtype)
        token = jnp.zeros((B,), jnp.int32)
        return (params, cache, token)

    return Cell(spec.arch_id, shape.name, shape.kind, step,
                (params_shapes, cache_shapes, token_shapes),
                in_sh, out_sh, make_inputs)


# --------------------------------------------------------------------------
# GNN cells
# --------------------------------------------------------------------------

def _gnn_cfg_for(cfg, dims):
    import dataclasses
    # replace, don't reconstruct: reconstruction silently drops any field
    # not listed (remat_group went missing that way once)
    return dataclasses.replace(
        cfg, d_feat=dims.get("d_feat", cfg.d_feat),
        n_classes=dims.get("n_classes", cfg.n_classes))


def _gnn_cell(spec, shape, cfg, dims, mesh, scale="full") -> Cell:
    from repro.data.graph import subgraph_max_edges, subgraph_max_nodes
    cfg = _gnn_cfg_for(cfg, dims)
    opt = AdamWConfig(total_steps=10_000, weight_decay=0.0)

    if shape.kind in ("full_graph", "minibatch"):
        if shape.kind == "full_graph":
            # pad node/edge extents to a 512 multiple: jit input shardings
            # require exact divisibility by the batch-axis extent (padding
            # nodes are masked; padding edges self-loop on a padding node)
            N_real, E_real = dims["n_nodes"], dims["n_edges"]
            N = -(-N_real // 512) * 512 if scale == "full" else N_real
            E = -(-E_real // 512) * 512 if scale == "full" else E_real
            masked = N != N_real or E != E_real
        else:
            seeds, fanouts = dims["batch_nodes"], dims["fanouts"]
            N = subgraph_max_nodes(seeds, fanouts)
            E = subgraph_max_edges(seeds, fanouts)
            N_real, E_real = N, E
            masked = True

        def loss(params, batch):
            return gnn_mod.loss_fn(
                params, batch["node_feats"], batch["edge_src"],
                batch["edge_dst"], batch["labels"], cfg,
                label_mask=batch.get("label_mask"),
                node_mask=batch.get("node_mask"))
        step = make_train_step(loss, opt)
        batch_shapes = {
            "node_feats": S((N, cfg.d_feat), jnp.float32),
            "edge_src": S((E,), jnp.int32),
            "edge_dst": S((E,), jnp.int32),
            "labels": S((N,), jnp.int32),
        }
        if masked:
            batch_shapes["node_mask"] = S((N,), jnp.float32)
            batch_shapes["label_mask"] = S((N,), jnp.float32)
        state_shapes = jax.eval_shape(
            lambda: init_train_state(
                gnn_mod.init_params(jax.random.PRNGKey(0), cfg)))
        in_sh = out_sh = None
        if mesh is not None:
            st = sh.gnn_state_shardings(mesh, state_shapes)
            in_sh = (st, sh.gnn_batch_shardings(mesh, batch_shapes))
            out_sh = (st, sh.replicated(
                mesh, {"loss": S((), jnp.float32), "lr": S((), jnp.float32),
                       "grad_norm": S((), jnp.float32)}))

        def make_inputs(seed=0):
            rng = np.random.default_rng(seed)
            params = gnn_mod.init_params(jax.random.PRNGKey(seed), cfg)
            src = rng.integers(0, N_real, E).astype(np.int32)
            dst = rng.integers(0, N_real, E).astype(np.int32)
            if E > E_real:  # padding edges self-loop on a padding node
                pad_node = min(N_real, N - 1)
                src[E_real:] = pad_node
                dst[E_real:] = pad_node
            batch = {
                "node_feats": jnp.asarray(
                    rng.normal(size=(N, cfg.d_feat)), jnp.float32),
                "edge_src": jnp.asarray(src),
                "edge_dst": jnp.asarray(dst),
                "labels": jnp.asarray(
                    rng.integers(0, cfg.n_classes, N), jnp.int32),
            }
            if masked:
                nm = np.zeros((N,), np.float32)
                nm[:N_real] = 1.0
                batch["node_mask"] = jnp.asarray(nm)
                batch["label_mask"] = jnp.asarray(nm)
            return (init_train_state(params), batch)

        return Cell(spec.arch_id, shape.name, shape.kind, step,
                    (state_shapes, batch_shapes), in_sh, out_sh, make_inputs)

    # molecule: batched small graphs, graph-level regression (MSE)
    G = dims["batch"]
    N = dims["n_nodes"] * G
    E = dims["n_edges"] * G

    def loss(params, batch):
        pred = gnn_mod.forward_pooled(
            params, batch["node_feats"], batch["edge_src"],
            batch["edge_dst"], batch["graph_ids"], G, cfg)[:, 0]
        return jnp.mean((pred - batch["targets"]) ** 2)
    step = make_train_step(loss, opt)
    batch_shapes = {
        "node_feats": S((N, cfg.d_feat), jnp.float32),
        "edge_src": S((E,), jnp.int32),
        "edge_dst": S((E,), jnp.int32),
        "graph_ids": S((N,), jnp.int32),
        "targets": S((G,), jnp.float32),
    }
    state_shapes = jax.eval_shape(
        lambda: init_train_state(
            gnn_mod.init_params(jax.random.PRNGKey(0), cfg)))
    in_sh = out_sh = None
    if mesh is not None:
        st = sh.gnn_state_shardings(mesh, state_shapes)
        in_sh = (st, sh.gnn_batch_shardings(mesh, batch_shapes))
        out_sh = (st, sh.replicated(
            mesh, {"loss": S((), jnp.float32), "lr": S((), jnp.float32),
                   "grad_norm": S((), jnp.float32)}))

    def make_inputs(seed=0):
        rng = np.random.default_rng(seed)
        params = gnn_mod.init_params(jax.random.PRNGKey(seed), cfg)
        n_per, e_per = dims["n_nodes"], dims["n_edges"]
        src = (rng.integers(0, n_per, E)
               + np.repeat(np.arange(G), e_per) * n_per)
        dst = (rng.integers(0, n_per, E)
               + np.repeat(np.arange(G), e_per) * n_per)
        batch = {
            "node_feats": jnp.asarray(
                rng.normal(size=(N, cfg.d_feat)), jnp.float32),
            "edge_src": jnp.asarray(src, jnp.int32),
            "edge_dst": jnp.asarray(dst, jnp.int32),
            "graph_ids": jnp.asarray(
                np.repeat(np.arange(G), n_per), jnp.int32),
            "targets": jnp.asarray(rng.normal(size=(G,)), jnp.float32),
        }
        return (init_train_state(params), batch)

    return Cell(spec.arch_id, shape.name, shape.kind, step,
                (state_shapes, batch_shapes), in_sh, out_sh, make_inputs)


# --------------------------------------------------------------------------
# RecSys cells
# --------------------------------------------------------------------------

def _recsys_forward(cfg):
    kind = cfg.kind

    def fwd(params, batch):
        if kind == "dcn_v2":
            return rec_mod.dcn_forward(params, batch["dense_feats"],
                                       batch["sparse_ids"], cfg)
        if kind == "autoint":
            return rec_mod.autoint_forward(params, batch["sparse_ids"], cfg)
        fn = rec_mod.din_forward if kind == "din" else rec_mod.dien_forward
        return fn(params, batch["profile_ids"], batch["hist_items"],
                  batch["hist_cates"], batch["hist_mask"],
                  batch["target_item"], batch["target_cate"], cfg)
    return fwd


def _recsys_init(cfg):
    return {"dcn_v2": rec_mod.dcn_init, "din": rec_mod.din_init,
            "dien": rec_mod.dien_init,
            "autoint": rec_mod.autoint_init}[cfg.kind]


def _recsys_batch_shapes(cfg, B) -> dict:
    if cfg.kind in ("dcn_v2", "autoint"):
        shapes = {"sparse_ids": S((B, cfg.n_sparse), jnp.int32)}
        if cfg.kind == "dcn_v2":
            shapes["dense_feats"] = S((B, cfg.n_dense), jnp.float32)
    else:
        L = cfg.seq_len
        shapes = {
            "profile_ids": S((B, cfg.n_profile_fields), jnp.int32),
            "hist_items": S((B, L), jnp.int32),
            "hist_cates": S((B, L), jnp.int32),
            "hist_mask": S((B, L), jnp.float32),
            "target_item": S((B,), jnp.int32),
            "target_cate": S((B,), jnp.int32),
        }
    return shapes


def _recsys_cell(spec, shape, cfg, dims, mesh) -> Cell:
    from repro.data.recsys import make_batch, make_candidates
    fwd = _recsys_forward(cfg)
    init = _recsys_init(cfg)
    B = dims["batch"]

    if shape.kind == "train":
        opt = AdamWConfig(total_steps=100_000, weight_decay=0.0, lr=1e-3)

        def loss(params, batch):
            return rec_mod.bce_loss(fwd(params, batch), batch["labels"])
        step = make_train_step(loss, opt)
        batch_shapes = {**_recsys_batch_shapes(cfg, B),
                        "labels": S((B,), jnp.float32)}
        state_shapes = jax.eval_shape(
            lambda: init_train_state(init(jax.random.PRNGKey(0), cfg)))
        in_sh = out_sh = None
        if mesh is not None:
            st = sh.recsys_state_shardings(mesh, state_shapes)
            in_sh = (st, sh.recsys_batch_shardings(mesh, batch_shapes))
            out_sh = (st, sh.replicated(
                mesh, {"loss": S((), jnp.float32), "lr": S((), jnp.float32),
                       "grad_norm": S((), jnp.float32)}))

        def make_inputs(seed=0):
            params = init(jax.random.PRNGKey(seed), cfg)
            batch = {k: jnp.asarray(v)
                     for k, v in make_batch(cfg, B, seed).items()}
            return (init_train_state(params), batch)

        return Cell(spec.arch_id, shape.name, shape.kind, step,
                    (state_shapes, batch_shapes), in_sh, out_sh, make_inputs)

    if shape.kind == "serve":
        def step(params, batch):
            return jax.nn.sigmoid(fwd(params, batch))
        batch_shapes = _recsys_batch_shapes(cfg, B)
        params_shapes = jax.eval_shape(
            lambda: init(jax.random.PRNGKey(0), cfg))
        in_sh = None
        if mesh is not None:
            rule = sh.recsys_param_rule(mesh)
            in_sh = (sh._spec_tree(mesh, params_shapes, rule),
                     sh.recsys_batch_shardings(mesh, batch_shapes))

        def make_inputs(seed=0):
            params = init(jax.random.PRNGKey(seed), cfg)
            b = make_batch(cfg, B, seed)
            b.pop("labels")
            return (params, {k: jnp.asarray(v) for k, v in b.items()})

        return Cell(spec.arch_id, shape.name, shape.kind, step,
                    (params_shapes, batch_shapes), in_sh, None, make_inputs)

    # retrieval: 1 query vs n_candidates via the two-tower path
    N = dims["n_candidates"]

    top_k = min(100, N)

    def step(params, batch, cand_ids):
        if cfg.kind in ("dcn_v2", "autoint"):
            uv = rec_mod.user_tower(params, cfg, batch["sparse_ids"])[0]
        else:
            uv = rec_mod.user_tower(params, cfg, batch["hist_items"],
                                    batch["hist_cates"], batch["hist_mask"])[0]
        return rec_mod.retrieval_scores(params, uv, cand_ids, cfg,
                                        top_k=top_k)

    batch_shapes = _recsys_batch_shapes(cfg, 1)
    cand_shapes = S((N,), jnp.int32)
    params_shapes = jax.eval_shape(lambda: init(jax.random.PRNGKey(0), cfg))
    in_sh = None
    if mesh is not None:
        rule = sh.recsys_param_rule(mesh)
        # the single query is replicated (B=1 cannot shard); the 10⁶
        # candidates carry the parallelism over the batch axes
        in_sh = (sh._spec_tree(mesh, params_shapes, rule),
                 sh.replicated(mesh, batch_shapes),
                 NamedSharding(mesh, P(batch_axes(mesh))))

    def make_inputs(seed=0):
        params = init(jax.random.PRNGKey(seed), cfg)
        b = make_batch(cfg, 1, seed)
        b.pop("labels")
        return (params, {k: jnp.asarray(v) for k, v in b.items()},
                jnp.asarray(make_candidates(cfg, N, seed)))

    return Cell(spec.arch_id, shape.name, shape.kind, step,
                (params_shapes, batch_shapes, cand_shapes), in_sh, None,
                make_inputs)
