"""Sharding rules: param-tree paths → PartitionSpecs, per model family.

Baseline distribution scheme (hillclimbed in EXPERIMENTS.md §Perf):

* **LM** — 2D weight sharding: tensor-parallel over ``model`` on the
  head/ffn/vocab dim *and* FSDP over the data-like axes on the other dim,
  so a 235B-param state (params bf16 + Adam m/v fp32 ≈ 2.35 TB) divides by
  all 256/512 chips, not just the 16-way model axis. Optimizer state
  inherits the param specs (ZeRO falls out for free).
* **GNN** — params replicated (tiny); node/edge tensors sharded over the
  batch axes.
* **RecSys** — embedding tables row-sharded over ``model`` (they dominate
  memory); interaction/MLP weights replicated; batch over data axes.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from .mesh import batch_axes, fsdp_axes


def _spec_tree(mesh: Mesh, tree, rule):
    """Map ``rule(path_str, leaf) -> PartitionSpec`` over a shape tree."""
    def one(path, leaf):
        spec = rule(jax.tree_util.keystr(path), leaf)
        return NamedSharding(mesh, spec)
    return jax.tree_util.tree_map_with_path(one, tree)


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    n = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        n *= mesh.shape[a]
    return dim % n == 0


# --------------------------------------------------------------------------
# LM rules
# --------------------------------------------------------------------------

def lm_param_rule(mesh: Mesh, fsdp: tuple | None = None):
    """``fsdp=()`` disables the second (ZeRO) sharding axis — used by the
    decode path when TP-only params fit in HBM, so one-token steps stop
    paying a full FSDP all-gather per layer (§Perf iteration: decode was
    7000× more collective- than compute-bound with 2D-sharded weights)."""
    fsdp = fsdp_axes(mesh) if fsdp is None else (fsdp or None)

    def fit(axes, dim: int):
        """Drop an axis set that doesn't divide ``dim`` — keeps the rules
        valid on shrunken (elastic) meshes with non-power-of-2 extents."""
        if axes is None or not _divisible(dim, mesh, axes):
            return None
        return axes

    def rule(path: str, leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if "embed" in path:                      # [V, d]
            return P(fit("model", shape[0]), fit(fsdp, shape[1]))
        if "lm_head" in path and nd == 2:        # [d, V]
            return P(fit(fsdp, shape[0]), fit("model", shape[1]))
        if "layers" in path:
            # stacked leaves: leading L axis never sharded
            if "moe" in path:
                if "router" in path:
                    return P(*([None] * nd))     # [L, d, E] small, replicated
                if nd == 4:                      # experts [L, E, d, f]
                    return P(None, fit("model", shape[1]),
                             fit(fsdp, shape[2]), None)
            if ("wq" in path or "wk" in path or "wv" in path) and nd == 3:
                return P(None, fit(fsdp, shape[1]),
                         fit("model", shape[2]))  # [L, d, H*dh]
            if "wo" in path and nd == 3:
                return P(None, fit("model", shape[1]),
                         fit(fsdp, shape[2]))     # [L, H*dh, d]
            if ("gate" in path or "up" in path) and nd == 3:
                return P(None, fit(fsdp, shape[1]),
                         fit("model", shape[2]))  # [L, d, ff]
            if "down" in path and nd == 3:
                return P(None, fit("model", shape[1]),
                         fit(fsdp, shape[2]))     # [L, ff, d]
            if nd == 2 and shape[-1] > 1024:     # stacked biases [L, H*dh]
                return P(None, fit("model", shape[1]))
        return P(*([None] * nd))                 # norms, small biases

    return rule


def lm_state_shardings(mesh: Mesh, state_shapes) -> dict:
    """Shardings for a full train state {params, opt{m,v,step}, ...}."""
    rule = lm_param_rule(mesh)
    out = {"params": _spec_tree(mesh, state_shapes["params"], rule)}
    if "opt" in state_shapes:
        opt = state_shapes["opt"]
        if "m_q" in opt:  # compact (8-bit) optimizer state
            out["opt"] = {
                "m_q": _spec_tree(mesh, opt["m_q"], rule),
                "m_scale": replicated(mesh, opt["m_scale"]),
                "v": _spec_tree(mesh, opt["v"], rule),
                "step": NamedSharding(mesh, P()),
            }
        else:
            out["opt"] = {
                "m": _spec_tree(mesh, opt["m"], rule),
                "v": _spec_tree(mesh, opt["v"], rule),
                "step": NamedSharding(mesh, P()),
            }
    if "ef_error" in state_shapes:
        out["ef_error"] = _spec_tree(mesh, state_shapes["ef_error"], rule)
    return out


def lm_batch_sharding(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P(batch_axes(mesh), None))


def lm_cache_shardings(mesh: Mesh) -> dict:
    """KV cache [L, B, Hkv, S, D]: batch over data axes, sequence over
    ``model`` (FlashDecoding-style split-KV — the kv-head extent (4–8) is
    smaller than the 16-way model axis, the sequence is not)."""
    b = batch_axes(mesh)
    return {
        "k": NamedSharding(mesh, P(None, b, None, "model", None)),
        "v": NamedSharding(mesh, P(None, b, None, "model", None)),
        "length": NamedSharding(mesh, P()),
    }


# --------------------------------------------------------------------------
# GNN rules
# --------------------------------------------------------------------------

def gnn_param_rule(mesh: Mesh):
    def rule(path: str, leaf) -> P:
        return P(*([None] * len(leaf.shape)))    # ~1M params: replicate
    return rule


def gnn_state_shardings(mesh: Mesh, state_shapes) -> dict:
    rule = gnn_param_rule(mesh)
    return {
        "params": _spec_tree(mesh, state_shapes["params"], rule),
        "opt": {
            "m": _spec_tree(mesh, state_shapes["opt"]["m"], rule),
            "v": _spec_tree(mesh, state_shapes["opt"]["v"], rule),
            "step": NamedSharding(mesh, P()),
        },
    }


def gnn_batch_shardings(mesh: Mesh, batch_shapes) -> dict:
    """Node/edge arrays sharded on their leading (node/edge) dim over ALL
    mesh axes — GNN params are replicated, so the model axis is otherwise
    idle; 256-way edge sharding cut ogbn-products' memory term 16×
    (§Perf iteration). Leaves whose leading dim doesn't divide the full
    extent fall back to the longest axis prefix that does (small graph-
    level arrays like per-graph targets end up data-only or replicated)."""
    all_axes = tuple(mesh.axis_names)

    def one(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        axes = all_axes
        while axes and not _divisible(leaf.shape[0], mesh, axes):
            axes = axes[:-1]
        spec = axes if axes else None
        return NamedSharding(mesh, P(spec, *([None] * (nd - 1))))
    return jax.tree_util.tree_map_with_path(one, batch_shapes)


# --------------------------------------------------------------------------
# RecSys rules
# --------------------------------------------------------------------------

def recsys_param_rule(mesh: Mesh):
    def rule(path: str, leaf) -> P:
        shape = leaf.shape
        nd = len(shape)
        if ("table" in path or "tables" in path) and nd == 2 \
                and shape[0] >= 4096:
            return P("model", None)              # row-sharded big tables
        return P(*([None] * nd))
    return rule


def recsys_state_shardings(mesh: Mesh, state_shapes) -> dict:
    rule = recsys_param_rule(mesh)
    return {
        "params": _spec_tree(mesh, state_shapes["params"], rule),
        "opt": {
            "m": _spec_tree(mesh, state_shapes["opt"]["m"], rule),
            "v": _spec_tree(mesh, state_shapes["opt"]["v"], rule),
            "step": NamedSharding(mesh, P()),
        },
    }


def recsys_batch_shardings(mesh: Mesh, batch_shapes) -> dict:
    b = batch_axes(mesh)

    def one(path, leaf):
        nd = len(leaf.shape)
        if nd == 0:
            return NamedSharding(mesh, P())
        return NamedSharding(mesh, P(b, *([None] * (nd - 1))))
    return jax.tree_util.tree_map_with_path(one, batch_shapes)


def replicated(mesh: Mesh, tree):
    return jax.tree.map(
        lambda leaf: NamedSharding(mesh, P(*([None] * len(leaf.shape)))),
        tree)
