"""Production mesh definitions (TPU v5e-256 pods).

Functions, not module-level constants — importing this module must never
touch jax device state (smoke tests see 1 CPU device; only dryrun.py
forces 512 host devices, in its first two lines).
"""
from __future__ import annotations

import jax

#: hardware constants used by the roofline analysis (TPU v5e)
PEAK_FLOPS_BF16 = 197e12      # per chip
HBM_BW = 819e9                # bytes/s per chip
ICI_BW = 50e9                 # bytes/s per link (~4 links usable per chip)
HBM_BYTES = 16 * 2**30        # 16 GiB per chip
DCI_BW = 25e9                 # inter-pod (data-center) per-host share, est.


def make_production_mesh(*, multi_pod: bool = False) -> jax.sharding.Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def batch_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes the global batch is sharded over."""
    return (("pod", "data") if "pod" in mesh.axis_names else ("data",))


def fsdp_axes(mesh: jax.sharding.Mesh) -> tuple[str, ...]:
    """Axes large weight matrices are additionally sharded over (ZeRO-3
    style): the data-parallel extent doubles as the FSDP extent."""
    return batch_axes(mesh)


def n_chips(mesh: jax.sharding.Mesh) -> int:
    return mesh.devices.size
