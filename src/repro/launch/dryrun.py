import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × shape) on the
production meshes and record memory/cost/roofline artifacts.

The two lines above MUST stay the first statements in this module — jax
locks the device count at first init, and the production meshes need 512
placeholder host devices. Nothing else in the repo sets this flag (smoke
tests and benchmarks see the 1 real CPU device).

Per cell this runs:
  1. the **proof compile** — the arch's real config (scan-over-layers,
     remat) lowered with its full train/serve state; memory_analysis()
     proves per-device residency, the compile itself proves the sharding
     is coherent on the target mesh;
  2. for LM cells, two **delta compiles** (n_layers = 1 and 2, inner
     scans unrolled) whose difference yields exact per-layer flops/bytes/
     collective counts — XLA's cost analysis counts while-loop bodies
     once, so the full-depth numbers are reconstructed as
     cell(1) + (L−1)·Δ (see repro/roofline/analysis.py);
     GNN/DIEN cells instead unroll their (shallow) scans directly.

Usage::

    PYTHONPATH=src python -m repro.launch.dryrun --arch internlm2_1_8b \
        --shape train_4k [--multi-pod]
    PYTHONPATH=src python -m repro.launch.dryrun --all   # subprocess/cell
"""
import argparse
import dataclasses
import json
import subprocess
import sys
import time

import jax

from repro.configs import all_arch_ids, get_spec
from repro.launch.mesh import HBM_BYTES, make_production_mesh, n_chips
from repro.launch.steps import build_cell
from repro.roofline.analysis import (
    fraction_of_roofline,
    model_flops_decode,
    model_flops_lm,
    raw_counts,
    terms_from_counts,
)

RESULTS_DIR = os.path.normpath(os.path.join(
    os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun"))


def _model_flops(spec, shape) -> float:
    if spec.family != "lm":
        return 0.0
    p = shape.params
    if shape.kind == "train":
        return model_flops_lm(spec.config, p["global_batch"], p["seq_len"],
                              training=True)
    if shape.kind == "prefill":
        return model_flops_lm(spec.config, p["global_batch"], p["seq_len"],
                              training=False)
    return model_flops_decode(spec.config, p["global_batch"])


def _compile_cell(spec, shape_name, mesh, cfg_override=None,
                  donate: bool = True):
    cell = build_cell(spec, shape_name, mesh=mesh, cfg_override=cfg_override)
    donate_argnums = ()
    if donate:
        if cell.kind in ("train", "full_graph", "minibatch", "molecule"):
            donate_argnums = (0,)      # train state is donated
        elif cell.kind == "decode":
            donate_argnums = (1,)      # KV cache is donated
    with mesh:
        jitted = jax.jit(cell.step, in_shardings=cell.in_shardings,
                         out_shardings=cell.out_shardings,
                         donate_argnums=donate_argnums)
        lowered = jitted.lower(*cell.args_shapes)
        compiled = lowered.compile()
    return cell, compiled


def run_cell(arch_id: str, shape_name: str, multi_pod: bool) -> dict:
    spec = get_spec(arch_id)
    shape = spec.shape(shape_name)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    record = {"arch": arch_id, "shape": shape_name, "mesh": mesh_name,
              "kind": shape.kind}
    if shape.skip_reason is not None:
        record["status"] = "skipped"
        record["skip_reason"] = shape.skip_reason
        return record

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = n_chips(mesh)

    # ---- proof compile: the REAL config (memory/compile evidence) -----
    t0 = time.time()
    _, compiled = _compile_cell(spec, shape_name, mesh)
    record["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    record["memory_analysis"] = {
        "argument_size": getattr(mem, "argument_size_in_bytes", None),
        "output_size": getattr(mem, "output_size_in_bytes", None),
        "temp_size": getattr(mem, "temp_size_in_bytes", None),
        "alias_size": getattr(mem, "alias_size_in_bytes", None),
    }
    ma = record["memory_analysis"]
    # donated state aliases outputs; arguments + temps bound residency
    per_dev = (ma["argument_size"] or 0) + (ma["temp_size"] or 0)
    record["per_device_bytes"] = per_dev
    record["fits_hbm"] = bool(per_dev <= HBM_BYTES)

    # ---- roofline counts (loop-corrected; separate compiles) -----------
    if spec.family == "lm":
        # microbatch grad-accumulation is a scan too (counted once):
        # deltas run at mb=1 — total per-step flops/bytes are unchanged
        L = spec.config.n_layers
        common = dict(attn_unroll=True, layers_unroll=True,
                      train_microbatches=1)
        cfg1 = dataclasses.replace(spec.config, n_layers=1, **common)
        cfg2 = dataclasses.replace(spec.config, n_layers=2, **common)
        t1 = time.time()
        _, c1 = _compile_cell(spec, shape_name, mesh, cfg_override=cfg1)
        _, c2 = _compile_cell(spec, shape_name, mesh, cfg_override=cfg2)
        record["delta_compile_s"] = round(time.time() - t1, 1)
        r1, r2 = raw_counts(c1), raw_counts(c2)
        counts = r1.scaled_add(r2 - r1, L - 1)
        record["loop_correction"] = "delta(n_layers 1→2, mb=1)"
    elif spec.family == "gnn" or (spec.family == "recsys"
                                  and spec.config.kind == "dien"):
        unrolled_cfg = dataclasses.replace(spec.config, scan_unroll=True)
        t1 = time.time()
        _, c_unrolled = _compile_cell(spec, shape_name, mesh,
                                      cfg_override=unrolled_cfg)
        record["delta_compile_s"] = round(time.time() - t1, 1)
        counts = raw_counts(c_unrolled)
        record["loop_correction"] = "unrolled scans (counts compile)"
    else:
        counts = raw_counts(compiled)
        record["loop_correction"] = "no loops"

    terms = terms_from_counts(
        counts, arch=arch_id, shape=shape_name, mesh_name=mesh_name,
        chips=chips, model_flops=_model_flops(spec, shape))
    record["roofline"] = terms.to_dict()
    record["roofline"]["fraction_dominant"] = fraction_of_roofline(terms)
    record["status"] = "ok"
    return record


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--only-missing", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    if not args.all:
        record = run_cell(args.arch, args.shape, args.multi_pod)
        print(json.dumps(record, indent=2))
        if args.out:
            with open(args.out, "w") as f:
                json.dump(record, f)
        return

    os.makedirs(RESULTS_DIR, exist_ok=True)
    merged = []
    for arch_id in all_arch_ids(include_paper=False):
        spec = get_spec(arch_id)
        for shape in spec.shapes:
            for multi_pod in (False, True):
                mesh_name = "2x16x16" if multi_pod else "16x16"
                out_path = os.path.join(
                    RESULTS_DIR, f"{arch_id}__{shape.name}__{mesh_name}.json")
                if os.path.exists(out_path):
                    with open(out_path) as f:
                        prev = json.load(f)
                    if prev.get("status") != "error" or args.only_missing:
                        merged.append(prev)
                        continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch_id, "--shape", shape.name,
                       "--out", out_path]
                if multi_pod:
                    cmd.append("--multi-pod")
                print(f">>> {arch_id}/{shape.name}/{mesh_name}", flush=True)
                t0 = time.time()
                proc = subprocess.run(
                    cmd, capture_output=True, text=True,
                    env={**os.environ, "PYTHONPATH": "src"})
                if proc.returncode != 0 or not os.path.exists(out_path):
                    record = {"arch": arch_id, "shape": shape.name,
                              "mesh": mesh_name, "status": "error",
                              "error": proc.stderr[-3000:]}
                    with open(out_path, "w") as f:
                        json.dump(record, f)
                    tail = proc.stderr.splitlines()[-1] if proc.stderr else "?"
                    print(f"    ERROR ({time.time()-t0:.0f}s): {tail}",
                          flush=True)
                else:
                    print(f"    ok ({time.time()-t0:.0f}s)", flush=True)
                with open(out_path) as f:
                    merged.append(json.load(f))
    with open(os.path.join(RESULTS_DIR, "..", "dryrun_results.json"),
              "w") as f:
        json.dump(merged, f, indent=1)
    ok = sum(1 for r in merged if r.get("status") == "ok")
    sk = sum(1 for r in merged if r.get("status") == "skipped")
    err = sum(1 for r in merged if r.get("status") == "error")
    print(f"done: {ok} ok, {sk} skipped, {err} errors / {len(merged)} cells")


if __name__ == "__main__":
    main()
