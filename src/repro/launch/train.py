"""Training driver: data pipeline → jitted train step → checkpoints.

The single-host entry point (multi-host launch wraps this per host with
``host_id``/``n_hosts`` and a shared coordinator, exactly as the loader
and checkpoint layers expect). Wires together every substrate:

* WARC ingestion pipeline (``repro.data.loader``) with exact-resume state
  stored inside each checkpoint;
* jitted/donated train step (``repro.launch.steps``);
* async checkpointing every ``ckpt_every`` steps + straggler monitoring
  with preemptive checkpoint on sustained slowdown (``repro.train.elastic``).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_spec
from repro.data.loader import WarcTokenLoader, split_batch
from repro.train import checkpoint as ckpt
from repro.train.elastic import StragglerMonitor
from repro.train.optimizer import AdamWConfig
from repro.train.step import init_train_state, make_train_step
from repro.models import transformer as tf_mod


def train_lm(
    *,
    arch: str = "fastwarc_lm",
    shards: list[str],
    steps: int = 200,
    batch: int = 8,
    seq_len: int = 512,
    lr: float = 3e-4,
    ckpt_dir: str | None = None,
    ckpt_every: int = 50,
    host_id: int = 0,
    n_hosts: int = 1,
    reduced: bool = False,
    log_every: int = 10,
) -> dict:
    spec = get_spec(arch)
    cfg = spec.reduced if reduced else spec.config
    loader = WarcTokenLoader(shards, batch=batch, seq_len=seq_len,
                             host_id=host_id, n_hosts=n_hosts)
    opt_cfg = AdamWConfig(lr=lr, total_steps=steps,
                          warmup_steps=max(steps // 20, 5))

    def loss_fn(params, batch_arrs):
        return tf_mod.loss_fn(params, batch_arrs["tokens"],
                              batch_arrs["labels"], cfg)

    step_fn = jax.jit(make_train_step(loss_fn, opt_cfg), donate_argnums=0)

    start_step = 0
    state = init_train_state(
        tf_mod.init_params(jax.random.PRNGKey(0), cfg))
    if ckpt_dir is not None and ckpt.latest_step(ckpt_dir) is not None:
        state, extras = ckpt.restore(ckpt_dir, state)
        loader.restore(extras["loader"])
        start_step = extras["step"]
        print(f"resumed from step {start_step}")

    saver = ckpt.AsyncCheckpointer()
    monitor = StragglerMonitor()
    losses = []
    it = iter(loader)
    t_train0 = time.perf_counter()
    for step in range(start_step, steps):
        t0 = time.perf_counter()
        rows = next(it)
        inputs, labels = split_batch(rows)
        state, metrics = step_fn(state, {"tokens": inputs, "labels": labels})
        loss = float(metrics["loss"])
        losses.append(loss)
        dt = time.perf_counter() - t0
        slow = monitor.observe(step, dt)
        if step % log_every == 0 or step == steps - 1:
            print(f"step {step:5d}  loss {loss:.4f}  "
                  f"lr {float(metrics['lr']):.2e}  "
                  f"gnorm {float(metrics['grad_norm']):.2f}  {dt*1e3:.0f} ms"
                  + ("  [straggler]" if slow else ""))
        want_ckpt = ckpt_dir is not None and (
            (step + 1) % ckpt_every == 0
            or monitor.should_checkpoint_early())
        if want_ckpt:
            saver.save(ckpt_dir, step + 1, state,
                       extras={"step": step + 1, "loader": loader.state()})
    saver.wait()
    loader.close()
    wall = time.perf_counter() - t_train0
    tokens = (steps - start_step) * batch * seq_len
    return {"final_loss": losses[-1] if losses else float("nan"),
            "first_loss": losses[0] if losses else float("nan"),
            "steps": steps, "tokens_per_s": tokens / wall,
            "straggler_events": len(monitor.events)}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="fastwarc_lm")
    ap.add_argument("--shards", nargs="+", required=True)
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--reduced", action="store_true")
    args = ap.parse_args()
    stats = train_lm(arch=args.arch, shards=args.shards, steps=args.steps,
                     batch=args.batch, seq_len=args.seq_len, lr=args.lr,
                     ckpt_dir=args.ckpt_dir, reduced=args.reduced)
    print(stats)


if __name__ == "__main__":
    main()
