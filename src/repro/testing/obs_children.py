"""Child-process targets for the shm stats-slot tests.

The ``forkserver`` start method pickles ``Process`` targets by
qualified name, so these helpers must live in an importable module —
a test-local closure would fail to spawn. They are deliberately
import-light (stdlib + ``repro.obs`` only): the forkserver parent
imports this module fresh per child.
"""
from __future__ import annotations

import os
import time

from repro.obs.registry import ObsSnapshot
from repro.obs.shmstats import STATS_SLOT_BYTES, StatsSlotWriter
from repro.obs.shmstats import _HDR  # noqa: F401 - frame layout, tests only

__all__ = ["publish_counters", "stall_mid_write"]


def _attach(shm_name: str):
    """Attach the parent-owned segment without registering it with this
    process's resource tracker (the repo-wide child-attach idiom: the
    parent owns lifetime; a tracker entry here would double-unlink)."""
    from multiprocessing import resource_tracker, shared_memory

    orig_register = resource_tracker.register
    resource_tracker.register = lambda *a, **k: None
    try:
        return shared_memory.SharedMemory(name=shm_name)
    finally:
        resource_tracker.register = orig_register


def publish_counters(shm_name: str, offset: int, counters: dict,
                     publishes: int = 1) -> None:
    """Attach the parent's segment and publish ``counters`` as a
    cumulative snapshot ``publishes`` times (seqlock exercises the
    even→odd→even cycle once per publish)."""
    shm = _attach(shm_name)
    try:
        writer = StatsSlotWriter(shm.buf[offset:offset + STATS_SLOT_BYTES])
        for i in range(publishes):
            snap = ObsSnapshot(
                counters={k: v + i for k, v in counters.items()},
                sources=(f"child-{os.getpid()}",))
            writer.publish(snap)
        writer.close()
    finally:
        shm.close()


def stall_mid_write(shm_name: str, offset: int, started) -> None:
    """Simulate a writer dying *mid-publish*: mark the slot's seq odd,
    scribble garbage into the payload area, signal ``started``, and hang
    until the parent SIGKILLs us. A correct reader must reject the torn
    frame (``read() is None``); a successor writer must recover the slot
    (stale odd seq bumps to even on construction)."""
    shm = _attach(shm_name)
    try:
        buf = shm.buf[offset:offset + STATS_SLOT_BYTES]
        garbage = b"\xde\xad" * 32
        _HDR.pack_into(buf, 0, 7, len(garbage))  # odd seq: in progress
        buf[_HDR.size:_HDR.size + len(garbage)] = garbage
        del buf  # release the memoryview before the parent unlinks
        started.set()
        time.sleep(600)  # parent SIGKILLs here
    finally:
        shm.close()
