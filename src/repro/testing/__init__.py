"""Test-support utilities: deterministic fault injection for chaos tests.

Everything in here is import-light (no jax, no heavy deps) so test
collection stays fast; the injectors themselves are pure byte surgery
plus environment plumbing for the in-tree fault hooks.
"""
from .faults import (
    DamagedSpan,
    arm_decoder_stall,
    arm_scheduler_shard_kill,
    arm_worker_kill,
    corrupt_warc,
    member_spans,
)

__all__ = [
    "DamagedSpan",
    "arm_decoder_stall",
    "arm_scheduler_shard_kill",
    "arm_worker_kill",
    "corrupt_warc",
    "member_spans",
]
