"""Deterministic fault injection for WARC robustness tests.

Two families of injectors:

* **Byte corruption** — :func:`corrupt_warc` damages a seeded sample of
  members/records in a WARC image and returns the exact damaged spans,
  so a chaos test can predict which records the tolerant parser must
  quarantine and assert the survivors byte-identical to a clean oracle.
* **Process faults** — :func:`arm_worker_kill` / :func:`arm_decoder_stall`
  arm the in-tree env-var hooks (``REPRO_FAULT_WORKER_KILL``,
  ``REPRO_FAULT_DECODER_STALL``) with a fresh one-shot latch file, so
  exactly one child process dies/stalls per armed context no matter how
  many children inherit the environment.

Everything is deterministic under a fixed ``seed``: same input bytes →
same damaged spans → same surviving record set.
"""
from __future__ import annotations

import contextlib
import os
import random
import uuid
import zlib
from dataclasses import dataclass

from repro.core.warc import lz4 as _lz4
from repro.core.warc.streams import detect_compression

__all__ = [
    "DamagedSpan",
    "arm_decoder_stall",
    "arm_scheduler_shard_kill",
    "arm_worker_kill",
    "corrupt_warc",
    "member_spans",
]

# Junk that can never resynchronize: contains no WARC record magic, no
# gzip member magic (1f 8b 08), and no LZ4 frame magic (04 22 4d 18).
_JUNK = b"\xde\xad\xbe\xef\xfe\xed\xfa\xce"


@dataclass(frozen=True)
class DamagedSpan:
    """One damaged member/record: ``[start, end)`` in the *original* image."""

    index: int        # member ordinal in the clean image
    start: int        # absolute byte offset of the member/record
    end: int          # absolute end (next member's start)
    kind: str         # "garble" | "flip" | "truncate"


def member_spans(data: bytes) -> list[tuple[int, int]]:
    """Exact ``[start, end)`` spans of every member/record in ``data``.

    Spans are recovered by *decoding*, not by magic scanning, so
    compressed payload bytes that happen to contain a magic string can't
    produce phantom boundaries: gzip members via ``zlib`` unused-data
    walking, LZ4 frames via the in-tree frame parser, uncompressed
    records via the record parser's framing walk.
    """
    kind = detect_compression(data[:8])
    spans: list[tuple[int, int]] = []
    if kind == "gzip":
        pos = 0
        while pos < len(data):
            d = zlib.decompressobj(wbits=31)
            d.decompress(data[pos:])
            end = len(data) - len(d.unused_data)
            spans.append((pos, end))
            pos = end
    elif kind == "lz4":
        pos = 0
        while pos < len(data):
            end = _lz4.skip_frame(data, pos)
            spans.append((pos, end))
            pos = end
    elif kind == "none":
        from repro.core.warc.fastwarc import FastWARCIterator

        offsets = [r.stream_offset
                   for r in FastWARCIterator(data, parse_http=False)]
        for i, off in enumerate(offsets):
            end = offsets[i + 1] if i + 1 < len(offsets) else len(data)
            spans.append((off, end))
    else:  # pragma: no cover - zstd shards aren't member-addressable
        raise ValueError(f"unsupported compression for fault injection: "
                         f"{kind}")
    return spans


def _damage(buf: bytearray, a: int, b: int, kind: str, fmt: str) -> None:
    if kind == "garble":
        # Hit the spot each decoder validates *first*, so the error is
        # raised at the member boundary and the resync span is exact:
        # gzip CM byte (offset 2), LZ4 frame descriptor (offset 4, fails
        # the header checksum), uncompressed record magic.
        off = a + (2 if fmt == "gzip" else 4 if fmt == "lz4" else 0)
        n = min(len(_JUNK), b - off)
        buf[off:off + n] = _JUNK[:n]
    elif kind == "flip":
        # One bit-flipped byte mid-member: compressed formats catch it
        # via CRC/content checks; uncompressed payload flips may pass
        # silently (WARC framing intact) — realistic, and why the chaos
        # test uses "garble" when it needs exact survivor accounting.
        mid = a + (b - a) // 2
        buf[mid] ^= 0xFF
    else:
        raise ValueError(f"unknown damage kind: {kind}")


def corrupt_warc(data: bytes, *, fraction: float = 0.01, seed: int = 0,
                 mode: str = "garble") -> tuple[bytes, list[DamagedSpan]]:
    """Damage a seeded sample of members in a WARC image.

    ``mode="garble"`` overwrites each selected member's format header
    with junk (deterministically detectable at the member boundary);
    ``mode="flip"`` flips one byte mid-member; ``mode="truncate"``
    ignores ``fraction`` and cuts the image mid-way through its final
    member. Returns ``(damaged_bytes, spans)`` where ``spans`` lists the
    exact damaged ranges in the original image — the records a tolerant
    reader is expected to lose, in order.
    """
    spans = member_spans(data)
    if not spans:
        return data, []
    if mode == "truncate":
        a, b = spans[-1]
        cut = a + max(1, (b - a) // 2)
        return data[:cut], [DamagedSpan(len(spans) - 1, a, b, "truncate")]
    if mode not in ("garble", "flip"):
        raise ValueError(f"unknown corruption mode: {mode}")
    fmt = detect_compression(data[:8])
    k = min(len(spans), max(1, round(fraction * len(spans))))
    picks = sorted(random.Random(seed).sample(range(len(spans)), k))
    buf = bytearray(data)
    out: list[DamagedSpan] = []
    for i in picks:
        a, b = spans[i]
        _damage(buf, a, b, mode, fmt)
        out.append(DamagedSpan(i, a, b, mode))
    return bytes(buf), out


# ---------------------------------------------------------------------------
# process-fault arming (env + one-shot latch)
# ---------------------------------------------------------------------------
@contextlib.contextmanager
def _armed(var: str, latch_dir: str, spec_tail: str):
    latch = os.path.join(str(latch_dir), f"fault-latch-{uuid.uuid4().hex}")
    prev = os.environ.get(var)
    os.environ[var] = f"{latch}:{spec_tail}"
    # observability: armed faults are themselves counted, so a merged
    # snapshot from a fault-injection run says which faults were live
    from repro import obs

    obs.registry().counter_add(f"faults.armed.{var}")
    try:
        yield latch
    finally:
        if prev is None:
            os.environ.pop(var, None)
        else:
            os.environ[var] = prev
        with contextlib.suppress(OSError):
            os.unlink(latch)


def arm_worker_kill(latch_dir: str, nth: int = 1):
    """Arm ``REPRO_FAULT_WORKER_KILL``: the first pool worker (across the
    whole process tree sharing this environment) to reach its ``nth``
    produced result wins the latch and hard-exits (``os._exit``) before
    sending it. Yields the latch path; the latch file existing afterwards
    means the fault actually fired.
    """
    return _armed("REPRO_FAULT_WORKER_KILL", latch_dir, str(int(nth)))


def arm_scheduler_shard_kill(latch_dir: str, nth_batch: int = 1):
    """Arm ``REPRO_FAULT_SHARD_KILL``: the first gateway scheduler shard
    (the spec is captured at shard-*spawn* time, so arm before building
    the gateway) to begin serving its ``nth_batch``-th drained batch
    wins the one-shot latch and dies **mid-batch** — after publishing
    its in-flight scan registry (so coalesce-attached waiters are
    orphaned too) and before resolving any waiter. Losers of the latch
    race keep serving. Yields the latch path; the latch file existing
    afterwards means the fault actually fired.
    """
    return _armed("REPRO_FAULT_SHARD_KILL", latch_dir,
                  str(int(nth_batch)))


def arm_decoder_stall(latch_dir: str, member: int = 1,
                      seconds: float = 30.0):
    """Arm ``REPRO_FAULT_DECODER_STALL``: the first readahead decoder
    child to decode its ``member``-th member wins the latch and sleeps
    ``seconds`` — long past the supervisor's stall timeout, so the parent
    must detect the hang, kill the child, and resume. Yields the latch
    path.
    """
    return _armed("REPRO_FAULT_DECODER_STALL", latch_dir,
                  f"{int(member)}:{float(seconds)}")
