"""Model zoo: pure-JAX model definitions over explicit param pytrees.

No flax/haiku offline — models are (init_fn, apply_fn) pairs over plain
dict pytrees, which also keeps sharding-rule assignment transparent
(``repro/launch/sharding.py`` maps param paths to PartitionSpecs).
"""
