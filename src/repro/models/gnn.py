"""GatedGCN [arXiv:1711.07553, benchmarked in arXiv:2003.00982] in JAX.

Message passing is built on ``jax.ops.segment_sum`` over an explicit
``(src, dst)`` edge index — JAX has no sparse SpMM beyond BCOO, so the
gather/segment-reduce *is* the kernel (kernel_taxonomy §GNN). Layer l:

    ê_ij = E_ij + ReLU(LN(A h_i + B h_j + C e_ij))          (edge update)
    η_ij = σ(ê_ij) / (Σ_{j'→i} σ(ê_ij') + ε)                (edge gates)
    h_i  = h_i + ReLU(LN(U h_i + Σ_{j→i} η_ij ⊙ V h_j))     (node update)

LayerNorm replaces the reference BatchNorm (batch-independent, the common
JAX choice — noted in DESIGN.md). Node/edge padding uses a validity mask
so fixed-shape minibatches (sampled subgraphs) lower cleanly.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .common import (
    Params,
    dense,
    dense_init,
    layernorm,
    layernorm_init,
    mlp,
    mlp_init,
    shard_hint,
)

#: GNN tensors shard their node/edge dim over every mesh axis — params are
#: replicated, so the model axis is free parallelism for message passing
GNN_AXES = ("pod", "data", "model")


@dataclass(frozen=True)
class GatedGCNConfig:
    name: str
    n_layers: int = 16
    d_hidden: int = 70
    d_feat: int = 1433
    d_edge_feat: int = 0      # 0 = no input edge features
    n_classes: int = 7
    dtype: str = "float32"
    remat: bool = False
    remat_group: int = 0        # >1: save layer carries every g layers only
    scan_unroll: bool = False   # dry-run: unroll the 16-layer scan

    @property
    def jnp_dtype(self):
        return getattr(jnp, self.dtype)


def _layer_init(key, d: int, dtype) -> Params:
    ks = jax.random.split(key, 5)
    return {
        "A": dense_init(ks[0], d, d, dtype, bias=True),
        "B": dense_init(ks[1], d, d, dtype, bias=True),
        "C": dense_init(ks[2], d, d, dtype, bias=True),
        "U": dense_init(ks[3], d, d, dtype, bias=True),
        "V": dense_init(ks[4], d, d, dtype, bias=True),
        "ln_h": layernorm_init(d, dtype),
        "ln_e": layernorm_init(d, dtype),
    }


def _scan_layers(layer_fn, carry, layers, cfg):
    """Layer scan with optional two-level (grouped) remat.

    With ``remat_group = g``, only every g-th carry is saved; the inner g
    layers recompute in backward. Carries are edge-sized ([E, d] ≈ 1 GiB
    per layer shard on ogbn-products), so saving 16 of them dominated the
    memory roofline (§Perf iteration).
    """
    g = cfg.remat_group
    unroll = True if cfg.scan_unroll else 1
    if g and g > 1 and cfg.n_layers % g == 0:
        n_groups = cfg.n_layers // g
        grouped = jax.tree.map(
            lambda x: x.reshape(n_groups, g, *x.shape[1:]), layers)

        inner = jax.checkpoint(layer_fn) if cfg.remat else layer_fn

        @jax.checkpoint
        def group_fn(carry, glp):
            out, _ = jax.lax.scan(inner, carry, glp, unroll=unroll)
            return out, None

        carry, _ = jax.lax.scan(group_fn, carry, grouped)
        return carry
    body = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    carry, _ = jax.lax.scan(body, carry, layers, unroll=unroll)
    return carry


def init_params(key, cfg: GatedGCNConfig) -> Params:
    dt = cfg.jnp_dtype
    k_in, k_e, k_layers, k_out = jax.random.split(key, 4)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg.d_hidden, dt))(layer_keys)
    return {
        "encode_h": dense_init(k_in, cfg.d_feat, cfg.d_hidden, dt, bias=True),
        "encode_e": dense_init(
            k_e, max(cfg.d_edge_feat, 1), cfg.d_hidden, dt, bias=True),
        "layers": layers,
        "head": mlp_init(k_out, [cfg.d_hidden, cfg.d_hidden // 2,
                                 cfg.n_classes], dt),
    }


def forward(params: Params, node_feats: jax.Array, edge_src: jax.Array,
            edge_dst: jax.Array, cfg: GatedGCNConfig,
            edge_feats: jax.Array | None = None,
            node_mask: jax.Array | None = None) -> jax.Array:
    """-> per-node class logits [N, n_classes].

    node_feats [N, d_feat]; edge_src/dst [E] int32 (messages flow src->dst;
    padding edges must point at a padding node). ``node_mask`` zeroes
    padding nodes so they never contribute through normalization.
    """
    N = node_feats.shape[0]
    h = dense(params["encode_h"], node_feats.astype(cfg.jnp_dtype))
    if edge_feats is None:
        edge_feats = jnp.ones((edge_src.shape[0], 1), cfg.jnp_dtype)
    e = dense(params["encode_e"], edge_feats.astype(cfg.jnp_dtype))
    if node_mask is not None:
        h = h * node_mask[:, None].astype(h.dtype)

    def layer_fn(carry, lp):
        h, e = carry
        # gather/scatter outputs default to replicated under GSPMD: hints
        # keep edge tensors edge-sharded and node tensors node-sharded
        # (§Perf iteration: ogb_products held 105 GiB/device without them)
        h_src = shard_hint(h[edge_src], GNN_AXES, None)   # [E, d]
        h_dst = shard_hint(h[edge_dst], GNN_AXES, None)
        e_hat = dense(lp["A"], h_dst) + dense(lp["B"], h_src) \
            + dense(lp["C"], e)
        e_new = e + jax.nn.relu(layernorm(lp["ln_e"], e_hat))
        gates = jax.nn.sigmoid(e_new)             # [E, d]
        msg = gates * dense(lp["V"], h_src)
        num = shard_hint(
            jax.ops.segment_sum(msg, edge_dst, num_segments=N),
            GNN_AXES, None)
        den = shard_hint(
            jax.ops.segment_sum(gates, edge_dst, num_segments=N),
            GNN_AXES, None) + 1e-6
        agg = num / den
        h_new = h + jax.nn.relu(
            layernorm(lp["ln_h"], dense(lp["U"], h) + agg))
        if node_mask is not None:
            h_new = h_new * node_mask[:, None].astype(h.dtype)
        return (h_new, shard_hint(e_new, GNN_AXES, None)), None

    h, _ = _scan_layers(layer_fn, (h, e), params["layers"], cfg)
    return mlp(params["head"], h)


def forward_pooled(params: Params, node_feats, edge_src, edge_dst,
                   graph_ids: jax.Array, n_graphs: int,
                   cfg: GatedGCNConfig, node_mask=None) -> jax.Array:
    """Graph-level prediction (``molecule`` shape): mean-pool nodes per
    graph via segment_sum, then the classification head."""
    N = node_feats.shape[0]
    h = dense(params["encode_h"], node_feats.astype(cfg.jnp_dtype))
    e = dense(params["encode_e"],
              jnp.ones((edge_src.shape[0], 1), cfg.jnp_dtype))
    if node_mask is not None:
        h = h * node_mask[:, None].astype(h.dtype)

    def layer_fn(carry, lp):
        h, e = carry
        h_src = shard_hint(h[edge_src], GNN_AXES, None)
        h_dst = shard_hint(h[edge_dst], GNN_AXES, None)
        e_hat = dense(lp["A"], h_dst) + dense(lp["B"], h_src) + dense(lp["C"], e)
        e_new = e + jax.nn.relu(layernorm(lp["ln_e"], e_hat))
        gates = jax.nn.sigmoid(e_new)
        num = shard_hint(
            jax.ops.segment_sum(gates * dense(lp["V"], h_src), edge_dst,
                                num_segments=N), GNN_AXES, None)
        den = shard_hint(
            jax.ops.segment_sum(gates, edge_dst, num_segments=N),
            GNN_AXES, None) + 1e-6
        h_new = h + jax.nn.relu(
            layernorm(lp["ln_h"], dense(lp["U"], h) + num / den))
        if node_mask is not None:
            h_new = h_new * node_mask[:, None].astype(h.dtype)
        return (h_new, shard_hint(e_new, GNN_AXES, None)), None

    h, _ = _scan_layers(layer_fn, (h, e), params["layers"], cfg)
    w = (node_mask if node_mask is not None
         else jnp.ones((N,), h.dtype))[:, None]
    sums = jax.ops.segment_sum(h * w, graph_ids, num_segments=n_graphs)
    counts = jax.ops.segment_sum(w, graph_ids, num_segments=n_graphs)
    pooled = sums / jnp.maximum(counts, 1.0)
    return mlp(params["head"], pooled)


def loss_fn(params: Params, node_feats, edge_src, edge_dst, labels,
            cfg: GatedGCNConfig, label_mask=None, node_mask=None) -> jax.Array:
    """Masked node-classification cross entropy."""
    logits = forward(params, node_feats, edge_src, edge_dst, cfg,
                     node_mask=node_mask).astype(jnp.float32)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(
        logp, jnp.maximum(labels, 0)[:, None], axis=-1)[:, 0]
    if label_mask is None:
        label_mask = labels >= 0
    w = label_mask.astype(jnp.float32)
    return (nll * w).sum() / jnp.maximum(w.sum(), 1.0)
