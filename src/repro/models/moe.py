"""Mixture-of-Experts FFN: top-k routing, GShard-style grouped dispatch.

Dispatch design (it matters at 128 experts × 1M tokens × 512 chips):

* Tokens are split into **G groups aligned with the mesh's batch shards**
  (GShard [arXiv:2006.16668] groups == data shards). All sorting, capacity
  bookkeeping, and gather/scatter happen *within a group*, so under GSPMD
  they are shard-local — no cross-shard scatter (which the partitioner can
  only realize by replicating a [T, d] buffer on every chip; dry-run
  finding, 153 GB/device before this formulation).
* Within a group, assignments are argsorted by expert (the MegaBlocks
  permutation [arXiv:2211.15841]) and packed into a dense
  ``[G, E, C, d]`` buffer for one batched grouped GEMM — E rides the
  ``model`` mesh axis (expert parallelism), so the dispatched buffer's
  movement between batch- and expert-sharded layouts lowers to the
  canonical MoE all-to-all.
* Per-group capacity ``C = cf · Tg · k / E`` (lane-aligned); overflow
  drops are per-group, as in GShard. Gates of kept assignments are
  scattered alongside token ids, and the combine is a weighted
  shard-local scatter-add from the expert-major buffer — nothing
  assignment-major ``[A, d]`` is ever materialized (its cotangent was
  replicated too).

Load-balancing auxiliary loss: Switch-style E·Σ(f_e · p̄_e), global.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import BATCH_AXES, Params, ambient_mesh_shape, shard_hint


def moe_init(key, d_model: int, d_ff: int, n_experts: int,
             dtype=jnp.float32) -> Params:
    kr, kg, ku, kd = jax.random.split(key, 4)
    scale_in = 1.0 / jnp.sqrt(jnp.float32(d_model))
    scale_out = 1.0 / jnp.sqrt(jnp.float32(d_ff))
    uniform = jax.random.uniform
    return {
        "router": {"w": uniform(kr, (d_model, n_experts), dtype,
                                -scale_in, scale_in)},
        "gate": uniform(kg, (n_experts, d_model, d_ff), dtype,
                        -scale_in, scale_in),
        "up": uniform(ku, (n_experts, d_model, d_ff), dtype,
                      -scale_in, scale_in),
        "down": uniform(kd, (n_experts, d_ff, d_model), dtype,
                        -scale_out, scale_out),
    }


def _batch_shard_extent() -> int:
    shape = ambient_mesh_shape()
    g = 1
    for axis in BATCH_AXES:
        g *= shape.get(axis, 1)
    return g


def moe_apply(p: Params, x: jax.Array, *, top_k: int,
              capacity_factor: float = 1.25,
              norm_topk: bool = True,
              groups: int | None = None) -> tuple[jax.Array, jax.Array]:
    """x: [T, d] flattened tokens -> (out [T, d], aux_loss scalar)."""
    T, d = x.shape
    E = p["gate"].shape[0]
    G = _batch_shard_extent() if groups is None else groups
    G = max(min(G, T), 1)
    while T % G:  # tiny/odd token counts: fall back to fewer groups
        G -= 1
    Tg = T // G
    A = Tg * top_k                                   # assignments per group
    capacity = int(max(capacity_factor * A / E, top_k))
    capacity = -(-capacity // 8) * 8                 # lane-align
    pad_rows = 8                                     # scatter sentinel rows

    xg = shard_hint(x.reshape(G, Tg, d), BATCH_AXES, None, None)
    logits = (xg @ p["router"]["w"]).astype(jnp.float32)    # [G, Tg, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gates, experts = jax.lax.top_k(probs, top_k)            # [G, Tg, k]
    if norm_topk:  # Qwen3 normalizes the selected gates
        gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    # ---- per-group assignment permutation -----------------------------
    flat_expert = experts.reshape(G, A)
    flat_token = jnp.broadcast_to(
        jnp.arange(Tg, dtype=jnp.int32)[:, None], (Tg, top_k)).reshape(A)
    flat_token = jnp.broadcast_to(flat_token, (G, A))
    flat_gate = gates.reshape(G, A)

    order = jnp.argsort(flat_expert, axis=1)                # stable
    sorted_expert = jnp.take_along_axis(flat_expert, order, axis=1)
    sorted_token = jnp.take_along_axis(flat_token, order, axis=1)
    sorted_gate = jnp.take_along_axis(flat_gate, order, axis=1)

    counts = jax.vmap(lambda fe: jnp.bincount(fe, length=E))(flat_expert)
    starts = jnp.cumsum(counts, axis=1) - counts            # [G, E]
    pos = (jnp.arange(A, dtype=jnp.int32)[None, :]
           - jnp.take_along_axis(starts, sorted_expert, axis=1))
    keep = pos < capacity

    # ---- dispatch: shard-local scatters into [G, E, C] buffers --------
    slot = jnp.where(keep, sorted_expert * capacity + pos, E * capacity)

    def scatter_group(s, vals, fill, dtype):
        buf = jnp.full((E * capacity + 1,), fill, dtype)
        return buf.at[s].set(vals, mode="drop")[:E * capacity]

    token_ids = jax.vmap(
        lambda s, t: scatter_group(s, t, Tg, jnp.int32))(slot, sorted_token)
    token_ids = shard_hint(
        token_ids.reshape(G, E, capacity), BATCH_AXES, "model", None)
    gates_ec = jax.vmap(
        lambda s, g: scatter_group(s, g, 0.0, jnp.float32))(slot, sorted_gate)
    gates_ec = shard_hint(
        gates_ec.reshape(G, E, capacity), BATCH_AXES, "model", None)

    # ---- gather tokens (shard-local), grouped GEMM ---------------------
    x_pad = shard_hint(
        jnp.concatenate(
            [xg, jnp.zeros((G, pad_rows, d), x.dtype)], axis=1),
        BATCH_AXES, None, None)                              # [G, Tg+8, d]
    xe = jax.vmap(lambda xp, ti: xp[ti])(x_pad, token_ids)   # [G, E, C, d]
    xe = shard_hint(xe, BATCH_AXES, "model", None, None)

    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["gate"])) * \
        jnp.einsum("gecd,edf->gecf", xe, p["up"])
    h = shard_hint(h, BATCH_AXES, "model", None, None)       # [G, E, C, f]
    ye = jnp.einsum("gecf,efd->gecd", h, p["down"])          # [G, E, C, d]
    ye = shard_hint(ye, BATCH_AXES, "model", None, None)

    # ---- combine: weighted shard-local scatter-add ---------------------
    weighted = ye * gates_ec[..., None].astype(ye.dtype)
    out_pad = jax.vmap(
        lambda ti, w: jnp.zeros((Tg + pad_rows, d), ye.dtype)
        .at[ti.reshape(E * capacity)].add(w.reshape(E * capacity, d)))(
        token_ids, weighted)
    out_pad = shard_hint(out_pad, BATCH_AXES, None, None)
    out = out_pad[:, :Tg].reshape(T, d)

    # ---- Switch aux loss (global) ---------------------------------------
    frac_tokens = counts.sum(0).astype(jnp.float32) / jnp.maximum(G * A, 1)
    mean_probs = probs.mean(axis=(0, 1))
    aux = E * jnp.sum(frac_tokens * mean_probs)
    return shard_hint(out, BATCH_AXES, None).astype(x.dtype), aux
