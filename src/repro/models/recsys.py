"""RecSys ranking models: DCN-v2, DIN, DIEN, AutoInt + retrieval scoring.

The hot path is the sparse embedding lookup. JAX has no ``nn.EmbeddingBag``
— it is built here from ``jnp.take`` + ``jax.ops.segment_sum`` (ragged
bags) and masked take-sum (fixed-shape behavior sequences), per the
kernel-taxonomy note that this is part of the system, not a stub.

Sharding: tables are row-sharded over the ``model`` axis (they dominate
memory at 10⁶–10⁹ rows); the per-field gather then lowers to the standard
embedding all-to-all under GSPMD. MLPs are replicated.

Retrieval (``retrieval_cand`` shape): one query scored against 10⁶
candidates as a *single batched forward* — item-side tower embeds all
candidates, user-side vector dots against them, top-k on device. For the
target-attention models (DIN/DIEN) the retrieval stage uses sum-pooled
history as the user vector (the papers themselves use two-tower retrieval
in front of attention ranking; DESIGN.md §5).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .common import (
    Params,
    dense,
    dense_init,
    embed_init,
    layernorm_init,
    mlp,
    mlp_init,
)

# --------------------------------------------------------------------------
# EmbeddingBag built from take + segment_sum
# --------------------------------------------------------------------------

def embedding_bag(table: jax.Array, ids: jax.Array, offsets: jax.Array,
                  mode: str = "sum") -> jax.Array:
    """Ragged bags: ids [nnz], offsets [B] (CSR-style starts) -> [B, d]."""
    B = offsets.shape[0]
    nnz = ids.shape[0]
    seg = jnp.cumsum(
        jnp.zeros(nnz, jnp.int32).at[offsets[1:]].add(1)) if B > 1 else \
        jnp.zeros(nnz, jnp.int32)
    vecs = jnp.take(table, ids, axis=0)
    out = jax.ops.segment_sum(vecs, seg, num_segments=B)
    if mode == "mean":
        counts = jax.ops.segment_sum(jnp.ones(nnz), seg, num_segments=B)
        out = out / jnp.maximum(counts, 1.0)[:, None]
    return out


def masked_bag(table: jax.Array, ids: jax.Array, mask: jax.Array,
               mode: str = "sum") -> jax.Array:
    """Fixed-shape bags: ids [B, L], mask [B, L] -> [B, d]."""
    vecs = jnp.take(table, ids, axis=0)               # [B, L, d]
    w = mask.astype(vecs.dtype)[..., None]
    out = (vecs * w).sum(axis=1)
    if mode == "mean":
        out = out / jnp.maximum(w.sum(axis=1), 1.0)
    return out


# --------------------------------------------------------------------------
# configs
# --------------------------------------------------------------------------

#: Criteo-like per-field vocab profile: a few huge, many small (36.1M rows)
DEFAULT_VOCABS_26 = (
    [10_000_000] * 3 + [1_000_000] * 5 + [100_000] * 10 + [1_000] * 8
)
#: Avazu-like 39-field profile for AutoInt
DEFAULT_VOCABS_39 = (
    [5_000_000] * 4 + [500_000] * 10 + [50_000] * 15 + [1_000] * 10
)


@dataclass(frozen=True)
class RecsysConfig:
    name: str
    kind: str                       # dcn_v2 | din | dien | autoint
    embed_dim: int = 16
    n_dense: int = 13
    vocabs: tuple = tuple(DEFAULT_VOCABS_26)
    # dcn-v2
    n_cross_layers: int = 3
    mlp_dims: tuple = (1024, 1024, 512)
    # din / dien
    seq_len: int = 100
    scan_unroll: bool = False   # dry-run: unroll the GRU/AUGRU time scan
    attn_mlp: tuple = (80, 40)
    gru_dim: int = 108
    item_vocab: int = 10_000_000
    cate_vocab: int = 100_000
    n_profile_fields: int = 8
    profile_vocab: int = 100_000
    # autoint
    n_attn_layers: int = 3
    n_attn_heads: int = 2
    d_attn: int = 32
    dtype: str = "float32"

    @property
    def jnp_dtype(self):
        return getattr(jnp, self.dtype)

    @property
    def n_sparse(self) -> int:
        return len(self.vocabs)


# --------------------------------------------------------------------------
# DCN-v2
# --------------------------------------------------------------------------

def dcn_init(key, cfg: RecsysConfig) -> Params:
    dt = cfg.jnp_dtype
    keys = jax.random.split(key, 5 + cfg.n_cross_layers)
    tables = [embed_init(k, v, cfg.embed_dim, dt)
              for k, v in zip(jax.random.split(keys[0], cfg.n_sparse),
                              cfg.vocabs)]
    d0 = cfg.n_dense + cfg.n_sparse * cfg.embed_dim
    cross = [dense_init(keys[1 + i], d0, d0, dt, bias=True)
             for i in range(cfg.n_cross_layers)]
    deep = mlp_init(keys[-3], [d0, *cfg.mlp_dims], dt)
    head = dense_init(keys[-2], d0 + cfg.mlp_dims[-1], 1, dt, bias=True)
    item_tower = mlp_init(keys[-1], [cfg.embed_dim, 64, 32], dt)
    return {"tables": tables, "cross": cross, "deep": deep, "head": head,
            "item_tower": item_tower}


def dcn_forward(params: Params, dense_feats: jax.Array,
                sparse_ids: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """dense [B, 13] fp, sparse [B, 26] int -> logits [B]."""
    embs = [jnp.take(t, sparse_ids[:, i], axis=0)
            for i, t in enumerate(params["tables"])]
    x0 = jnp.concatenate([dense_feats.astype(cfg.jnp_dtype), *embs], axis=-1)
    x = x0
    for layer in params["cross"]:                  # x_{l+1} = x0 ⊙ Wx + x
        x = x0 * dense(layer, x) + x
    deep = mlp(params["deep"], x0)
    return dense(params["head"],
                 jnp.concatenate([x, deep], axis=-1))[:, 0]


# --------------------------------------------------------------------------
# DIN (target attention over behavior history)
# --------------------------------------------------------------------------

def din_init(key, cfg: RecsysConfig) -> Params:
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 7)
    d = cfg.embed_dim
    return {
        "item_table": embed_init(ks[0], cfg.item_vocab, d, dt),
        "cate_table": embed_init(ks[1], cfg.cate_vocab, d, dt),
        "profile_tables": [
            embed_init(k, cfg.profile_vocab, d, dt)
            for k in jax.random.split(ks[2], cfg.n_profile_fields)],
        # attention MLP over [hist, target, hist-target, hist*target]
        "attn": mlp_init(ks[3], [8 * d, *cfg.attn_mlp, 1], dt),
        "mlp": mlp_init(ks[4], [(cfg.n_profile_fields + 4) * d, 200, 80, 1],
                        dt),
        "item_tower": mlp_init(ks[5], [2 * d, 64, 32], dt),
    }


def _din_embed_pair(params, item_ids, cate_ids):
    return jnp.concatenate([
        jnp.take(params["item_table"], item_ids, axis=0),
        jnp.take(params["cate_table"], cate_ids, axis=0)], axis=-1)


def din_forward(params: Params, profile_ids: jax.Array,
                hist_items: jax.Array, hist_cates: jax.Array,
                hist_mask: jax.Array, target_item: jax.Array,
                target_cate: jax.Array, cfg: RecsysConfig) -> jax.Array:
    """profile [B,P], hist [B,L], target [B] -> logits [B]."""
    e_hist = _din_embed_pair(params, hist_items, hist_cates)  # [B, L, 2d]
    e_tgt = _din_embed_pair(params, target_item, target_cate)  # [B, 2d]
    tgt = jnp.broadcast_to(e_tgt[:, None, :], e_hist.shape)
    feats = jnp.concatenate(
        [e_hist, tgt, e_hist - tgt, e_hist * tgt], axis=-1)   # [B, L, 8d]
    scores = mlp(params["attn"], feats)[..., 0]               # [B, L]
    scores = jnp.where(hist_mask > 0, scores, -1e30)
    w = jax.nn.softmax(scores, axis=-1) * (hist_mask.sum(-1, keepdims=True) > 0)
    pooled = jnp.einsum("bl,bld->bd", w, e_hist)              # [B, 2d]
    prof = [jnp.take(t, profile_ids[:, i], axis=0)
            for i, t in enumerate(params["profile_tables"])]
    x = jnp.concatenate([*prof, pooled, e_tgt], axis=-1)
    return mlp(params["mlp"], x)[:, 0]


# --------------------------------------------------------------------------
# DIEN (interest extractor GRU + AUGRU)
# --------------------------------------------------------------------------

def _gru_init(key, d_in: int, d_h: int, dtype) -> Params:
    k1, k2 = jax.random.split(key)
    s_in = 1.0 / jnp.sqrt(jnp.float32(d_in))
    s_h = 1.0 / jnp.sqrt(jnp.float32(d_h))
    return {
        "wx": jax.random.uniform(k1, (d_in, 3 * d_h), dtype, -s_in, s_in),
        "wh": jax.random.uniform(k2, (d_h, 3 * d_h), dtype, -s_h, s_h),
        "b": jnp.zeros((3 * d_h,), dtype),
    }


def gru_scan(p: Params, xs: jax.Array, h0: jax.Array,
             att: jax.Array | None = None,
             unroll: bool = False) -> tuple[jax.Array, jax.Array]:
    """xs [B, L, d_in] -> (hs [B, L, d_h], h_last). If ``att`` [B, L] is
    given, runs AUGRU: the update gate is scaled by the attention score."""
    d_h = h0.shape[-1]
    wx, wh, b = p["wx"], p["wh"], p["b"]

    def step(h, inp):
        if att is None:
            x = inp
            a = None
        else:
            x, a = inp
        gx = x @ wx + b
        gh = h @ wh
        xr, xz, xn = jnp.split(gx, 3, axis=-1)
        hr, hz, hn = jnp.split(gh, 3, axis=-1)
        r = jax.nn.sigmoid(xr + hr)
        z = jax.nn.sigmoid(xz + hz)
        n = jnp.tanh(xn + r * hn)
        if a is not None:
            z = z * a[:, None]                    # AUGRU: attentional update
        h_new = (1 - z) * h + z * n
        return h_new, h_new

    xs_t = xs.transpose(1, 0, 2)                  # [L, B, d]
    inputs = xs_t if att is None else (xs_t, att.transpose(1, 0))
    h_last, hs = jax.lax.scan(step, h0, inputs,
                              unroll=True if unroll else 1)
    return hs.transpose(1, 0, 2), h_last


def dien_init(key, cfg: RecsysConfig) -> Params:
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 8)
    d = cfg.embed_dim
    return {
        "item_table": embed_init(ks[0], cfg.item_vocab, d, dt),
        "cate_table": embed_init(ks[1], cfg.cate_vocab, d, dt),
        "profile_tables": [
            embed_init(k, cfg.profile_vocab, d, dt)
            for k in jax.random.split(ks[2], cfg.n_profile_fields)],
        "gru1": _gru_init(ks[3], 2 * d, cfg.gru_dim, dt),
        "augru": _gru_init(ks[4], cfg.gru_dim, cfg.gru_dim, dt),
        "attn": mlp_init(ks[5], [cfg.gru_dim + 2 * d, *cfg.attn_mlp, 1], dt),
        "mlp": mlp_init(
            ks[6],
            [cfg.n_profile_fields * d + cfg.gru_dim + 2 * d, 200, 80, 1], dt),
        "item_tower": mlp_init(ks[7], [2 * d, 64, 32], dt),
    }


def dien_forward(params: Params, profile_ids, hist_items, hist_cates,
                 hist_mask, target_item, target_cate,
                 cfg: RecsysConfig) -> jax.Array:
    B = hist_items.shape[0]
    e_hist = _din_embed_pair(params, hist_items, hist_cates)   # [B, L, 2d]
    e_tgt = _din_embed_pair(params, target_item, target_cate)  # [B, 2d]
    h0 = jnp.zeros((B, cfg.gru_dim), cfg.jnp_dtype)
    interest, _ = gru_scan(params["gru1"], e_hist, h0,
                           unroll=cfg.scan_unroll)           # [B, L, g]
    tgt = jnp.broadcast_to(e_tgt[:, None, :],
                           (*interest.shape[:2], e_tgt.shape[-1]))
    scores = mlp(params["attn"],
                 jnp.concatenate([interest, tgt], -1))[..., 0]  # [B, L]
    scores = jnp.where(hist_mask > 0, scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1) * (hist_mask.sum(-1, keepdims=True) > 0)
    _, final = gru_scan(params["augru"], interest, h0, att=att,
                        unroll=cfg.scan_unroll)
    prof = [jnp.take(t, profile_ids[:, i], axis=0)
            for i, t in enumerate(params["profile_tables"])]
    x = jnp.concatenate([*prof, final, e_tgt], axis=-1)
    return mlp(params["mlp"], x)[:, 0]


# --------------------------------------------------------------------------
# AutoInt
# --------------------------------------------------------------------------

def autoint_init(key, cfg: RecsysConfig) -> Params:
    dt = cfg.jnp_dtype
    ks = jax.random.split(key, 4)
    tables = [embed_init(k, v, cfg.embed_dim, dt)
              for k, v in zip(jax.random.split(ks[0], cfg.n_sparse),
                              cfg.vocabs)]
    layers = []
    d_in = cfg.embed_dim
    for k in jax.random.split(ks[1], cfg.n_attn_layers):
        kq, kk, kv, kr = jax.random.split(k, 4)
        layers.append({
            "wq": dense_init(kq, d_in, cfg.d_attn, dt),
            "wk": dense_init(kk, d_in, cfg.d_attn, dt),
            "wv": dense_init(kv, d_in, cfg.d_attn, dt),
            "wres": dense_init(kr, d_in, cfg.d_attn, dt),
        })
        d_in = cfg.d_attn
    head = dense_init(ks[2], cfg.n_sparse * d_in, 1, dt, bias=True)
    item_tower = mlp_init(ks[3], [cfg.embed_dim, 64, 32], dt)
    return {"tables": tables, "layers": layers, "head": head,
            "item_tower": item_tower}


def autoint_forward(params: Params, sparse_ids: jax.Array,
                    cfg: RecsysConfig) -> jax.Array:
    """sparse [B, F] -> logits [B]; F field embeddings interact via MHSA."""
    x = jnp.stack([jnp.take(t, sparse_ids[:, i], axis=0)
                   for i, t in enumerate(params["tables"])], axis=1)  # [B,F,d]
    H = cfg.n_attn_heads
    for lp in params["layers"]:
        q, k, v = dense(lp["wq"], x), dense(lp["wk"], x), dense(lp["wv"], x)
        B, F, D = q.shape
        dh = D // H
        qh = q.reshape(B, F, H, dh).transpose(0, 2, 1, 3)
        kh = k.reshape(B, F, H, dh).transpose(0, 2, 1, 3)
        vh = v.reshape(B, F, H, dh).transpose(0, 2, 1, 3)
        s = jnp.einsum("bhfd,bhgd->bhfg", qh, kh) / jnp.sqrt(jnp.float32(dh))
        p = jax.nn.softmax(s.astype(jnp.float32), -1).astype(x.dtype)
        o = jnp.einsum("bhfg,bhgd->bhfd", p, vh)
        o = o.transpose(0, 2, 1, 3).reshape(B, F, D)
        x = jax.nn.relu(o + dense(lp["wres"], x))
    return dense(params["head"], x.reshape(x.shape[0], -1))[:, 0]


# --------------------------------------------------------------------------
# shared: loss + retrieval scoring
# --------------------------------------------------------------------------

def bce_loss(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logits = logits.astype(jnp.float32)
    labels = labels.astype(jnp.float32)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * labels
        + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_scores(params: Params, user_vec: jax.Array,
                     cand_ids: jax.Array, cfg: RecsysConfig,
                     top_k: int = 100) -> tuple[jax.Array, jax.Array]:
    """Score 1 query against N candidates with one batched matmul.

    ``user_vec`` [d_tower]; candidates embedded via the first/item table +
    item tower -> [N, d_tower]; returns (top_scores, top_ids).
    """
    table = params["tables"][0] if "tables" in params else params["item_table"]
    cand = jnp.take(table, cand_ids, axis=0)          # [N, d]
    if "item_table" in params:  # din/dien: concat cate-0 embedding
        cand = jnp.concatenate(
            [cand, jnp.broadcast_to(params["cate_table"][0],
                                    cand.shape)], axis=-1)
    cand_vec = mlp(params["item_tower"], cand)        # [N, d_tower]
    scores = cand_vec @ user_vec                      # [N]
    return jax.lax.top_k(scores, top_k)


def user_tower(params: Params, cfg: RecsysConfig, *args) -> jax.Array:
    """Cheap user vector for retrieval: pooled embeddings -> item_tower dim."""
    if "tables" in params:  # dcn/autoint: mean of field embeddings
        sparse_ids = args[0]
        embs = jnp.stack([jnp.take(t, sparse_ids[:, i], axis=0)
                          for i, t in enumerate(params["tables"])], axis=1)
        pooled = embs.mean(axis=1)
        if pooled.shape[-1] != params["item_tower"][0]["w"].shape[0]:
            pooled = jnp.pad(
                pooled,
                ((0, 0),
                 (0, params["item_tower"][0]["w"].shape[0] - pooled.shape[-1])))
    else:  # din/dien: sum-pooled history pair embedding
        hist_items, hist_cates, hist_mask = args
        e = _din_embed_pair(params, hist_items, hist_cates)
        pooled = (e * hist_mask[..., None]).sum(1) / jnp.maximum(
            hist_mask.sum(-1, keepdims=True), 1.0)
    return mlp(params["item_tower"], pooled)
