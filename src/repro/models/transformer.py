"""Decoder-only transformer LM: dense + MoE variants, GQA + RoPE.

Layers are *stacked* (every layer-param leaf has a leading ``n_layers``
axis) and applied with ``jax.lax.scan``, so the lowered HLO is
depth-independent — a 94-layer MoE compiles as fast as a 2-layer one,
which the 70-cell dry-run matrix depends on. Remat (``jax.checkpoint``)
wraps the scanned body for training.

Three entry points per config:
  * :func:`forward`      — logits for teacher forcing ([B,S] tokens)
  * :func:`loss_fn`      — next-token CE (+ MoE aux loss)
  * :func:`decode_step`  — one-token serve step against a KV cache
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace

import jax
import jax.numpy as jnp

from .attention import apply_rope, chunked_attention
from .common import (
    BATCH_AXES,
    Params,
    cross_entropy_loss,
    dense,
    dense_init,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    shard_hint,
    swiglu,
    swiglu_init,
)
from .moe import moe_apply, moe_init


@dataclass(frozen=True)
class TransformerConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    moe_experts: int = 0           # 0 = dense FFN
    moe_top_k: int = 0
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    dtype: str = "float32"
    remat: bool = True
    attn_chunk: int = 1024
    attn_unroll: bool = False    # dry-run: unroll the KV-chunk scan
    layers_unroll: bool = False  # dry-run delta compiles: unroll layer scan
    capacity_factor: float = 1.25
    aux_loss_weight: float = 0.001
    train_microbatches: int = 1  # grad-accumulation splits of global batch
    compact_opt_state: bool = False  # int8/bf16 Adam state (8-bit-optimizer)
    grad_accum_dtype: str = "float32"  # microbatch grad accumulator dtype

    @property
    def is_moe(self) -> bool:
        return self.moe_experts > 0

    @property
    def jnp_dtype(self):
        return getattr(jnp, self.dtype)

    def scaled(self, **kw) -> "TransformerConfig":
        return replace(self, **kw)

    def param_count(self) -> int:
        """Analytic parameter count (no allocation)."""
        d, dh = self.d_model, self.d_head
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
            + self.n_heads * dh * d
        if self.qkv_bias:
            attn += (self.n_heads + 2 * self.n_kv_heads) * dh
        if self.is_moe:
            ffn = d * self.moe_experts \
                + 3 * self.moe_experts * d * self.d_ff
        else:
            ffn = 3 * d * self.d_ff
        per_layer = attn + ffn + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k of E experts)."""
        if not self.is_moe:
            return self.param_count()
        d = self.d_model
        dh = self.d_head
        attn = d * self.n_heads * dh + 2 * d * self.n_kv_heads * dh \
            + self.n_heads * dh * d
        ffn_active = d * self.moe_experts + 3 * self.moe_top_k * d * self.d_ff
        per_layer = attn + ffn_active + 2 * d
        return self.vocab * d * 2 + self.n_layers * per_layer + d


# --------------------------------------------------------------------------
# init
# --------------------------------------------------------------------------

def _layer_init(key, cfg: TransformerConfig) -> Params:
    dt = cfg.jnp_dtype
    keys = jax.random.split(key, 8)
    d, dh = cfg.d_model, cfg.d_head
    p: Params = {
        "ln1": rmsnorm_init(d, dt),
        "ln2": rmsnorm_init(d, dt),
        "wq": dense_init(keys[0], d, cfg.n_heads * dh, dt, bias=cfg.qkv_bias),
        "wk": dense_init(keys[1], d, cfg.n_kv_heads * dh, dt, bias=cfg.qkv_bias),
        "wv": dense_init(keys[2], d, cfg.n_kv_heads * dh, dt, bias=cfg.qkv_bias),
        "wo": dense_init(keys[3], cfg.n_heads * dh, d, dt),
    }
    if cfg.is_moe:
        p["moe"] = moe_init(keys[4], d, cfg.d_ff, cfg.moe_experts, dt)
    else:
        p["mlp"] = swiglu_init(keys[4], d, cfg.d_ff, dt)
    return p


def init_params(key, cfg: TransformerConfig) -> Params:
    dt = cfg.jnp_dtype
    k_embed, k_head, k_layers = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = jax.vmap(lambda k: _layer_init(k, cfg))(layer_keys)
    return {
        "embed": embed_init(k_embed, cfg.vocab, cfg.d_model, dt),
        "layers": layers,  # stacked: every leaf has leading [n_layers]
        "ln_f": rmsnorm_init(cfg.d_model, dt),
        "lm_head": dense_init(k_head, cfg.d_model, cfg.vocab, dt),
    }


def param_shapes(cfg: TransformerConfig) -> Params:
    """Shape/dtype tree without allocation (dry-run path)."""
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


# --------------------------------------------------------------------------
# forward
# --------------------------------------------------------------------------

def _attention_block(lp: Params, x: jax.Array, cfg: TransformerConfig,
                     positions: jax.Array) -> jax.Array:
    B, S, d = x.shape
    h = rmsnorm(lp["ln1"], x)
    q = dense(lp["wq"], h).reshape(B, S, cfg.n_heads, cfg.d_head)
    k = dense(lp["wk"], h).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    v = dense(lp["wv"], h).reshape(B, S, cfg.n_kv_heads, cfg.d_head)
    q = apply_rope(q.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    k = apply_rope(k.transpose(0, 2, 1, 3), positions, cfg.rope_theta)
    v = v.transpose(0, 2, 1, 3)
    o = chunked_attention(q, k, v, causal=True, chunk=cfg.attn_chunk,
                          unroll=cfg.attn_unroll)
    o = o.transpose(0, 2, 1, 3).reshape(B, S, cfg.n_heads * cfg.d_head)
    return x + dense(lp["wo"], o)


def _ffn_block(lp: Params, x: jax.Array, cfg: TransformerConfig) -> tuple:
    h = rmsnorm(lp["ln2"], x)
    if cfg.is_moe:
        B, S, d = h.shape
        y, aux = moe_apply(lp["moe"], h.reshape(B * S, d),
                           top_k=cfg.moe_top_k,
                           capacity_factor=cfg.capacity_factor)
        return x + y.reshape(B, S, d), aux
    return x + swiglu(lp["mlp"], h), jnp.zeros((), jnp.float32)


def forward_hidden(params: Params, tokens: jax.Array,
                   cfg: TransformerConfig) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (final hidden [B, S, d], aux_loss)."""
    B, S = tokens.shape
    x = shard_hint(params["embed"][tokens], BATCH_AXES, None, None)
    positions = jnp.arange(S, dtype=jnp.int32)

    def layer_fn(carry, lp):
        x, aux = carry
        x = _attention_block(lp, x, cfg, positions)
        x, aux_l = _ffn_block(lp, x, cfg)
        return (x, aux + aux_l), None

    body = jax.checkpoint(layer_fn) if cfg.remat else layer_fn
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["layers"],
                               unroll=True if cfg.layers_unroll else 1)
    return rmsnorm(params["ln_f"], x), aux / cfg.n_layers


def forward(params: Params, tokens: jax.Array,
            cfg: TransformerConfig) -> tuple[jax.Array, jax.Array]:
    """tokens [B, S] -> (logits [B, S, V], aux_loss)."""
    x, aux = forward_hidden(params, tokens, cfg)
    return dense(params["lm_head"], x), aux


def fused_ce_loss(head: Params, x: jax.Array, labels: jax.Array,
                  chunk_s: int = 512) -> jax.Array:
    """Fused lm_head + cross entropy, chunked over the sequence dim.

    Never materializes [B, S, V] logits: each scan step projects one
    [B, chunk, d] slice, reduces it to (logsumexp, label-logit) pairs, and
    remat recomputes the chunk's logits in backward. At 1M tokens × 152k
    vocab the unfused loss held ~12 GiB/device of fp32 logits + iota +
    transposes (§Perf iteration 1); chunking bounds it by S/chunk_s.
    Chunking rides the (unsharded) S dim, so slices stay shard-aligned.
    """
    B, S, d = x.shape
    chunk_s = min(chunk_s, S)
    while S % chunk_s:
        chunk_s //= 2
    n_chunks = S // chunk_s
    xc = x.reshape(B, n_chunks, chunk_s, d).transpose(1, 0, 2, 3)
    lc = labels.reshape(B, n_chunks, chunk_s).transpose(1, 0, 2)

    @jax.checkpoint
    def chunk_nll(args):
        xs, ls = args                                   # [B,c,d], [B,c]
        logits = dense(head, xs).astype(jnp.float32)    # [B, c, V]
        logits = shard_hint(logits, BATCH_AXES, None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)         # [B, c]
        vocab_iota = jax.lax.broadcasted_iota(
            jnp.int32, logits.shape, logits.ndim - 1)
        mask = ls != -1
        safe = jnp.where(mask, ls, 0)
        label_logit = jnp.sum(
            jnp.where(vocab_iota == safe[..., None], logits, 0.0), axis=-1)
        nll = jnp.where(mask, lse - label_logit, 0.0)
        return nll.sum(), mask.sum()

    def step(carry, args):
        nll_sum, count = carry
        s, c = chunk_nll(args)
        return (nll_sum + s, count + c), None

    (nll_sum, count), _ = jax.lax.scan(
        step, (jnp.zeros((), jnp.float32), jnp.zeros((), jnp.int32)),
        (xc, lc))
    return nll_sum / jnp.maximum(count, 1)


def loss_fn(params: Params, tokens: jax.Array, labels: jax.Array,
            cfg: TransformerConfig) -> jax.Array:
    x, aux = forward_hidden(params, tokens, cfg)
    return fused_ce_loss(params["lm_head"], x, labels) \
        + cfg.aux_loss_weight * aux


# --------------------------------------------------------------------------
# decode (serve path)
# --------------------------------------------------------------------------

def init_cache(cfg: TransformerConfig, batch: int, max_seq: int,
               dtype=None) -> dict:
    dt = dtype or cfg.jnp_dtype
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, cfg.d_head)
    return {"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt),
            "length": jnp.zeros((), jnp.int32)}


def decode_step(params: Params, cache: dict, token: jax.Array,
                cfg: TransformerConfig) -> tuple[jax.Array, dict]:
    """One decode step: token [B] -> (logits [B, V], updated cache).

    Attends over the full cache buffer with a length mask (no dynamic
    shapes), inserting the new KV at ``cache['length']``.
    """
    B = token.shape[0]
    S_max = cache["k"].shape[3]
    idx = cache["length"]
    x = params["embed"][token][:, None, :]            # [B, 1, d]
    pos = jnp.full((1,), idx, jnp.int32)

    def layer_fn(x, inputs):
        lp, kc, vc = inputs                            # kc/vc [B,Hkv,S,D]
        h = rmsnorm(lp["ln1"], x)
        q = dense(lp["wq"], h).reshape(B, 1, cfg.n_heads, cfg.d_head)
        k = dense(lp["wk"], h).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        v = dense(lp["wv"], h).reshape(B, 1, cfg.n_kv_heads, cfg.d_head)
        q = apply_rope(q.transpose(0, 2, 1, 3), pos, cfg.rope_theta)
        k = apply_rope(k.transpose(0, 2, 1, 3), pos, cfg.rope_theta)
        v = v.transpose(0, 2, 1, 3)
        # insert at `idx` via a one-hot masked merge, NOT dynamic_update_slice:
        # DUS at a dynamic index of the model-axis-sharded S dim forces GSPMD
        # to all-gather the whole cache every step (2 GiB/chip/token on the
        # 32k shapes — §Perf iteration 3); the mask is shard-local.
        cache_spec = (BATCH_AXES, None, "model", None)  # [B, Hkv, S, D]
        onehot = (jnp.arange(S_max, dtype=jnp.int32) == idx)
        onehot = onehot[None, None, :, None]
        kc = shard_hint(jnp.where(onehot, k.astype(kc.dtype), kc), *cache_spec)
        vc = shard_hint(jnp.where(onehot, v.astype(vc.dtype), vc), *cache_spec)
        # masked full-buffer attention: scores [B, H, 1, S_max]. Hints pin
        # the sequence dim to the model axis through the fp32 upcast +
        # GQA repeat — without them GSPMD all-gathered the whole cache
        # every step (48 GiB/chip at 32k; §Perf iteration B3).
        group = cfg.n_heads // cfg.n_kv_heads
        # keep the cache in its storage dtype end-to-end: fp32 accumulation
        # happens inside the dots (preferred_element_type), never as a
        # materialized cache copy — the upcast version stacked a fp32
        # [L, B, Hkv, S, D] buffer (4 GiB/chip at 32k × 64L, §Perf B4)
        kk = shard_hint(jnp.repeat(kc, group, axis=1),
                        BATCH_AXES, None, "model", None)
        vv = shard_hint(jnp.repeat(vc, group, axis=1),
                        BATCH_AXES, None, "model", None)
        s = jax.lax.dot_general(
            q.astype(kk.dtype), kk,
            (((3,), (3,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32)       # [B, H, 1, S]
        s = s / jnp.sqrt(jnp.float32(cfg.d_head))
        valid = jnp.arange(S_max)[None, None, None, :] <= idx
        s = shard_hint(jnp.where(valid, s, -1e30),
                       BATCH_AXES, None, None, "model")
        p = jax.nn.softmax(s, axis=-1)
        o = jax.lax.dot_general(
            p.astype(vv.dtype), vv,
            (((3,), (2,)), ((0, 1), (0, 1))),
            preferred_element_type=jnp.float32).astype(x.dtype)
        o = o.transpose(0, 2, 1, 3).reshape(B, 1, cfg.n_heads * cfg.d_head)
        x = x + dense(lp["wo"], o)
        # FFN (dense or MoE)
        h2 = rmsnorm(lp["ln2"], x)
        if cfg.is_moe:
            y, _ = moe_apply(lp["moe"], h2.reshape(B, cfg.d_model),
                             top_k=cfg.moe_top_k,
                             capacity_factor=max(cfg.capacity_factor, 2.0))
            x = x + y.reshape(B, 1, cfg.d_model)
        else:
            x = x + swiglu(lp["mlp"], h2)
        return x, (kc, vc)

    x, (k_new, v_new) = jax.lax.scan(
        layer_fn, x, (params["layers"], cache["k"], cache["v"]),
        unroll=True if cfg.layers_unroll else 1)
    x = rmsnorm(params["ln_f"], x)
    logits = dense(params["lm_head"], x)[:, 0]
    new_cache = {"k": k_new, "v": v_new, "length": idx + 1}
    return logits, new_cache
