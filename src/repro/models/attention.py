"""Attention: RoPE, chunked (flash-style) jnp attention, GQA, KV cache.

Two execution paths share one math definition:

* :func:`chunked_attention` — pure-JAX online-softmax attention scanned
  over KV chunks. This is what the distributed model lowers: it never
  materializes the [Sq, Sk] score matrix (32k-prefill would OOM), XLA's
  cost model sees its FLOPs explicitly, and it shards cleanly under GSPMD.
* :mod:`repro.kernels.flash_attention` — the Pallas TPU kernel with the
  same semantics, dispatched when ``use_kernel=True`` (hot path on real
  hardware; validated in interpret mode on CPU).
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_frequencies(d_head: int, theta: float = 10000.0) -> jax.Array:
    exponents = jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head
    return 1.0 / (theta ** exponents)  # [d_head/2]


def apply_rope(x: jax.Array, positions: jax.Array,
               theta: float = 10000.0) -> jax.Array:
    """x: [B, H, S, D]; positions: [S] or [B, S]."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                      # [D/2]
    angles = positions[..., None].astype(jnp.float32) * freqs  # [..., S, D/2]
    if angles.ndim == 2:  # [S, D/2] -> broadcast over batch and heads
        angles = angles[None, None]
    else:  # [B, S, D/2]
        angles = angles[:, None]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# chunked online-softmax attention (jnp)
# --------------------------------------------------------------------------

def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                      causal: bool = True, chunk: int = 1024,
                      kv_offset: int | None = None,
                      unroll: bool = False) -> jax.Array:
    """GQA attention without the full score matrix.

    q [B,H,Sq,D], k/v [B,Hkv,Sk,D] -> [B,H,Sq,D]. Scans KV in chunks of
    ``chunk`` with running (max, denom, acc) — the flash recurrence in XLA.
    ``kv_offset`` aligns the causal diagonal (defaults to Sk - Sq).
    """
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    offset = Sk - Sq if kv_offset is None else kv_offset
    scale = 1.0 / math.sqrt(D)

    if Sk <= chunk:
        return _attn_block(q, k, v, 0, causal, offset, scale, group)

    n_chunks = -(-Sk // chunk)
    pad = n_chunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    kc = k.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(B, Hkv, n_chunks, chunk, D).transpose(2, 0, 1, 3, 4)

    q32 = q.astype(jnp.float32) * scale

    def step(carry, inputs):
        m_prev, l_prev, acc_prev = carry
        kb, vb, ci = inputs
        kb = jnp.repeat(kb.astype(jnp.float32), group, axis=1)
        vb = jnp.repeat(vb.astype(jnp.float32), group, axis=1)
        s = jnp.einsum("bhqd,bhkd->bhqk", q32, kb)
        k_start = ci * chunk
        rows = (offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2))
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
        valid = cols < Sk  # padding chunk guard
        if causal:
            valid = valid & (rows >= cols)
        s = jnp.where(valid, s, -1e30)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m_prev - m_new)
        l_new = l_prev * corr + p.sum(axis=-1)
        acc_new = acc_prev * corr[..., None] + jnp.einsum("bhqk,bhkd->bhqd", p, vb)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, H, Sq), -1e30, jnp.float32)
    l0 = jnp.zeros((B, H, Sq), jnp.float32)
    a0 = jnp.zeros((B, H, Sq, D), jnp.float32)
    # checkpoint per chunk = FlashAttention-style backward: the [·,Sq,chunk]
    # score/probability matrices are recomputed in bwd instead of stowed
    # across the scan (they were the largest attention residual, §Perf it.2)
    # unroll=True removes the while-loop so XLA's static cost analysis sees
    # every chunk's FLOPs (loop bodies are otherwise counted once) — the
    # dry-run sets it; real training keeps the rolled loop
    (m, l, acc), _ = jax.lax.scan(
        jax.checkpoint(step), (m0, l0, a0),
        (kc, vc, jnp.arange(n_chunks, dtype=jnp.int32)),
        unroll=True if unroll else 1)
    out = acc / jnp.where(l == 0.0, 1.0, l)[..., None]
    return out.astype(q.dtype)


def _attn_block(q, k, v, k_start, causal, offset, scale, group):
    """Single-block exact attention (small Sk fast path)."""
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32) * scale,
                   jnp.repeat(k.astype(jnp.float32), group, axis=1))
    if causal:
        rows = offset + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 3)
        s = jnp.where(rows >= cols, s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p,
                     jnp.repeat(v.astype(jnp.float32), group, axis=1))
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# KV cache (decode path)
# --------------------------------------------------------------------------

def init_kv_cache(n_layers: int, batch: int, n_kv_heads: int, max_seq: int,
                  d_head: int, dtype=jnp.bfloat16) -> dict:
    shape = (n_layers, batch, n_kv_heads, max_seq, d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_update(cache: dict, layer: int, k_new: jax.Array,
                 v_new: jax.Array) -> dict:
    """Insert [B, Hkv, 1, D] at the current length for ``layer``."""
    idx = cache["length"]
    k = jax.lax.dynamic_update_slice(
        cache["k"], k_new[None].astype(cache["k"].dtype),
        (layer, 0, 0, idx, 0))
    v = jax.lax.dynamic_update_slice(
        cache["v"], v_new[None].astype(cache["v"].dtype),
        (layer, 0, 0, idx, 0))
    return {**cache, "k": k, "v": v}
