"""Shared layers and parameter helpers (pure functions over dict pytrees)."""
from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

Params = dict  # nested dict pytree of jnp arrays


# --------------------------------------------------------------------------
# initializers
# --------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, dtype=jnp.float32,
               bias: bool = False) -> Params:
    scale = 1.0 / math.sqrt(d_in)
    p = {"w": jax.random.uniform(key, (d_in, d_out), dtype, -scale, scale)}
    if bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def embed_init(key, vocab: int, dim: int, dtype=jnp.float32) -> jax.Array:
    return jax.random.normal(key, (vocab, dim), dtype) * 0.02


def rmsnorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype)}


def layernorm_init(dim: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


# --------------------------------------------------------------------------
# layer applications
# --------------------------------------------------------------------------

def dense(p: Params, x: jax.Array) -> jax.Array:
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * p["scale"].astype(jnp.float32)).astype(dt)


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)
            + p["bias"].astype(jnp.float32)).astype(dt)


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    """LLaMA-style gated MLP: down( silu(gate(x)) * up(x) )."""
    return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))


def swiglu_init(key, d_model: int, d_ff: int, dtype=jnp.float32) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "gate": dense_init(k1, d_model, d_ff, dtype),
        "up": dense_init(k2, d_model, d_ff, dtype),
        "down": dense_init(k3, d_ff, d_model, dtype),
    }


def mlp(p: Params, x: jax.Array, act=jax.nn.relu) -> jax.Array:
    """Stacked plain MLP: p is a list of dense params."""
    for i, layer in enumerate(p):
        x = dense(layer, x)
        if i < len(p) - 1:
            x = act(x)
    return x


def mlp_init(key, dims: list[int], dtype=jnp.float32, bias: bool = True) -> list:
    keys = jax.random.split(key, len(dims) - 1)
    return [dense_init(k, dims[i], dims[i + 1], dtype, bias=bias)
            for i, k in enumerate(keys)]


def ambient_mesh_shape() -> dict[str, int]:
    """{axis: size} of the mesh currently in context, or {} when unmeshed."""
    try:
        from jax._src import mesh as _mesh_lib
        env_mesh = _mesh_lib.thread_resources.env.physical_mesh
        if env_mesh is not None and env_mesh.axis_names:
            return dict(env_mesh.shape)
    except Exception:
        pass
    try:
        am = jax.sharding.get_abstract_mesh()
        if am is not None and not getattr(am, "empty", True):
            return dict(am.shape)
    except Exception:
        pass
    return {}


def ambient_mesh_axes() -> tuple[str, ...]:
    """Axis names of the mesh currently in context, or () when unmeshed."""
    return tuple(ambient_mesh_shape().keys())


def shard_hint(x: jax.Array, *spec) -> jax.Array:
    """``with_sharding_constraint`` that degrades to a no-op off-mesh.

    ``spec`` entries are axis names / tuples / None; any axis absent from
    the ambient mesh is dropped, so model code can state its preferred
    layout once and run unchanged on 1 CPU device or a 512-chip mesh.
    """
    names = ambient_mesh_axes()
    if not names:
        return x

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, str):
            return entry if entry in names else None
        sub = tuple(a for a in entry if a in names)
        return sub if sub else None

    cleaned = [keep(e) for e in spec]
    if all(c is None for c in cleaned):
        return x
    from jax.sharding import PartitionSpec
    try:
        return jax.lax.with_sharding_constraint(x, PartitionSpec(*cleaned))
    except Exception:
        return x


#: conventional batch-like axes of this framework's meshes
BATCH_AXES = ("pod", "data")


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       ignore_index: int = -1) -> jax.Array:
    """Token-mean cross entropy in fp32; labels == ignore_index are masked.

    logsumexp formulation: never materializes log-probabilities, and the
    label-logit gather is expressed so GSPMD keeps the [B, S, V] logits
    sharded on batch *and* vocab (a take_along_axis over the sharded vocab
    dim previously forced an all-gather — the 110 GB/device dry-run bug).
    """
    logits = shard_hint(logits, BATCH_AXES, None, "model")
    logits = logits.astype(jnp.float32)
    mask = labels != ignore_index
    safe = jnp.where(mask, labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)                     # [B, S]
    # label logit via masked reduction over the (sharded) vocab dim:
    # lowers to a partial reduce + all-reduce instead of a vocab gather
    vocab = logits.shape[-1]
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == safe[..., None], logits, 0.0), axis=-1)
    nll = shard_hint(lse - label_logit, BATCH_AXES, None)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1)


def count_params(params: Any) -> int:
    return sum(p.size for p in jax.tree.leaves(params))
