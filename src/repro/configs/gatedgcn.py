"""GatedGCN: 16L d_hidden 70, gated-edge aggregation [arXiv:2003.00982].

Shape set carries its own graph dimensions (Cora / Reddit-sampled /
ogbn-products / ZINC-style batched molecules). Per-shape feature widths
and class counts follow the standard datasets.
"""
from repro.configs import ArchSpec, ShapeSpec
from repro.models.gnn import GatedGCNConfig

CONFIG = GatedGCNConfig(
    name="gatedgcn", n_layers=16, d_hidden=70, d_feat=1433, n_classes=7,
    # §Perf hillclimb: per-layer remat + group-4 carry saving — without
    # them ogbn-products holds 163 GiB/device of live edge intermediates
    remat=True, remat_group=4,
)

REDUCED = GatedGCNConfig(
    name="gatedgcn-reduced", n_layers=3, d_hidden=16, d_feat=12, n_classes=4,
)

SPEC = ArchSpec(
    arch_id="gatedgcn",
    family="gnn",
    config=CONFIG,
    reduced=REDUCED,
    shapes=(
        ShapeSpec("full_graph_sm", "full_graph",
                  {"n_nodes": 2708, "n_edges": 10556, "d_feat": 1433,
                   "n_classes": 7}),
        ShapeSpec("minibatch_lg", "minibatch",
                  {"n_nodes": 232_965, "n_edges": 114_615_892,
                   "batch_nodes": 1024, "fanouts": [15, 10],
                   "d_feat": 602, "n_classes": 41}),
        ShapeSpec("ogb_products", "full_graph",
                  {"n_nodes": 2_449_029, "n_edges": 61_859_140,
                   "d_feat": 100, "n_classes": 47}),
        ShapeSpec("molecule", "molecule",
                  {"n_nodes": 30, "n_edges": 64, "batch": 128,
                   "d_feat": 28, "n_classes": 1}),
    ),
    notes="message passing via segment_sum over edge index (no SpMM in "
          "JAX); minibatch_lg runs the real neighbor sampler "
          "(repro/data/graph.py)",
)
