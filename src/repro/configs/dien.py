"""DIEN [arXiv:1809.03672]: embed 18, seq 100, interest GRU + AUGRU 108,
ranking MLP 200-80. [unverified tier — dims follow the paper's §4]"""
from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="dien", kind="dien", embed_dim=18, seq_len=100, gru_dim=108,
    attn_mlp=(80, 40), item_vocab=10_000_000, cate_vocab=100_000,
    n_profile_fields=8, profile_vocab=100_000,
)

REDUCED = RecsysConfig(
    name="dien-reduced", kind="dien", embed_dim=8, seq_len=12, gru_dim=16,
    attn_mlp=(16, 8), item_vocab=256, cate_vocab=32,
    n_profile_fields=3, profile_vocab=64,
)

SPEC = ArchSpec(
    arch_id="dien", family="recsys", config=CONFIG, reduced=REDUCED,
    shapes=recsys_shapes(),
    notes="sequential recurrence (GRU+AUGRU scan) — the only recsys arch "
          "whose serve path is latency-bound by a scan",
)
