"""DIN [arXiv:1706.06978]: embed 18, behavior seq 100, target attention
MLP 80-40, ranking MLP 200-80."""
from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import RecsysConfig

CONFIG = RecsysConfig(
    name="din", kind="din", embed_dim=18, seq_len=100,
    attn_mlp=(80, 40), item_vocab=10_000_000, cate_vocab=100_000,
    n_profile_fields=8, profile_vocab=100_000,
)

REDUCED = RecsysConfig(
    name="din-reduced", kind="din", embed_dim=8, seq_len=12,
    attn_mlp=(16, 8), item_vocab=256, cate_vocab=32,
    n_profile_fields=3, profile_vocab=64,
)

SPEC = ArchSpec(
    arch_id="din", family="recsys", config=CONFIG, reduced=REDUCED,
    shapes=recsys_shapes(),
    notes="target attention over [B,100] history; retrieval shape uses "
          "the pooled-history two-tower variant (DESIGN.md §5)",
)
