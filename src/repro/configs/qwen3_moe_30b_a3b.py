"""Qwen3-30B-A3B: 48L d2048 32H(kv4) MoE 128e top-8 d_ff 768 v151936.

[hf:Qwen/Qwen3-30B-A3B; hf] head_dim 128 per the published config.
"""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-30b-a3b",
    n_layers=48, d_model=2048, n_heads=32, n_kv_heads=4, d_head=128,
    d_ff=768, vocab=151936, moe_experts=128, moe_top_k=8,
    rope_theta=1_000_000.0, dtype="bfloat16",
)

REDUCED = TransformerConfig(
    name="qwen3-moe-30b-a3b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=32, vocab=512, moe_experts=8, moe_top_k=2,
    dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="qwen3_moe_30b_a3b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=lm_shapes(),
    notes="mid-scale MoE sibling of the 235B config",
)
