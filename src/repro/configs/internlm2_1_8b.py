"""InternLM2-1.8B: 24L d2048 16H(kv8) d_ff 8192 v92544, GQA.

[arXiv:2403.17297; hf:internlm/internlm2-1_8b] d_head = 2048/16 = 128.
"""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="internlm2-1.8b",
    n_layers=24, d_model=2048, n_heads=16, n_kv_heads=8, d_head=128,
    d_ff=8192, vocab=92544, rope_theta=1_000_000.0, dtype="bfloat16",
)

REDUCED = TransformerConfig(
    name="internlm2-1.8b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512, dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="internlm2_1_8b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=lm_shapes(),
    notes="smallest LM of the pool; ~100M-class reduced variant is the "
          "end-to-end training example's base",
)
