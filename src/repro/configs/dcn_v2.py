"""DCN-v2 [arXiv:2008.13535]: 13 dense + 26 sparse fields, embed 16,
3 full-rank cross layers, parallel deep MLP 1024-1024-512."""
from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import DEFAULT_VOCABS_26, RecsysConfig

CONFIG = RecsysConfig(
    name="dcn-v2", kind="dcn_v2", embed_dim=16, n_dense=13,
    vocabs=tuple(DEFAULT_VOCABS_26), n_cross_layers=3,
    mlp_dims=(1024, 1024, 512),
)

REDUCED = RecsysConfig(
    name="dcn-v2-reduced", kind="dcn_v2", embed_dim=8, n_dense=13,
    vocabs=tuple([64] * 26), n_cross_layers=2, mlp_dims=(32, 16),
)

SPEC = ArchSpec(
    arch_id="dcn_v2", family="recsys", config=CONFIG, reduced=REDUCED,
    shapes=recsys_shapes(),
    notes="36.1M embedding rows (criteo-like profile), row-sharded over "
          "the model axis",
)
