"""AutoInt [arXiv:1810.11921]: 39 sparse fields, embed 16, 3 self-attn
interaction layers (2 heads, d_attn 32)."""
from repro.configs import ArchSpec, recsys_shapes
from repro.models.recsys import DEFAULT_VOCABS_39, RecsysConfig

CONFIG = RecsysConfig(
    name="autoint", kind="autoint", embed_dim=16,
    vocabs=tuple(DEFAULT_VOCABS_39), n_attn_layers=3, n_attn_heads=2,
    d_attn=32,
)

REDUCED = RecsysConfig(
    name="autoint-reduced", kind="autoint", embed_dim=8,
    vocabs=tuple([64] * 39), n_attn_layers=2, n_attn_heads=2, d_attn=16,
)

SPEC = ArchSpec(
    arch_id="autoint", family="recsys", config=CONFIG, reduced=REDUCED,
    shapes=recsys_shapes(),
    notes="field-embedding self-attention; 27.3M embedding rows",
)
