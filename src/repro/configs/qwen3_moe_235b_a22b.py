"""Qwen3-235B-A22B: 94L d4096 64H(kv4) MoE 128e top-8 d_ff 1536 v151936.

[hf:Qwen/Qwen3-235B-A22B; config family verified via hf:Qwen/Qwen3-30B-A3B]
head_dim 128 per the published config (attention dims decouple from
d_model in Qwen3). Analytic totals: 235.1B params, 22.2B active.
"""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen3-moe-235b-a22b",
    n_layers=94, d_model=4096, n_heads=64, n_kv_heads=4, d_head=128,
    d_ff=1536, vocab=151936, moe_experts=128, moe_top_k=8,
    rope_theta=1_000_000.0, dtype="bfloat16",
    # §Perf hillclimb: 94-layer carries + Adam state exceed v5e HBM at
    # 256 chips without 8-way grad accumulation + 8-bit optimizer state
    train_microbatches=8, compact_opt_state=True,
    grad_accum_dtype="bfloat16",
)

REDUCED = TransformerConfig(
    name="qwen3-moe-235b-a22b-reduced",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_head=16,
    d_ff=48, vocab=512, moe_experts=8, moe_top_k=2,
    dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="qwen3_moe_235b_a22b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=lm_shapes(),
    notes="flagship MoE; expert-parallel over the model axis (128e/16=8 per "
          "device), most representative of large-scale WARC-corpus training",
)
