"""StarCoder2-3B: 30L d3072 24H(kv2) d_ff 12288 v49152, GQA+RoPE.

[arXiv:2402.19173; hf:bigcode/starcoder2-3b] d_head = 3072/24 = 128.
StarCoder2 uses a plain (non-gated) MLP; we keep the framework-wide SwiGLU
block — parameter count differs by the gate matrix; noted as a
substitution in DESIGN.md (uniform FFN keeps the sharding rules shared).
"""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="starcoder2-3b",
    n_layers=30, d_model=3072, n_heads=24, n_kv_heads=2, d_head=128,
    d_ff=12288, vocab=49152, rope_theta=999_999.0, dtype="bfloat16",
)

REDUCED = TransformerConfig(
    name="starcoder2-3b-reduced",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2, d_head=16,
    d_ff=256, vocab=512, dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="starcoder2_3b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=lm_shapes(),
    notes="dense code LM; 24 heads is non-divisible by the 16-way model "
          "axis — GSPMD pads (see dry-run notes)",
)
