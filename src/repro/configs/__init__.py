"""Architecture registry: ``--arch <id>`` configs + their shape sets.

Each ``<id>.py`` defines ``SPEC: ArchSpec`` with the exact published
config, its per-arch input-shape set, and a reduced config for CPU smoke
tests. ``get_spec(arch_id)`` / ``all_arch_ids()`` are the public API.
"""
from __future__ import annotations

import importlib
from dataclasses import dataclass, field
from typing import Any

ARCH_IDS = [
    "qwen3_moe_235b_a22b",
    "qwen3_moe_30b_a3b",
    "starcoder2_3b",
    "qwen25_32b",
    "internlm2_1_8b",
    "gatedgcn",
    "dcn_v2",
    "din",
    "dien",
    "autoint",
    # the paper's own end-to-end config (WARC-pipeline-fed LM)
    "fastwarc_lm",
]

#: canonical ``--arch`` spelling (dashes) -> module name
_ALIAS = {a.replace("_", "-"): a for a in ARCH_IDS}


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str              # train | prefill | decode | serve | retrieval |
    #                        full_graph | minibatch | molecule
    params: dict = field(default_factory=dict)
    skip_reason: str | None = None   # e.g. long_500k on full attention


@dataclass(frozen=True)
class ArchSpec:
    arch_id: str
    family: str            # lm | gnn | recsys
    config: Any
    reduced: Any           # smoke-test-scale config of the same family
    shapes: tuple          # tuple[ShapeSpec, ...]
    notes: str = ""

    def shape(self, name: str) -> ShapeSpec:
        for s in self.shapes:
            if s.name == name:
                return s
        raise KeyError(f"{self.arch_id} has no shape {name!r}")


def get_spec(arch_id: str) -> ArchSpec:
    arch_id = _ALIAS.get(arch_id, arch_id)
    if arch_id not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch_id!r}; known: {ARCH_IDS}")
    module = importlib.import_module(f"repro.configs.{arch_id}")
    return module.SPEC


def all_arch_ids(include_paper: bool = True) -> list[str]:
    ids = list(ARCH_IDS)
    if not include_paper:
        ids.remove("fastwarc_lm")
    return ids


# -- shared LM shape set (assigned to every LM-family arch) -----------------

def lm_shapes(*, sub_quadratic: bool = False) -> tuple:
    skip = (None if sub_quadratic else
            "full quadratic attention at 524k tokens is infeasible by "
            "construction; arch has no sub-quadratic variant (DESIGN.md §5)")
    return (
        ShapeSpec("train_4k", "train", {"seq_len": 4096, "global_batch": 256}),
        ShapeSpec("prefill_32k", "prefill",
                  {"seq_len": 32768, "global_batch": 32}),
        ShapeSpec("decode_32k", "decode",
                  {"seq_len": 32768, "global_batch": 128}),
        ShapeSpec("long_500k", "decode",
                  {"seq_len": 524288, "global_batch": 1},
                  skip_reason=skip),
    )


def recsys_shapes() -> tuple:
    return (
        ShapeSpec("train_batch", "train", {"batch": 65536}),
        ShapeSpec("serve_p99", "serve", {"batch": 512}),
        ShapeSpec("serve_bulk", "serve", {"batch": 262144}),
        ShapeSpec("retrieval_cand", "retrieval",
                  {"batch": 1, "n_candidates": 1_000_000}),
    )
