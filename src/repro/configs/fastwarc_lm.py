"""The paper's own end-to-end config: a ~100M-param byte-level LM trained
on the FastWARC ingestion pipeline's output (Common-Crawl-style corpus).

This is the configuration ``examples/train_lm_on_warc.py`` runs for a few
hundred steps on CPU — the full-system demonstration that the paper's
parser feeds a real training loop.
"""
from repro.configs import ArchSpec, ShapeSpec
from repro.data.tokenizer import VOCAB_SIZE
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="fastwarc-lm-100m",
    n_layers=12, d_model=768, n_heads=12, n_kv_heads=4, d_head=64,
    d_ff=2048, vocab=VOCAB_SIZE, rope_theta=10_000.0, dtype="float32",
    attn_chunk=256,
)

REDUCED = TransformerConfig(
    name="fastwarc-lm-reduced",
    n_layers=2, d_model=128, n_heads=4, n_kv_heads=2, d_head=32,
    d_ff=256, vocab=VOCAB_SIZE, dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="fastwarc_lm",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=(
        ShapeSpec("train_1k", "train", {"seq_len": 1024, "global_batch": 32}),
        ShapeSpec("serve_1k", "decode", {"seq_len": 1024, "global_batch": 8}),
    ),
    notes="the paper's deployment context: WARC pipeline → byte-level LM",
)
