"""Qwen2.5-32B: 64L d5120 40H(kv8) d_ff 27648 v152064, GQA + QKV bias.

[hf:Qwen/Qwen2.5-32B; config family verified via hf:Qwen/Qwen2.5-0.5B]
d_head = 5120/40 = 128.
"""
from repro.configs import ArchSpec, lm_shapes
from repro.models.transformer import TransformerConfig

CONFIG = TransformerConfig(
    name="qwen2.5-32b",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=8, d_head=128,
    d_ff=27648, vocab=152064, qkv_bias=True,
    rope_theta=1_000_000.0, dtype="bfloat16",
    # §Perf: 64-layer carries put train_4k at 17.9 GiB/chip on v5e-256
    train_microbatches=4, compact_opt_state=True,
)

REDUCED = TransformerConfig(
    name="qwen2.5-32b-reduced",
    n_layers=2, d_model=80, n_heads=5, n_kv_heads=1, d_head=16,
    d_ff=192, vocab=512, qkv_bias=True, dtype="float32", attn_chunk=64,
)

SPEC = ArchSpec(
    arch_id="qwen25_32b",
    family="lm",
    config=CONFIG,
    reduced=REDUCED,
    shapes=lm_shapes(),
    notes="largest dense LM in the pool; d_ff 27648 = 16·1728 shards "
          "evenly, 40 heads pad under the 16-way model axis",
)
