"""Elastic scaling + fault tolerance for multi-host training.

At thousands of chips, node loss is routine; the framework's contract is:

1. **Detection** — :class:`Heartbeat` tracks per-host liveness (in a real
   deployment each host's agent pings; here failures are injected by the
   chaos tests and the launcher).
2. **Shrink** — :func:`shrunken_mesh` rebuilds the largest valid mesh from
   the surviving device set, keeping the ``model`` axis intact (model
   shards are not re-partitionable without resharding every weight) and
   shrinking the ``data``/``pod`` axes, so the job continues at reduced
   global batch.
3. **Resume** — restore the last committed checkpoint with shardings for
   the *new* mesh (``checkpoint.restore(..., shardings=new)``), rescale
   the data loader's shard assignment, continue. Exactly-once data
   semantics come from the iterator cursor stored in the checkpoint.
4. **Stragglers** — :class:`StragglerMonitor` EMA-tracks step times; a
   step exceeding ``threshold ×`` EMA marks the host suspect. Mitigation
   at the launcher level: deprioritize its data shard (backup-task style,
   the MapReduce trick) and trigger preemptive checkpointing.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import numpy as np


class HostFailure(RuntimeError):
    def __init__(self, host_ids: list[int]):
        super().__init__(f"hosts failed: {host_ids}")
        self.host_ids = host_ids


class Heartbeat:
    """Liveness registry; a host is dead after ``timeout`` s of silence."""

    def __init__(self, n_hosts: int, timeout: float = 60.0,
                 clock=time.monotonic) -> None:
        self.timeout = timeout
        self._clock = clock
        now = clock()
        self._last_seen = {h: now for h in range(n_hosts)}

    def ping(self, host: int) -> None:
        self._last_seen[host] = self._clock()

    def dead_hosts(self) -> list[int]:
        now = self._clock()
        return [h for h, t in self._last_seen.items()
                if now - t > self.timeout]

    def check(self) -> None:
        dead = self.dead_hosts()
        if dead:
            raise HostFailure(dead)


def shrunken_mesh(devices: np.ndarray, axis_names: tuple[str, ...],
                  lost_device_ids: set[int]) -> jax.sharding.Mesh:
    """Largest valid mesh over surviving devices.

    The trailing (``model``) axis extent is preserved; the leading
    data-like axes shrink to use ⌊survivors / model⌋ × model devices.
    Survivors beyond the largest full hyper-row go idle (standby pool).
    """
    flat = devices.reshape(-1)
    survivors = [d for d in flat if d.id not in lost_device_ids]
    model = devices.shape[-1]
    usable_rows = len(survivors) // model
    if usable_rows == 0:
        raise RuntimeError("not enough devices for one model replica")
    chosen = np.array(survivors[:usable_rows * model]).reshape(
        usable_rows, model)
    if len(axis_names) == 2:
        return jax.sharding.Mesh(chosen, axis_names)
    # multi-pod (pod, data, model): fold rows back into (pod, data)
    pod = devices.shape[0]
    rows_per_pod = max(usable_rows // pod, 1)
    pods = min(pod, usable_rows // rows_per_pod)
    chosen = chosen[:pods * rows_per_pod * 1].reshape(
        pods, rows_per_pod, model)
    return jax.sharding.Mesh(chosen, axis_names)


@dataclass
class StragglerMonitor:
    """EMA step-time tracker with slowdown flagging."""

    threshold: float = 2.0
    alpha: float = 0.1
    ema: float | None = None
    slow_steps: int = field(default=0)
    events: list = field(default_factory=list)

    def observe(self, step: int, seconds: float) -> bool:
        """Record a step time; returns True when the step was a straggler."""
        if self.ema is None:
            self.ema = seconds
            return False
        slow = seconds > self.threshold * self.ema
        if slow:
            self.slow_steps += 1
            self.events.append((step, seconds, self.ema))
            # slow steps do not poison the EMA (one bad host would
            # otherwise ratchet the baseline up)
        else:
            self.ema = self.alpha * seconds + (1 - self.alpha) * self.ema
        return slow

    def should_checkpoint_early(self, consecutive: int = 3) -> bool:
        if len(self.events) < consecutive:
            return False
        recent = self.events[-consecutive:]
        return recent[-1][0] - recent[0][0] == consecutive - 1


def rescale_batch_for_mesh(global_batch: int, old_rows: int,
                           new_rows: int) -> int:
    """Keep per-replica batch constant when the data extent shrinks."""
    per_row = global_batch // old_rows
    return per_row * new_rows
