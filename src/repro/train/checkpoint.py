"""Sharded checkpoint save/restore (own format — no orbax/tensorstore offline).

Layout of a checkpoint directory::

    step_000123/
      metadata.json       # tree structure, shapes, dtypes, step, extras
      arrays/<idx>.npy    # one .npy per leaf, index matches metadata order
      COMMIT              # written last: restore ignores dirs without it

Properties needed at scale and how they're covered here:

* **atomicity** — leaves land in a temp dir, COMMIT marker written last,
  then an atomic rename; a crash mid-save never corrupts the latest good
  checkpoint.
* **async** — ``save_async`` snapshots to host memory (``jax.device_get``)
  and hands the serialization to a background thread, so the train loop
  only blocks for the device→host copy (checkpoint/compute overlap).
* **data-iterator state** — ``extras`` carries the pipeline cursor
  (shard index, record offset, rng state) so restarts are exactly
  resumable (see ``repro/data/loader.py``).
* **resharding restore** — leaves are restored host-side; callers pass
  ``shardings`` (possibly for a *different* mesh after an elastic
  shrink) and get ``jax.device_put`` arrays — checkpoint-reshard-resume.
* **rotation** — ``keep`` bounds disk usage.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
from typing import Any

import jax
import numpy as np

_COMMIT = "COMMIT"


def _leaf_paths(tree: Any) -> tuple[list[str], list, Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names = [jax.tree_util.keystr(path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return names, leaves, treedef


def save(directory: str, step: int, tree: Any,
         extras: dict | None = None, keep: int = 3) -> str:
    """Synchronous checkpoint write. Returns the checkpoint path."""
    host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)
    return _write(directory, step, host_tree, extras or {}, keep)


class AsyncCheckpointer:
    """Background-thread checkpoint writer with at-most-one in flight."""

    def __init__(self) -> None:
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, directory: str, step: int, tree: Any,
             extras: dict | None = None, keep: int = 3) -> None:
        self.wait()
        host_tree = jax.tree.map(lambda x: np.asarray(jax.device_get(x)), tree)

        def work():
            self.last_path = _write(directory, step, host_tree,
                                    extras or {}, keep)

        self._thread = threading.Thread(target=work, daemon=True)
        self._thread.start()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def _write(directory: str, step: int, host_tree: Any, extras: dict,
           keep: int) -> str:
    names, leaves, treedef = _leaf_paths(host_tree)
    final = os.path.join(directory, f"step_{step:09d}")
    tmp = final + ".tmp"
    arrays = os.path.join(tmp, "arrays")
    os.makedirs(arrays, exist_ok=True)
    meta = {
        "step": step,
        "names": names,
        "shapes": [list(np.shape(x)) for x in leaves],
        "dtypes": [str(np.asarray(x).dtype) for x in leaves],
        "extras": extras,
    }
    for i, leaf in enumerate(leaves):
        np.save(os.path.join(arrays, f"{i}.npy"), np.asarray(leaf),
                allow_pickle=False)
    with open(os.path.join(tmp, "metadata.json"), "w") as f:
        json.dump(meta, f)
    with open(os.path.join(tmp, _COMMIT), "w") as f:
        f.write("ok")
    if os.path.exists(final):
        shutil.rmtree(final)
    os.replace(tmp, final)
    _rotate(directory, keep)
    return final


def _rotate(directory: str, keep: int) -> None:
    ckpts = sorted(d for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for stale in ckpts[:-keep]:
        shutil.rmtree(os.path.join(directory, stale), ignore_errors=True)


def latest_step(directory: str) -> int | None:
    if not os.path.isdir(directory):
        return None
    steps = []
    for d in os.listdir(directory):
        if d.startswith("step_") and os.path.exists(
                os.path.join(directory, d, _COMMIT)):
            steps.append(int(d.split("_")[1]))
    return max(steps) if steps else None


def restore(directory: str, target_tree: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, dict]:
    """Restore into the structure of ``target_tree``.

    ``shardings`` (optional pytree of ``jax.sharding.Sharding`` matching
    the target) places leaves onto devices — including a *different* mesh
    than the one that saved (elastic reshard-on-restore).
    Returns (tree, extras).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no committed checkpoint in {directory}")
    path = os.path.join(directory, f"step_{step:09d}")
    with open(os.path.join(path, "metadata.json")) as f:
        meta = json.load(f)
    names, _, treedef = _leaf_paths(target_tree)
    if names != meta["names"]:
        raise ValueError(
            "checkpoint/target tree mismatch: "
            f"{set(names) ^ set(meta['names'])}")
    leaves = []
    for i, dtype_str in enumerate(meta["dtypes"]):
        arr = np.load(os.path.join(path, "arrays", f"{i}.npy"))
        if arr.dtype.name != dtype_str:
            # extended dtypes (bfloat16, fp8) serialize as raw void bytes;
            # the true dtype lives in metadata — view-cast it back
            import ml_dtypes  # ships with jax
            arr = arr.view(np.dtype(getattr(ml_dtypes, dtype_str, dtype_str)))
        leaves.append(arr)
    tree = jax.tree_util.tree_unflatten(treedef, leaves)
    if shardings is not None:
        tree = jax.tree.map(
            lambda x, s: jax.device_put(x, s), tree, shardings)
    return tree, meta["extras"]
