"""AdamW + global-norm clipping + schedules, from scratch (no optax offline).

State layout mirrors the param tree (m, v as like-shaped trees) so the
same sharding rules apply to optimizer state as to params — ZeRO-style
distribution falls out of passing the param PartitionSpecs for m/v.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    schedule: str = "cosine"       # cosine | constant | linear
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1
    #: compact optimizer state (8-bit-optimizer style [arXiv:2110.02861],
    #: adapted): momentum as int8 with per-row fp32 scales, second moment
    #: as bf16 — 12 B/param of Adam state become ~3.1 B/param. This is
    #: what lets the 235B config's train state fit a v5e-256 (§Perf).
    compact_state: bool = False


def lr_at(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    """Warmup + (cosine | linear | constant) decay, jit-friendly."""
    step = step.astype(jnp.float32)
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    if cfg.schedule == "cosine":
        decay = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
            1 + jnp.cos(math.pi * frac))
    elif cfg.schedule == "linear":
        decay = 1.0 - (1 - cfg.min_lr_ratio) * frac
    else:
        decay = jnp.ones_like(frac)
    return cfg.lr * warm * decay


def init_state(params: Any, compact: bool = False) -> dict:
    if not compact:
        zeros = jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "step": jnp.zeros((), jnp.int32)}
    return {
        "m_q": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.int8), params),
        "m_scale": jax.tree.map(
            lambda p: jnp.zeros(p.shape[:-1] or (1,), jnp.float32), params),
        "v": jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.bfloat16), params),
        "step": jnp.zeros((), jnp.int32),
    }


def _m_dequant(q: jax.Array, scale: jax.Array) -> jax.Array:
    s = scale if q.ndim == scale.ndim else scale[..., None]
    return q.astype(jnp.float32) * s


def _m_quant(m: jax.Array) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(m), axis=-1)
    scale = jnp.maximum(amax, 1e-20) / 127.0
    q = jnp.clip(jnp.round(m / scale[..., None]), -127, 127).astype(jnp.int8)
    if scale.ndim == 0:  # 1-D params: keep the scale rank-1 ((1,) leaves)
        scale = scale.reshape(1)
    return q, scale


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(
        jnp.sum(jnp.square(x.astype(jnp.float32)))
        for x in jax.tree.leaves(tree)))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def apply_updates(params: Any, grads: Any, state: dict,
                  cfg: AdamWConfig) -> tuple[Any, dict, dict]:
    """One AdamW step. Returns (params, state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.grad_clip)
    step = state["step"] + 1
    lr = lr_at(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)
    compact = "m_q" in state

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32)
        m_new = cfg.b1 * m + (1 - cfg.b1) * g32
        v_new = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m_new / b1c
        vh = v_new / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if cfg.weight_decay:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    if compact:
        flat_mq = treedef.flatten_up_to(state["m_q"])
        flat_ms = treedef.flatten_up_to(state["m_scale"])
        flat_m = [_m_dequant(q, s) for q, s in zip(flat_mq, flat_ms)]
    else:
        flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v
           in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    if compact:
        quantized = [_m_quant(o[1]) for o in out]
        new_state = {
            "m_q": treedef.unflatten([q for q, _ in quantized]),
            "m_scale": treedef.unflatten([s for _, s in quantized]),
            "v": treedef.unflatten([o[2].astype(jnp.bfloat16) for o in out]),
            "step": step,
        }
    else:
        new_state = {"m": treedef.unflatten([o[1] for o in out]),
                     "v": treedef.unflatten([o[2] for o in out]),
                     "step": step}
    return new_params, new_state, {"lr": lr, "grad_norm": gnorm}
