"""Error-feedback int8 gradient compression (cross-pod sync optimization).

On a multi-pod mesh the ``pod``-axis gradient all-reduce crosses DCI,
the scarcest bandwidth in the system. 1-bit/8-bit SGD with error feedback
[Seide et al., Interspeech'14; Karimireddy et al., arXiv:1901.09847]
quantizes the per-leaf gradient to int8 with a per-leaf scale, carries
the quantization residual into the next step, and all-reduces 1/4 of the
bytes (bf16→int8 would be 1/2; fp32→int8 is 1/4).

Two entry points:

* :func:`quantize` / :func:`dequantize` + :func:`ef_compress_tree` — the
  error-feedback transform as pure functions (unit-tested for the
  contraction property).
* :func:`compressed_psum` — a ``shard_map`` collective that performs the
  actual int8 all-reduce over a named mesh axis (used by the optimized
  train step on the ``pod`` axis; int32 accumulator avoids overflow at
  ≤ 2¹⁶ participants).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization. Returns (q, scale)."""
    x32 = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(x32)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def ef_compress_tree(grads: Any, errors: Any) -> tuple[Any, Any]:
    """Error-feedback compression over a gradient tree.

    Returns (decompressed_grads, new_errors); the decompressed grads are
    what the (simulated) wire carries, errors accumulate the residual.
    """
    def one(g, e):
        target = g.astype(jnp.float32) + e
        q, s = quantize(target)
        deq = dequantize(q, s)
        return deq.astype(g.dtype), target - deq

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(errors)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return (treedef.unflatten([o[0] for o in out]),
            treedef.unflatten([o[1] for o in out]))


def init_error_state(params: Any) -> Any:
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(x: jax.Array, axis_name: str) -> jax.Array:
    """int8-on-the-wire psum over ``axis_name`` (call inside shard_map).

    All participants must share one scale (summing int8 grids with
    different scales is meaningless), so a scalar ``pmax`` of the local
    amplitudes runs first — negligible traffic next to the payload. The
    int8 payload then all-reduces in int32 (no overflow below 2²⁴
    participants) and rescales once.
    """
    x32 = x.astype(jnp.float32)
    amax = jax.lax.pmax(jnp.max(jnp.abs(x32)), axis_name)
    scale = jnp.maximum(amax, 1e-12) / 127.0
    q = jnp.clip(jnp.round(x32 / scale), -127, 127).astype(jnp.int8)
    q_sum = jax.lax.psum(q.astype(jnp.int32), axis_name)
    return (q_sum.astype(jnp.float32) * scale).astype(x.dtype)
