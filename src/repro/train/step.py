"""Generic train step: grad → clip → AdamW, with microbatch accumulation.

``make_train_step`` closes over a family-specific ``loss_fn(params, batch)``
and returns a pure function suitable for ``jax.jit`` under a mesh.
Microbatching (``n_microbatches > 1``) accumulates grads with a
``lax.scan`` over leading-dim splits of the batch — bounding activation
memory for the 1M-token global batches while XLA overlaps the per-
microbatch backward with the gradient all-reduce of the previous one.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp

from .grad_compress import ef_compress_tree
from .optimizer import AdamWConfig, apply_updates, init_state


def init_train_state(params: Any, use_grad_compression: bool = False,
                     compact_state: bool = False) -> dict:
    state = {"params": params, "opt": init_state(params, compact_state)}
    if use_grad_compression:
        from .grad_compress import init_error_state
        state["ef_error"] = init_error_state(params)
    return state


def make_train_step(
    loss_fn: Callable[[Any, Any], jax.Array],
    opt_cfg: AdamWConfig,
    *,
    n_microbatches: int = 1,
    use_grad_compression: bool = False,
    accum_dtype: str = "float32",
) -> Callable[[dict, Any], tuple[dict, dict]]:
    """Returns step(state, batch) -> (state, metrics).

    ``batch`` is a pytree whose leaves have a leading global-batch dim
    divisible by ``n_microbatches``.
    """

    def grads_of(params, batch):
        return jax.value_and_grad(loss_fn)(params, batch)

    def step(state: dict, batch: Any) -> tuple[dict, dict]:
        params = state["params"]
        if n_microbatches == 1:
            loss, grads = grads_of(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape(n_microbatches,
                                    x.shape[0] // n_microbatches,
                                    *x.shape[1:]), batch)

            def acc_fn(carry, mb):
                loss_acc, g_acc = carry
                loss, g = grads_of(params, mb)
                return (loss_acc + loss,
                        jax.tree.map(
                            lambda a, b: a + b.astype(a.dtype), g_acc, g)), None

            # bf16 accumulation halves the accumulator's residency; with
            # ≤16 same-magnitude microbatch grads the rounding error is
            # ~1e-3 relative — the 235B config opts in (§Perf)
            acc_dt = getattr(jnp, accum_dtype)
            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dt), params)
            (loss, grads), _ = jax.lax.scan(
                acc_fn, (jnp.zeros((), jnp.float32), g0), micro)
            loss = loss / n_microbatches
            grads = jax.tree.map(lambda g: g / n_microbatches, grads)

        new_state = dict(state)
        if use_grad_compression:
            grads, new_err = ef_compress_tree(grads, state["ef_error"])
            new_state["ef_error"] = new_err

        new_params, new_opt, opt_metrics = apply_updates(
            params, grads, state["opt"], opt_cfg)
        new_state["params"] = new_params
        new_state["opt"] = new_opt
        return new_state, {"loss": loss, **opt_metrics}

    return step
