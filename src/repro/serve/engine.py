"""Batched LM serving engine: prefill + decode with a shared KV cache.

Small-scale but structurally faithful serving loop: a request queue is
drained into fixed-size batches (static shapes for jit), each batch is
prefilled token-by-token into the cache, then decoded greedily/with
temperature until EOS or ``max_new_tokens``. The decode step is the same
``decode_step`` the dry-run lowers at 32k-cache scale.

With tracing on (``repro.obs.trace.enable()``), each batch records
``serve.prefill`` / ``serve.decode`` span durations — one enabled()
check per batch, zero per-token cost.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.tokenizer import EOS_ID, BOS_ID, decode as tok_decode, encode
from repro.models import transformer as tf_mod
from repro.obs import trace as obs_trace


@dataclass
class Request:
    prompt: bytes
    max_new_tokens: int = 64
    out_tokens: list = field(default_factory=list)
    done: bool = False

    @property
    def text(self) -> bytes:
        return tok_decode(np.asarray(self.out_tokens, np.int32))


class ServeEngine:
    def __init__(self, cfg: tf_mod.TransformerConfig, params,
                 batch_size: int = 4, max_seq: int = 512,
                 temperature: float = 0.0, seed: int = 0) -> None:
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.max_seq = max_seq
        self.temperature = temperature
        self._key = jax.random.PRNGKey(seed)
        self._step = jax.jit(
            lambda p, c, t: tf_mod.decode_step(p, c, t, cfg),
            donate_argnums=1)
        self.stats = {"requests": 0, "tokens_generated": 0, "batches": 0,
                      "decode_s": 0.0}

    def _sample(self, logits: jax.Array) -> jax.Array:
        if self.temperature <= 0:
            return logits.argmax(-1).astype(jnp.int32)
        self._key, sub = jax.random.split(self._key)
        return jax.random.categorical(
            sub, logits / self.temperature).astype(jnp.int32)

    def run_batch(self, requests: list[Request]) -> list[Request]:
        B = self.batch_size
        requests = requests[:B]
        prompts = [np.concatenate(([BOS_ID], encode(r.prompt)))
                   for r in requests]
        while len(prompts) < B:  # pad slots replay the first prompt
            prompts.append(prompts[0])
        max_prompt = max(p.size for p in prompts)
        cache = tf_mod.init_cache(self.cfg, B, self.max_seq,
                                  dtype=self.cfg.jnp_dtype)
        traced = obs_trace.enabled()  # one check per batch, not per token
        t0 = time.perf_counter()
        # prefill token-by-token (cache fills positionally; static shapes)
        tok = jnp.asarray([p[0] for p in prompts], jnp.int32)
        for i in range(max_prompt):
            logits, cache = self._step(self.params, cache, tok)
            nxt_in = [p[i + 1] if i + 1 < p.size else None for p in prompts]
            sampled = self._sample(logits)
            tok = jnp.asarray(
                [n if n is not None else int(sampled[j])
                 for j, n in enumerate(nxt_in)], jnp.int32)
        t_prefill = time.perf_counter()
        if traced:
            obs_trace.add("serve.prefill", t_prefill - t0)
        # decode
        budget = max(r.max_new_tokens for r in requests)
        for _ in range(min(budget, self.max_seq - max_prompt - 1)):
            for j, r in enumerate(requests):
                if not r.done:
                    r.out_tokens.append(int(tok[j]))
                    if tok[j] == EOS_ID or len(r.out_tokens) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in requests):
                break
            logits, cache = self._step(self.params, cache, tok)
            tok = self._sample(logits)
        t_end = time.perf_counter()
        if traced:
            obs_trace.add("serve.decode", t_end - t_prefill)
        dt = t_end - t0
        self.stats["requests"] += len(requests)
        self.stats["tokens_generated"] += sum(
            len(r.out_tokens) for r in requests)
        self.stats["batches"] += 1
        self.stats["decode_s"] += dt
        return requests

    def serve(self, requests: list[Request]) -> list[Request]:
        out = []
        for i in range(0, len(requests), self.batch_size):
            out.extend(self.run_batch(requests[i:i + self.batch_size]))
        return out
