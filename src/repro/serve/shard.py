"""``repro.serve.shard`` — one gateway scheduler shard (DESIGN.md §12).

PR 9 splits the PR 3 single-scheduler :class:`~repro.serve.archive.
ArchiveGateway` into a *router* (still ``serve/archive.py``) and a pool
of :class:`ShardScheduler` instances defined here. A shard is the unit
of serving **and** the unit of failure:

* it owns one :class:`~repro.index.query.QueryEngine` (and therefore its
  readers and device dispatches) plus one drain thread;
* it runs its **own admission budget** — a queue-depth bound and an
  optional pending-byte budget (estimated scan bytes per *unique* queued
  scan identity, so coalesced duplicates are free) — and raises a typed,
  shard-tagged :class:`GatewayOverloaded` instead of contributing to one
  global cliff;
* it keeps its **own in-flight registry**, so request coalescing works
  exactly as before *within* the shard — and the router's scan-identity
  affinity hashing guarantees identical scans always land on the same
  shard, which is why sharding doesn't cost any coalescing;
* it is **supervised**: the drain thread updates a heartbeat each cycle,
  an abnormal exit (including the injected
  ``REPRO_FAULT_SHARD_KILL`` death, spec captured at shard-spawn time
  like the PR 6 worker-kill hooks) marks the shard dirty-dead, and the
  router reaps it via :meth:`take_orphans` — every queued, serving and
  coalesce-attached ticket comes back exactly once for re-drive.

The serving machinery (batch formation, deadline shedding, prefilter
planning, chunked cache-aware fetch, shared multi-pattern kernel
dispatch, host verify, respond) is the PR 3–8 code moved here verbatim
in behaviour; responses stay byte-identical to a synchronous
:class:`QueryEngine` run.

Concurrency note: shards share one device, and JAX dispatch is cheapest
(and unconditionally thread-safe) when serialized — so the kernel
dispatch stage alone runs under a process-wide lock. The cliff this PR
kills is queue wait, not kernel time (BENCH_serve.json: kernel p50 flat
at ~8 ms while queue_wait p99 grew 7×), so serializing only the
dispatch keeps the win intact.
"""
from __future__ import annotations

import os
import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.warc.errors import RecordReadError
from repro.index.query import PatternHit, QueryEngine, QueryPlan
from repro.index.service import QueryRequest, QueryResponse
from repro.obs import flight as obs_flight
from repro.obs import trace as obs_trace

__all__ = ["GatewayClosed", "GatewayOverloaded", "GatewayShardDown",
           "GatewayTimeout", "ShardKilled", "ShardScheduler"]

#: env hook armed by :func:`repro.testing.faults.arm_scheduler_shard_kill`
FAULT_SHARD_KILL_ENV = "REPRO_FAULT_SHARD_KILL"

#: shards share one device; serialize only the Pallas dispatch stage
_DISPATCH_LOCK = threading.Lock()


class GatewayOverloaded(RuntimeError):
    """Admission budget exhausted: backpressure instead of unbounded
    growth. Per-shard since PR 9 — ``shard`` names the scheduler shard
    that rejected, ``reason`` is ``"depth"`` (queue bound) or
    ``"bytes"`` (pending-scan byte budget)."""

    def __init__(self, msg: str, *, shard: int | None = None,
                 reason: str = "depth") -> None:
        super().__init__(msg)
        self.shard = shard
        self.reason = reason


class GatewayClosed(RuntimeError):
    """Request submitted to (or still pending in) a closed gateway."""


class GatewayTimeout(RuntimeError):
    """Per-request deadline expired before the scan could resolve it.

    Distinct from :class:`GatewayOverloaded` (rejected at admission) —
    a timed-out request was *accepted* but couldn't be served in time;
    the caller can tell load shedding apart from slow serving.
    """


class GatewayShardDown(RuntimeError):
    """A scheduler shard died and the request could not be recovered.

    Raised (as a future's exception, never silently dropped) only when
    the single allowed re-drive also failed — the re-driven shard died
    too, or every shard is permanently down. ``shard`` names the last
    shard that failed the request.
    """

    def __init__(self, msg: str, *, shard: int | None = None) -> None:
        super().__init__(msg)
        self.shard = shard


class ShardKilled(BaseException):
    """Injected shard death (``REPRO_FAULT_SHARD_KILL``).

    Derives :class:`BaseException` so the per-batch ``except
    BaseException`` isolation in the drain loop can explicitly re-raise
    it: the injected fault must kill the *thread* (exercising the
    reap/re-drive path), not be absorbed as a batch error.
    """


@dataclass
class _Ticket:
    """One submitted request and its completion future."""

    request: QueryRequest
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)
    deadline: float | None = None  # absolute perf_counter time, or None
    # request-scoped tracing (None when trace_requests=False): the root
    # span carries the trace across the submit-thread → scheduler-thread
    # boundary; wait_span times queue residency (opened by the submitter,
    # closed by the scheduler)
    span: obs_trace.Span | None = None
    wait_span: obs_trace.Span | None = None
    # routing state: the shard currently responsible, and whether the
    # ticket already consumed its single allowed re-drive
    shard: int | None = None
    redriven: bool = False

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


class _StageCM:
    """``with shard._stage("gw.cache_fill") as sp:`` — span + stage
    histogram, or a no-op when the gateway isn't tracing."""

    __slots__ = ("_owner", "span")

    def __init__(self, owner, name: str, parent=None, attrs=None):
        self._owner = owner
        self.span = obs_trace.start_span(name, parent, attrs=attrs)

    def __enter__(self) -> obs_trace.Span:
        return self.span

    def __exit__(self, *exc) -> None:
        self._owner._end_span(self.span)


class _NullCM:
    __slots__ = ()
    span = None

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        pass


_NULL_CM = _NullCM()


class ShardScheduler:
    """One supervised scheduler shard: queue + budgets + engine + thread.

    Created, started and reaped by :class:`~repro.serve.archive.
    ArchiveGateway`; client threads only ever touch :meth:`admit` (via
    the router) and the returned futures.
    """

    def __init__(self, shard_id: int, *, engine: QueryEngine, cache,
                 metrics, max_pending: int = 256,
                 byte_budget: int | None = None,
                 est_scan_bytes: int = 1 << 20,
                 max_batch_requests: int = 16,
                 poll_interval_s: float = 0.02,
                 trace_requests: bool = True,
                 flight_recorder: obs_flight.FlightRecorder | None = None,
                 slo_p99_s: float | None = None,
                 queue_highwater: int | None = None) -> None:
        self.shard_id = shard_id
        self.engine = engine
        self.index = engine.index
        self.cache = cache        # shared (sharded) record cache
        self.metrics = metrics    # shared gateway metrics
        self.max_pending = max(1, max_pending)
        self.byte_budget = byte_budget
        self.est_scan_bytes = max(1, int(est_scan_bytes))
        self.max_batch_requests = max(1, max_batch_requests)
        self._poll = poll_interval_s
        self._trace = bool(trace_requests)
        self._flight = flight_recorder if flight_recorder is not None \
            else obs_flight.recorder()
        self._slo_p99_s = slo_p99_s
        self._highwater = queue_highwater if queue_highwater is not None \
            else max(4, (self.max_pending * 3) // 4)
        self._above_highwater = False
        # admission state, all under one lock/condition: queued depth,
        # charged unique scan keys (refcounted), pending byte charge
        self._lock = threading.Lock()
        self._space = threading.Condition(self._lock)
        self._queue: "queue.Queue[_Ticket]" = queue.Queue()  # depth-bounded
        self._depth = 0                                      # via _depth
        self._queued_keys: dict[tuple, int] = {}
        self._pending_bytes = 0
        self._inflight: dict[tuple, list[_Ticket]] = {}
        self._serving: list[_Ticket] = []
        # lifecycle flags (written under self._lock where racing reap)
        self.closed = False        # close() called — reject new work
        self.down = False          # permanently down (respawn budget spent)
        self.dead = False          # drain thread exited abnormally
        self._reaped = False       # take_orphans() already collected
        self.respawns = 0
        self.batches_served = 0    # drained batches (fault nth counts these)
        self.heartbeat = time.perf_counter()
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    # -- lifecycle --------------------------------------------------------
    def start(self) -> None:
        """Spawn the drain thread. The shard-kill fault spec is captured
        from the environment *now* (arm-before-spawn, exactly like the
        PR 6 worker hooks) so re-arming after spawn cannot retroactively
        affect a running shard."""
        fault_spec = os.environ.get(FAULT_SHARD_KILL_ENV)
        self._stop.clear()
        self._thread = threading.Thread(
            target=self._run, args=(fault_spec,), daemon=True,
            name=f"gw-shard-{self.shard_id}")
        self._thread.start()

    def alive(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def respawn(self) -> None:
        """Restart after a dirty death (router-driven, post-reap)."""
        with self._lock:
            self.dead = False
            self._reaped = False
            self.respawns += 1
        self.start()

    def mark_down(self) -> None:
        """Permanently retire the shard (respawn budget exhausted)."""
        with self._space:
            self.down = True
            self._space.notify_all()

    # -- tracing plumbing -------------------------------------------------
    def _end_span(self, span: obs_trace.Span | None) -> None:
        """Finish a span into the flight recorder and fold its duration
        into the ``gateway.stage.*`` histogram of the same name."""
        if span is not None:
            self.metrics.observe_stage(span.name,
                                       span.finish(recorder=self._flight))

    def _stage(self, name: str, parent=None, attrs=None):
        """Context manager for one scheduler-side stage (no-op untraced)."""
        if not self._trace:
            return _NULL_CM
        return _StageCM(self, name, parent, attrs)

    def _trip(self, reason: str, attrs: dict | None = None) -> None:
        """Anomaly: auto-dump the flight recorder, tagged with the shard
        (rate-limited inside)."""
        attrs = dict(attrs or {})
        attrs.setdefault("shard", self.shard_id)
        if self._flight.trip(reason, attrs,
                             tag=f"shard{self.shard_id}") is not None:
            self.metrics.inc("flight_dumps")

    def _note_queue_depth(self, depth: int) -> None:
        self.metrics.gauge_set(f"shard{self.shard_id}.queue_depth", depth)
        self.metrics.note_global_depth(depth)
        if depth >= self._highwater:
            if not self._above_highwater:  # trip on the crossing, not
                self._above_highwater = True  # on every submit above it
                self._trip("queue_highwater",
                           {"depth": depth, "highwater": self._highwater})
        else:
            self._above_highwater = False

    # -- admission (called by the router, any client thread) --------------
    def admit(self, ticket: _Ticket, *, block: bool = True,
              timeout: float | None = None,
              force: bool = False) -> tuple[str, int]:
        """Admit one ticket under this shard's budgets.

        Returns ``("attached", n_waiters)`` when the ticket coalesced
        onto an already-executing identical scan (no queue slot, no
        budget charge), or ``("queued", depth)`` when it entered the
        queue. Budget accounting charges the queue-depth bound per
        ticket and the byte budget per *unique* queued scan identity
        (``est_scan_bytes`` each) — a duplicate of an already-queued
        scan is free, so coalescing-friendly traffic is never the
        traffic that gets shed.

        ``force=True`` (re-drive path) bypasses the budget checks: a
        recovered ticket was already admitted once and must not bounce.
        Raises :class:`GatewayShardDown` if the shard is retired and
        :class:`GatewayOverloaded` (shard-tagged) over budget.
        """
        key = ticket.request.scan_key()
        deadline = (time.perf_counter() + timeout) if timeout is not None \
            else None
        with self._space:
            while True:
                if self.down or self.closed:
                    raise GatewayShardDown(
                        f"shard {self.shard_id} is retired",
                        shard=self.shard_id)
                waiters = self._inflight.get(key)
                if waiters is not None:
                    # in-flight coalescing fast path: join the executing
                    # scan directly, never entering the queue
                    waiters.append(ticket)
                    ticket.shard = self.shard_id
                    self.metrics.inc("requests")
                    self.metrics.inc("coalesced")
                    return ("attached", len(waiters))
                over_depth = self._depth >= self.max_pending
                charged = key in self._queued_keys
                charge = 0 if charged else self.est_scan_bytes
                over_bytes = (self.byte_budget is not None and not charged
                              and self._pending_bytes + charge >
                              self.byte_budget)
                if force or not (over_depth or over_bytes):
                    self._depth += 1
                    self._queued_keys[key] = self._queued_keys.get(key, 0) + 1
                    if not charged:
                        self._pending_bytes += self.est_scan_bytes
                    ticket.shard = self.shard_id
                    self._queue.put(ticket)
                    depth = self._depth
                    self.metrics.inc("requests")
                    break
                if not block:
                    self._reject(over_bytes and not over_depth)
                remaining = None if deadline is None \
                    else deadline - time.perf_counter()
                if remaining is not None and remaining <= 0:
                    self._reject(over_bytes and not over_depth)
                self._space.wait(remaining if remaining is not None
                                 else self._poll)
        self._note_queue_depth(depth)
        return ("queued", depth)

    def _reject(self, bytes_bound: bool) -> None:
        reason = "bytes" if bytes_bound else "depth"
        self.metrics.inc("rejected")
        if bytes_bound:
            self.metrics.inc("rejected_bytes")
        self._trip("gateway_overloaded",
                   {"max_pending": self.max_pending, "reason": reason,
                    "pending_bytes": self._pending_bytes})
        if bytes_bound:
            raise GatewayOverloaded(
                f"shard {self.shard_id} pending-scan byte budget full "
                f"({self._pending_bytes}/{self.byte_budget} bytes)",
                shard=self.shard_id, reason="bytes")
        raise GatewayOverloaded(
            f"shard {self.shard_id} admission queue full "
            f"({self.max_pending} pending)",
            shard=self.shard_id, reason="depth")

    def _uncharge(self, batch: list[_Ticket]) -> None:
        """Release the admission budget for a drained batch."""
        with self._space:
            for ticket in batch:
                key = ticket.request.scan_key()
                self._depth -= 1
                left = self._queued_keys.get(key, 0) - 1
                if left <= 0:
                    self._queued_keys.pop(key, None)
                    self._pending_bytes -= self.est_scan_bytes
                else:
                    self._queued_keys[key] = left
            if self._pending_bytes < 0:
                self._pending_bytes = 0
            self._space.notify_all()

    def pending(self) -> int:
        return self._depth

    # -- drain loop -------------------------------------------------------
    def _run(self, fault_spec: str | None) -> None:
        try:
            self._drain(fault_spec)
        except ShardKilled:
            with self._lock:
                self.dead = True  # dirty death: supervisor reaps + re-drives
        except BaseException:  # pragma: no cover - defensive
            self.metrics.inc("errors")
            with self._lock:
                self.dead = True

    def _drain(self, fault_spec: str | None) -> None:
        while True:
            self.heartbeat = time.perf_counter()
            try:
                first = self._queue.get(timeout=self._poll)
            except queue.Empty:
                if self._stop.is_set():
                    return  # drained: every accepted request was served
                continue
            batch = [first]
            while len(batch) < self.max_batch_requests:
                try:
                    batch.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            self._uncharge(batch)
            self._note_queue_depth(self._depth)
            self.batches_served += 1
            self._serving = batch
            try:
                self._serve_batch(batch, fault_spec)
            except ShardKilled:
                raise  # injected death: leave _serving/_inflight for reap
            except BaseException:  # the scheduler must outlive any batch
                self.metrics.inc("errors")
            self._serving = []

    def _timeout(self, ticket: _Ticket) -> None:
        """Resolve one expired ticket (caller already claimed the future)."""
        waited = time.perf_counter() - ticket.t_submit
        ticket.future.set_exception(GatewayTimeout(
            f"deadline expired after {waited:.3f}s"))
        self.metrics.inc("timeouts")
        if ticket.span is not None:
            # marker child + closed root *before* the trip, so the dump
            # holds the offending request's complete span tree
            with self._stage("gw.timeout", ticket.span,
                             attrs={"waited_s": waited}):
                pass
            ticket.span.set_attr("error", "GatewayTimeout")
            ticket.span.finish(recorder=self._flight)
        self._trip("gateway_timeout",
                   {"waited_s": waited,
                    "trace_id": ticket.span.trace_id if ticket.span else None})

    def _serve_batch(self, tickets: list[_Ticket],
                     fault_spec: str | None = None) -> None:
        if not self._trace:
            self._serve_batch_body(tickets, fault_spec)
            return
        # the batch roots its own trace (a scan serves many requests —
        # span trees are strict, so waiter roots *link* to it via attrs
        # rather than parent it); installing it as the context's current
        # span lets every stage below default-parent to it
        for ticket in tickets:
            if ticket.wait_span is not None:  # queue residency ends here
                self._end_span(ticket.wait_span)
                ticket.wait_span = None
        batch_span = obs_trace.start_span(
            "gw.scan_batch", obs_trace.ROOT,
            attrs={"shard": self.shard_id,
                   "n_tickets": len(tickets),
                   "waiter_traces": [t.span.trace_id for t in tickets
                                     if t.span is not None]})
        try:
            with obs_trace.use_span(batch_span):
                self._serve_batch_body(tickets, fault_spec)
        finally:
            self._end_span(batch_span)
        if self._slo_p99_s is not None and self.metrics.latency_count() >= 32:
            p99 = self.metrics.latency_s(99)
            self.metrics.gauge_set("latency_p99_s", p99)
            if p99 > self._slo_p99_s:
                self._trip("slo_p99", {"p99_s": p99,
                                       "slo_s": self._slo_p99_s})

    def _maybe_kill(self, fault_spec: str | None) -> None:
        """Injected mid-batch death: fires *after* the in-flight registry
        is published (so coalesce-attached waiters are orphaned too) and
        before any waiter resolves — the worst moment the re-drive
        protocol must survive. One-shot across every shard sharing the
        latch: losers of the O_EXCL race keep serving."""
        if not fault_spec:
            return
        latch, _, nth = fault_spec.rpartition(":")
        if not latch or self.batches_served != int(nth):
            return
        try:
            fd = os.open(latch, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
        except OSError:
            return  # another shard already died for this latch
        os.close(fd)
        raise ShardKilled(
            f"shard {self.shard_id} killed mid-batch by fault injection")

    def _serve_batch_body(self, tickets: list[_Ticket],
                          fault_spec: str | None = None) -> None:
        form = self._stage("gw.batch_form").__enter__()
        # shed already-expired tickets before planning anything: under
        # overload the queue ages, and scanning for a waiter that stopped
        # caring only makes every later deadline worse
        now = time.perf_counter()
        live: list[_Ticket] = []
        for ticket in tickets:
            if ticket.expired(now):
                if ticket.future.set_running_or_notify_cancel():
                    self._timeout(ticket)
            else:
                live.append(ticket)
        if not live:
            self._end_span(form)
            return
        tickets = live
        # group by scan identity; first occurrence keeps submission order
        groups: dict[tuple, list[_Ticket]] = {}
        for ticket in tickets:
            key = ticket.request.scan_key()
            if key in groups:
                groups[key].append(ticket)
                self.metrics.inc("coalesced")
            else:
                groups[key] = [ticket]
        with self._lock:
            # publish the in-flight registry: identical requests submitted
            # while we scan attach to these lists and never enter the queue
            self._inflight.update(groups)
            self._serving = []  # tickets now owned by _inflight, not both
        self._end_span(form)
        self._maybe_kill(fault_spec)
        self.metrics.inc("scan_batches")
        self.metrics.inc("unique_scans", len(groups))
        results: dict[tuple, list[PatternHit]] = {}
        failures: dict[tuple, BaseException] = {}
        try:
            plans = {}
            for key, group_waiters in groups.items():
                try:
                    with self._stage("gw.prefilter",
                                     attrs={"pattern":
                                            repr(key[0][:64])}):
                        plans[key] = self._plan(group_waiters[0].request)
                except Exception as exc:  # malformed query: fail only its
                    failures[key] = exc   # own waiters, not the batch
                    self.metrics.inc("errors")
            results, scan_failures = self._execute_plans(plans)
            for key, exc in scan_failures.items():
                failures.setdefault(key, exc)
        except ShardKilled:
            raise  # _inflight deliberately left populated for the reap
        except BaseException as exc:  # scan failure: resolve all, keep serving
            self.metrics.inc("errors")
            failures = {key: failures.get(key, exc) for key in groups}
            with self._lock:
                waiters = {key: self._inflight.pop(key) for key in groups
                           if key in self._inflight}
        else:
            with self._lock:
                waiters = {key: self._inflight.pop(key) for key in groups
                           if key in self._inflight}
        with self._stage("gw.respond"):
            now = time.perf_counter()
            for key, tickets_for_key in waiters.items():
                hits = results.get(key, [])
                error = failures.get(key)
                # rank: most matches first, index order breaks ties
                # (stable) — identical to IndexQueryService
                ranked = sorted(hits, key=lambda h: -h.n_matches)
                for ticket in tickets_for_key:
                    # a client may have cancel()ed while we scanned;
                    # claiming the future first makes the set_* below
                    # race-free (and a cancelled ticket must not kill the
                    # scheduler)
                    if not ticket.future.set_running_or_notify_cancel():
                        if ticket.span is not None:
                            ticket.span.set_attr("cancelled", True)
                            ticket.span.finish(recorder=self._flight)
                        continue
                    if error is not None:
                        ticket.future.set_exception(error)
                        if ticket.span is not None:
                            ticket.span.set_attr("error",
                                                 type(error).__name__)
                            ticket.span.finish(recorder=self._flight)
                        continue
                    if ticket.expired(now):  # scan outlived the deadline
                        self._timeout(ticket)
                        continue
                    latency = now - ticket.t_submit
                    ticket.future.set_result(QueryResponse(
                        request=ticket.request,
                        hits=ranked[:ticket.request.top_k],
                        total_matches=len(hits), latency_s=latency))
                    self.metrics.observe_latency(latency)
                    self.metrics.inc("responses")
                    if ticket.span is not None:
                        ticket.span.finish(recorder=self._flight)

    def _plan(self, request: QueryRequest) -> QueryPlan:
        if request.regex:
            return self.engine.plan_regex(request.pattern, request.filters,
                                          prefilter=request.prefilter)
        return self.engine.plan(request.pattern, request.filters,
                                prefilter=request.prefilter)

    # -- cache-aware fetch ----------------------------------------------
    def _fetch(self, row: int) -> bytes:
        key = (int(self.index.shard_id[row]), int(self.index.offset[row]))
        data = self.cache.get(key)
        if data is None:
            data = self.engine._fetch(row)
            self.cache.put(key, data)
            self.metrics.inc("records_fetched")
            if self.engine.store is not None:  # served from row-groups
                self.metrics.inc("store_fetches")
        return data

    def _fetch_chunk(self, chunk: list[tuple[tuple, int]]
                     ) -> tuple[dict[int, bytes], list[tuple[tuple, int]]]:
        """Fetch one chunk's payloads, quarantining unreadable rows.

        A row whose record can't be parsed (:class:`RecordReadError` —
        damaged member, bad framing) is dropped from the chunk instead
        of failing any query: a damaged record simply can't match, and
        every plan sharing the row keeps its other candidates. Counted
        under ``read_errors`` (fetch attempts that failed) and
        ``quarantined_rows`` (distinct rows skipped).
        """
        bufs: dict[int, bytes] = {}
        dead: set[int] = set()
        with self._stage("gw.cache_fill",
                         attrs={"rows": len(chunk)}) as sp:
            for _, row in chunk:  # dedupe: shared rows fetched once
                if row in bufs or row in dead:
                    continue
                try:
                    bufs[row] = self._fetch(row)
                except RecordReadError:
                    dead.add(row)
                    self.metrics.inc("read_errors")
            if sp is not None:
                sp.set_attr("fetched", len(bufs))
        if not dead:
            return bufs, chunk
        self.metrics.inc("quarantined_rows", len(dead))
        return bufs, [(key, row) for key, row in chunk if row not in dead]

    def _fail_chunk(self, chunk: list[tuple[tuple, int]],
                    exc: BaseException,
                    failures: dict[tuple, BaseException]) -> None:
        self.metrics.inc("errors")
        for key in {key for key, _ in chunk}:
            failures.setdefault(key, exc)

    # -- cross-request scan ----------------------------------------------
    def _execute_plans(self, plans: dict[tuple, QueryPlan]
                       ) -> tuple[dict[tuple, list[PatternHit]],
                                  dict[tuple, BaseException]]:
        """Scan all plans' candidates through *shared* kernel dispatches.

        Every (plan, candidate row) pair becomes one scan item; items
        from different plans are chunked together under the engine's
        batch_records / batch_bytes limits (sized from the index's
        ``uncomp_len`` column, so chunking decides before any payload is
        decompressed) and each chunk goes through one multi-pattern
        dispatch per width bucket — the request count no longer shows up
        in the dispatch count. Payloads are fetched per chunk in
        shard/offset order (deduped inside the chunk, the cache absorbs
        repeats across chunks), scanned and verified, then released —
        resident memory stays bounded by chunk size + cache budget, like
        the sync engine's streaming execute.

        Failure isolation: unreadable rows are skipped per-row (see
        :meth:`_fetch_chunk`); a chunk whose scan/verify raises fails
        only the plans with items in that chunk (returned in the second
        element), never the whole batch — one poisoned query can't take
        down its co-batched neighbours.
        """
        results: dict[tuple, list[PatternHit]] = {key: [] for key in plans}
        failures: dict[tuple, BaseException] = {}
        kernel_items: list[tuple[tuple, int]] = []  # (plan key, row)
        host_items: list[tuple[tuple, int]] = []
        for key, plan in plans.items():
            target = (host_items if plan.needs_host_scan
                      or not self.engine.use_kernel else kernel_items)
            target.extend((key, int(r)) for r in plan.rows)

        def fetch_order(item: tuple[tuple, int]) -> tuple[int, int]:
            return (int(self.index.shard_id[item[1]]),
                    int(self.index.offset[item[1]]))

        kernel_items.sort(key=fetch_order)
        host_items.sort(key=fetch_order)

        n_scanned = bytes_scanned = 0
        for chunk in self._chunks(kernel_items):
            chunk = [item for item in chunk if item[0] not in failures]
            if not chunk:
                continue
            try:
                bufs, chunk = self._fetch_chunk(chunk)
                if chunk:
                    self._scan_chunk(chunk, plans, bufs, results)
                n_scanned += len(chunk)
                bytes_scanned += sum(len(bufs[row]) for _, row in chunk)
            except Exception as exc:
                self._fail_chunk(chunk, exc, failures)

        # host path (literal sweep / regex gate, no device work): same
        # chunked fetch-dedup-release structure as the kernel path
        for chunk in self._chunks(host_items):
            chunk = [item for item in chunk if item[0] not in failures]
            if not chunk:
                continue
            try:
                bufs, chunk = self._fetch_chunk(chunk)
                with self._stage("gw.host_verify",
                                 attrs={"rows": len(chunk)}):
                    for key, row in chunk:
                        plan = plans[key]
                        buf = bufs[row]
                        self._finish_row(plan, key, row, buf,
                                         plan.host_scan(buf), results)
                        n_scanned += 1
                        bytes_scanned += len(buf)
            except Exception as exc:
                self._fail_chunk(chunk, exc, failures)

        self.metrics.inc("host_scans", len(host_items))
        self.metrics.inc("records_scanned", n_scanned)
        self.metrics.inc("bytes_scanned", bytes_scanned)
        for hits in results.values():
            hits.sort(key=lambda h: h.index_row)
        return results, failures

    def _chunks(self, items: list[tuple[tuple, int]]
                ) -> "list[list[tuple[tuple, int]]]":
        """Split scan items under the engine's batch record/byte limits,
        sized from the index (``uncomp_len`` == payload length)."""
        chunks: list[list[tuple[tuple, int]]] = []
        current: list[tuple[tuple, int]] = []
        pending = 0
        for item in items:
            current.append(item)
            pending += int(self.index.uncomp_len[item[1]])
            if (len(current) >= self.engine.batch_records
                    or pending >= self.engine.batch_bytes):
                chunks.append(current)
                current, pending = [], 0
        if current:
            chunks.append(current)
        return chunks

    def _finish_row(self, plan: QueryPlan, key: tuple, row: int, buf: bytes,
                    lit_positions: np.ndarray,
                    results: dict[tuple, list[PatternHit]]) -> None:
        final, first_len = plan.verify(buf, lit_positions)
        if final.size:
            results[key].append(self.engine.make_hit(row, buf, final,
                                                     first_len))

    def _scan_chunk(self, chunk: list[tuple[tuple, int]],
                    plans: dict[tuple, QueryPlan], bufs: dict[int, bytes],
                    results: dict[tuple, list[PatternHit]]) -> None:
        from repro.kernels.bucketing import dispatch_count
        from repro.kernels.pattern_scan import find_pattern_masks_multi

        chunk_bufs = [bufs[row] for _, row in chunk]
        chunk_pats = [plans[key].kernel_pattern for key, _ in chunk]
        with self._stage("gw.kernel_dispatch",
                         attrs={"rows": len(chunk),
                                "shard": self.shard_id}) as sp:
            with _DISPATCH_LOCK:  # shards share one device (module note)
                masks = find_pattern_masks_multi(
                    chunk_bufs, chunk_pats, block=self.engine.scan_block,
                    interpret=self.engine.interpret)
            dispatches = dispatch_count(
                [len(b) for b in chunk_bufs], self.engine.scan_block)
            if sp is not None:
                sp.set_attr("dispatches", dispatches)
        self.metrics.inc("kernel_dispatches", dispatches)
        with self._stage("gw.host_verify", attrs={"rows": len(chunk)}):
            for (key, row), mask, buf in zip(chunk, masks, chunk_bufs):
                self._finish_row(plans[key], key, row, buf,
                                 np.flatnonzero(mask), results)

    # -- reap + teardown --------------------------------------------------
    def take_orphans(self) -> list[_Ticket]:
        """Collect every unresolved ticket this shard is responsible for
        — queued, mid-serve, and coalesce-attached — exactly once.

        Idempotent: the first caller after a death gets the full set and
        resets the admission accounting; later calls get ``[]``. Safe to
        call on a live shard only from ``close()`` after the drain
        thread has exited.
        """
        with self._space:
            if self._reaped:
                return []
            self._reaped = True
            orphans: list[_Ticket] = []
            seen: set[int] = set()

            def _add(ticket: _Ticket) -> None:
                if id(ticket) not in seen:
                    seen.add(id(ticket))
                    orphans.append(ticket)

            for ticket in self._serving:
                _add(ticket)
            for waiters in self._inflight.values():
                for ticket in waiters:
                    _add(ticket)
            while True:
                try:
                    _add(self._queue.get_nowait())
                except queue.Empty:
                    break
            self._serving = []
            self._inflight.clear()
            self._queued_keys.clear()
            self._depth = 0
            self._pending_bytes = 0
            self._space.notify_all()
        return [t for t in orphans if not t.future.done()]

    def fail_queued(self) -> None:
        """Fail every currently queued ticket with :class:`GatewayClosed`
        (the queue hands tickets to exactly one caller each, so this can
        race a live scheduler without double-resolving any future)."""
        drained: list[_Ticket] = []
        while True:
            try:
                ticket = self._queue.get_nowait()
            except queue.Empty:
                break
            drained.append(ticket)
        if drained:
            self._uncharge(drained)
        for ticket in drained:
            if ticket.future.set_running_or_notify_cancel():
                ticket.future.set_exception(GatewayClosed("gateway closed"))

    def close(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the drain thread; by default serve everything queued.

        Raises ``TimeoutError`` if the shard is still mid-scan after
        ``timeout`` — the engine is left open for it; call ``close``
        again to retry teardown.
        """
        with self._space:
            self.closed = True  # admit() now raises GatewayShardDown
            self._space.notify_all()
        if not drain:
            self.fail_queued()
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout)
            if self._thread.is_alive():
                raise TimeoutError(
                    f"shard {self.shard_id} still serving after {timeout}s; "
                    f"engine left open — retry close() to finish teardown")
        # a submit that raced close() may have enqueued after the drain
        # thread exited — fail it rather than leave its future pending
        self.fail_queued()
        self.engine.close()
