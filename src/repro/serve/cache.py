"""Byte-budgeted LRU cache of decompressed record payloads.

The gateway-level counterpart of the paper's decompression bottleneck:
under concurrent query traffic the same few hot records are fetched (and
therefore decompressed) over and over — exactly the repeated work the
archive-scale analytics discipline says to aggregate away. Entries are
keyed by ``(shard_id, offset)`` (the CDX-addressable identity of a
record) and the budget is in *bytes*, not entries, because archive
payloads are wildly ragged: a handful of megabyte pages must not be
allowed to masquerade as a "small" cache.

Admission is guarded by a TinyLFU-style frequency sketch
(:class:`FrequencySketch`): before an insert may evict, the candidate's
estimated access frequency must beat the eviction victim's. Archive
query traffic is scan-heavy — one indexed query can touch thousands of
records exactly once — and under plain LRU a single such scan flushes
the hot working set; the sketch makes one-shot keys lose the admission
duel instead (``admission="lru"`` restores the PR 3 behaviour).

Thread-safe; eviction among admitted entries is strict LRU. Payloads
larger than the whole budget are not admitted (one oversize record must
not flush everything).
"""
from __future__ import annotations

import hashlib
import threading
from bisect import bisect_right
from collections import OrderedDict

import numpy as np

__all__ = ["FrequencySketch", "RecordCache", "ShardedRecordCache"]


class FrequencySketch:
    """Count-min sketch with saturating 4-bit-style counters + aging.

    The TinyLFU frequency oracle: ``record`` bumps ``depth`` hashed
    counters (conservative increment — only the current minima move, so
    one key cannot inflate another's estimate more than necessary) and
    ``estimate`` reads their minimum. After ``sample_size`` recordings
    every counter is halved — the classic reset that lets the sketch
    track a *moving* working set instead of all of history.

    Counters live in plain ``bytearray`` rows and the per-access path is
    pure-int: it runs on every ``RecordCache.get``/``put`` *inside the
    cache lock*, where numpy scalar dispatch (~µs per op) would tax the
    gateway's record-fetch hot loop; only the amortized aging sweep
    touches numpy.
    """

    _SEEDS = (0x9E3779B1, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F)
    _CAP = 15  # saturation: 4-bit counters, as in the TinyLFU paper
    _M64 = 0xFFFFFFFFFFFFFFFF

    def __init__(self, capacity_hint: int = 4096, *, depth: int = 4,
                 sample_factor: int = 8) -> None:
        if depth < 1 or depth > len(self._SEEDS):
            raise ValueError(f"depth must be in [1, {len(self._SEEDS)}]")
        width = 1
        while width < max(capacity_hint, 16):
            width <<= 1
        self._width_mask = width - 1
        self._counts = [bytearray(width) for _ in range(depth)]
        self._depth = depth
        self.sample_size = sample_factor * width
        self._recorded = 0
        self.ages = 0

    def _slots(self, key) -> list[int]:
        h = hash(key) & self._M64
        h ^= h >> 33
        slots = []
        for seed in self._SEEDS[:self._depth]:
            m = (h * seed) & self._M64
            slots.append(((m >> 17) ^ m) & self._width_mask)
        return slots

    def record(self, key) -> None:
        """Count one access attempt for ``key`` (hit or miss alike)."""
        idx = self._slots(key)
        counts = self._counts
        lo = min(counts[r][i] for r, i in enumerate(idx))
        if lo < self._CAP:  # conservative increment of the minima only
            for r, i in enumerate(idx):
                if counts[r][i] == lo:
                    counts[r][i] = lo + 1
        self._recorded += 1
        if self._recorded >= self.sample_size:
            for row in counts:  # aging: halve everything (amortized)
                row[:] = (np.frombuffer(row, np.uint8) >> 1).tobytes()
            self._recorded //= 2
            self.ages += 1

    def estimate(self, key) -> int:
        return min(self._counts[r][i]
                   for r, i in enumerate(self._slots(key)))


class RecordCache:
    """LRU over ``(shard_id, offset) -> bytes`` with a byte budget.

    ``admission="tinylfu"`` (the gateway default) gates evicting inserts
    behind the frequency duel described in the module docstring;
    ``admission="lru"`` admits unconditionally (PR 3 behaviour).
    """

    def __init__(self, budget_bytes: int, *, admission: str = "lru",
                 sketch: FrequencySketch | None = None) -> None:
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        if admission not in ("lru", "tinylfu"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.budget_bytes = budget_bytes
        self.admission = admission
        self._sketch = (sketch if sketch is not None
                        else FrequencySketch() if admission == "tinylfu"
                        else None)
        self._entries: "OrderedDict[tuple[int, int], bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejected_oversize = 0
        self.rejected_admission = 0
        self.bytes_filled = 0  # bytes admitted over the cache's lifetime

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def bytes_cached(self) -> int:
        return self._bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: tuple[int, int]) -> bytes | None:
        with self._lock:
            if self._sketch is not None:
                self._sketch.record(key)  # every access attempt counts
            data = self._entries.get(key)
            if data is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return data

    def put(self, key: tuple[int, int], data: bytes) -> bool:
        """Admit ``data``; returns False when it exceeds the budget or
        (TinyLFU) loses the admission duel against the eviction victim."""
        size = len(data)
        with self._lock:
            if self._sketch is not None:
                # an insertion attempt is an access attempt too: without
                # this, a put-without-prior-get workload leaves every
                # candidate at estimate 0 and the duel (<=) freezes the
                # cache on whatever was admitted first
                self._sketch.record(key)
            if size > self.budget_bytes:
                self.rejected_oversize += 1
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= len(old)
            if self._sketch is not None and self._bytes + size > \
                    self.budget_bytes:
                # the insert must evict: the candidate duels *every* entry
                # it would displace (LRU → MRU until enough bytes free) —
                # dueling only the LRU head would let one large candidate
                # beat a stale victim and then flush arbitrarily many hot
                # entries the duel never consulted
                cand_freq = self._sketch.estimate(key)
                need = self._bytes + size - self.budget_bytes
                freed = 0
                admitted = True
                for vkey, vdata in self._entries.items():
                    if freed >= need:
                        break
                    if cand_freq <= self._sketch.estimate(vkey):
                        admitted = False
                        break
                    freed += len(vdata)
                if not admitted:
                    self.rejected_admission += 1
                    if old is not None:  # key was resident: keep old value
                        self._entries[key] = old
                        self._bytes += len(old)
                        self._entries.move_to_end(key)
                    return False
            self._entries[key] = data
            self._bytes += size
            self.bytes_filled += size
            while self._bytes > self.budget_bytes:
                _, evicted = self._entries.popitem(last=False)
                self._bytes -= len(evicted)
                self.evictions += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def snapshot(self) -> dict:
        """Counters for the metrics surface."""
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes_cached": self._bytes,
                "budget_bytes": self.budget_bytes,
                "admission": self.admission,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "rejected_oversize": self.rejected_oversize,
                "rejected_admission": self.rejected_admission,
                "bytes_filled": self.bytes_filled,
                "hit_rate": self.hit_rate,
            }


class ShardedRecordCache:
    """Consistent-hash ring of :class:`RecordCache` slices (PR 9).

    The sharded gateway runs N scheduler shards against one payload
    cache; a plain shared cache would work but couple every shard's
    fate (one death evicts everything) — and N *independent* caches
    would duplicate hot bytes N times. Consistent hashing gives both
    properties the DESIGN §12 topology wants:

    * every key is owned by exactly **one** slice (no duplicated hot
      bytes — the residency property test asserts this);
    * removing a slice (a shard retired after exhausting its respawn
      budget) remaps only *its* arc of the ring — keys owned by
      surviving slices keep their placement and their heat;
    * a transient shard death clears only its own slice
      (:meth:`clear_slice`), bounding the cold-start to 1/N of the
      budget.

    The key → slice map uses ``vnodes`` virtual points per slice
    (default 64) hashed with ``blake2b`` — process-independent and
    uniform enough that a zipfian workload's hit rate stays within a
    few percent of a single cache of the same total budget (property
    tested). ``n_slices=1`` short-circuits all ring math: the
    single-shard gateway pays nothing for the generality.

    Thread-safe: slice routing state is read-mostly (rebuilt only on
    :meth:`remove_slice`, under a lock); each slice carries its own
    lock, so shards hitting different slices don't contend.
    """

    def __init__(self, budget_bytes: int, n_slices: int = 1, *,
                 admission: str = "tinylfu", vnodes: int = 64) -> None:
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        n = max(1, int(n_slices))
        base, extra = divmod(budget_bytes, n)
        self._slices = [RecordCache(base + (1 if i < extra else 0),
                                    admission=admission)
                        for i in range(n)]
        self.n_slices = n
        self.admission = admission
        self.budget_bytes = budget_bytes
        self._vnodes = max(1, int(vnodes))
        self._removed: set[int] = set()
        self._ring_lock = threading.Lock()
        self._rebuild_ring()

    # -- ring -------------------------------------------------------------
    @staticmethod
    def _hash(obj) -> int:
        digest = hashlib.blake2b(repr(obj).encode("utf-8",
                                                  "backslashreplace"),
                                 digest_size=8).digest()
        return int.from_bytes(digest, "big")

    def _rebuild_ring(self) -> None:
        points: list[tuple[int, int]] = []
        for i in range(self.n_slices):
            if i in self._removed:
                continue
            points.extend((self._hash(("slice", i, v)), i)
                          for v in range(self._vnodes))
        points.sort()
        self._points = [p for p, _ in points]
        self._owners = [o for _, o in points]

    def slice_for(self, key) -> int | None:
        """The slice owning ``key`` (``None`` when every slice is
        removed). Deterministic and stable across processes."""
        if self.n_slices == 1:
            return None if 0 in self._removed else 0
        points = self._points  # snapshot: rebuilds swap, never mutate
        if not points:
            return None
        i = bisect_right(points, self._hash(key)) % len(points)
        return self._owners[i]

    # -- cache surface (RecordCache-compatible) ---------------------------
    def get(self, key) -> bytes | None:
        owner = self.slice_for(key)
        return None if owner is None else self._slices[owner].get(key)

    def put(self, key, data: bytes) -> bool:
        owner = self.slice_for(key)
        return False if owner is None else self._slices[owner].put(key, data)

    def clear(self) -> None:
        for sl in self._slices:
            sl.clear()

    def clear_slice(self, i: int) -> None:
        """Evict one slice's residents (transient shard death): siblings
        keep their heat, the cold-start is bounded to this slice."""
        self._slices[i].clear()

    def remove_slice(self, i: int) -> None:
        """Retire one slice from the ring (permanent shard death): its
        arc remaps to the survivors, every other key keeps its owner."""
        with self._ring_lock:
            if i in self._removed:
                return
            self._removed.add(i)
            self._rebuild_ring()
        self._slices[i].clear()

    @property
    def slices(self) -> "list[RecordCache]":
        return self._slices

    def __len__(self) -> int:
        return sum(len(sl) for sl in self._slices)

    @property
    def bytes_cached(self) -> int:
        return sum(sl.bytes_cached for sl in self._slices)

    @property
    def hits(self) -> int:
        return sum(sl.hits for sl in self._slices)

    @property
    def misses(self) -> int:
        return sum(sl.misses for sl in self._slices)

    @property
    def evictions(self) -> int:
        return sum(sl.evictions for sl in self._slices)

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def snapshot(self) -> dict:
        """Aggregated counters (same keys as :meth:`RecordCache.snapshot`
        so the metrics surface is shape-stable) + slice accounting."""
        per = [sl.snapshot() for sl in self._slices]
        out = {
            "entries": sum(p["entries"] for p in per),
            "bytes_cached": sum(p["bytes_cached"] for p in per),
            "budget_bytes": self.budget_bytes,
            "admission": self.admission,
            "hits": sum(p["hits"] for p in per),
            "misses": sum(p["misses"] for p in per),
            "evictions": sum(p["evictions"] for p in per),
            "rejected_oversize": sum(p["rejected_oversize"] for p in per),
            "rejected_admission": sum(p["rejected_admission"] for p in per),
            "bytes_filled": sum(p["bytes_filled"] for p in per),
            "hit_rate": self.hit_rate,
            "slices": self.n_slices,
            "slices_removed": len(self._removed),
        }
        return out
