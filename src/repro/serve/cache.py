"""Byte-budgeted LRU cache of decompressed record payloads.

The gateway-level counterpart of the paper's decompression bottleneck:
under concurrent query traffic the same few hot records are fetched (and
therefore decompressed) over and over — exactly the repeated work the
archive-scale analytics discipline says to aggregate away. Entries are
keyed by ``(shard_id, offset)`` (the CDX-addressable identity of a
record) and the budget is in *bytes*, not entries, because archive
payloads are wildly ragged: a handful of megabyte pages must not be
allowed to masquerade as a "small" cache.

Thread-safe; eviction is strict LRU. Payloads larger than the whole
budget are not admitted (one oversize record must not flush everything).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

__all__ = ["RecordCache"]


class RecordCache:
    """LRU over ``(shard_id, offset) -> bytes`` with a byte budget."""

    def __init__(self, budget_bytes: int) -> None:
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        self.budget_bytes = budget_bytes
        self._entries: "OrderedDict[tuple[int, int], bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejected_oversize = 0

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def bytes_cached(self) -> int:
        return self._bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: tuple[int, int]) -> bytes | None:
        with self._lock:
            data = self._entries.get(key)
            if data is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return data

    def put(self, key: tuple[int, int], data: bytes) -> bool:
        """Admit ``data``; returns False when it exceeds the budget."""
        size = len(data)
        with self._lock:
            if size > self.budget_bytes:
                self.rejected_oversize += 1
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= len(old)
            self._entries[key] = data
            self._bytes += size
            while self._bytes > self.budget_bytes:
                _, evicted = self._entries.popitem(last=False)
                self._bytes -= len(evicted)
                self.evictions += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def snapshot(self) -> dict:
        """Counters for the metrics surface."""
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes_cached": self._bytes,
                "budget_bytes": self.budget_bytes,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "rejected_oversize": self.rejected_oversize,
                "hit_rate": self.hit_rate,
            }
