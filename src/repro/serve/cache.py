"""Byte-budgeted LRU cache of decompressed record payloads.

The gateway-level counterpart of the paper's decompression bottleneck:
under concurrent query traffic the same few hot records are fetched (and
therefore decompressed) over and over — exactly the repeated work the
archive-scale analytics discipline says to aggregate away. Entries are
keyed by ``(shard_id, offset)`` (the CDX-addressable identity of a
record) and the budget is in *bytes*, not entries, because archive
payloads are wildly ragged: a handful of megabyte pages must not be
allowed to masquerade as a "small" cache.

Admission is guarded by a TinyLFU-style frequency sketch
(:class:`FrequencySketch`): before an insert may evict, the candidate's
estimated access frequency must beat the eviction victim's. Archive
query traffic is scan-heavy — one indexed query can touch thousands of
records exactly once — and under plain LRU a single such scan flushes
the hot working set; the sketch makes one-shot keys lose the admission
duel instead (``admission="lru"`` restores the PR 3 behaviour).

Thread-safe; eviction among admitted entries is strict LRU. Payloads
larger than the whole budget are not admitted (one oversize record must
not flush everything).
"""
from __future__ import annotations

import threading
from collections import OrderedDict

import numpy as np

__all__ = ["FrequencySketch", "RecordCache"]


class FrequencySketch:
    """Count-min sketch with saturating 4-bit-style counters + aging.

    The TinyLFU frequency oracle: ``record`` bumps ``depth`` hashed
    counters (conservative increment — only the current minima move, so
    one key cannot inflate another's estimate more than necessary) and
    ``estimate`` reads their minimum. After ``sample_size`` recordings
    every counter is halved — the classic reset that lets the sketch
    track a *moving* working set instead of all of history.

    Counters live in plain ``bytearray`` rows and the per-access path is
    pure-int: it runs on every ``RecordCache.get``/``put`` *inside the
    cache lock*, where numpy scalar dispatch (~µs per op) would tax the
    gateway's record-fetch hot loop; only the amortized aging sweep
    touches numpy.
    """

    _SEEDS = (0x9E3779B1, 0x85EBCA6B, 0xC2B2AE35, 0x27D4EB2F)
    _CAP = 15  # saturation: 4-bit counters, as in the TinyLFU paper
    _M64 = 0xFFFFFFFFFFFFFFFF

    def __init__(self, capacity_hint: int = 4096, *, depth: int = 4,
                 sample_factor: int = 8) -> None:
        if depth < 1 or depth > len(self._SEEDS):
            raise ValueError(f"depth must be in [1, {len(self._SEEDS)}]")
        width = 1
        while width < max(capacity_hint, 16):
            width <<= 1
        self._width_mask = width - 1
        self._counts = [bytearray(width) for _ in range(depth)]
        self._depth = depth
        self.sample_size = sample_factor * width
        self._recorded = 0
        self.ages = 0

    def _slots(self, key) -> list[int]:
        h = hash(key) & self._M64
        h ^= h >> 33
        slots = []
        for seed in self._SEEDS[:self._depth]:
            m = (h * seed) & self._M64
            slots.append(((m >> 17) ^ m) & self._width_mask)
        return slots

    def record(self, key) -> None:
        """Count one access attempt for ``key`` (hit or miss alike)."""
        idx = self._slots(key)
        counts = self._counts
        lo = min(counts[r][i] for r, i in enumerate(idx))
        if lo < self._CAP:  # conservative increment of the minima only
            for r, i in enumerate(idx):
                if counts[r][i] == lo:
                    counts[r][i] = lo + 1
        self._recorded += 1
        if self._recorded >= self.sample_size:
            for row in counts:  # aging: halve everything (amortized)
                row[:] = (np.frombuffer(row, np.uint8) >> 1).tobytes()
            self._recorded //= 2
            self.ages += 1

    def estimate(self, key) -> int:
        return min(self._counts[r][i]
                   for r, i in enumerate(self._slots(key)))


class RecordCache:
    """LRU over ``(shard_id, offset) -> bytes`` with a byte budget.

    ``admission="tinylfu"`` (the gateway default) gates evicting inserts
    behind the frequency duel described in the module docstring;
    ``admission="lru"`` admits unconditionally (PR 3 behaviour).
    """

    def __init__(self, budget_bytes: int, *, admission: str = "lru",
                 sketch: FrequencySketch | None = None) -> None:
        if budget_bytes < 0:
            raise ValueError("budget_bytes must be >= 0")
        if admission not in ("lru", "tinylfu"):
            raise ValueError(f"unknown admission policy {admission!r}")
        self.budget_bytes = budget_bytes
        self.admission = admission
        self._sketch = (sketch if sketch is not None
                        else FrequencySketch() if admission == "tinylfu"
                        else None)
        self._entries: "OrderedDict[tuple[int, int], bytes]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        self.hits = 0
        self.misses = 0
        self.evictions = 0
        self.rejected_oversize = 0
        self.rejected_admission = 0
        self.bytes_filled = 0  # bytes admitted over the cache's lifetime

    def __len__(self) -> int:
        return len(self._entries)

    @property
    def bytes_cached(self) -> int:
        return self._bytes

    @property
    def hit_rate(self) -> float:
        total = self.hits + self.misses
        return self.hits / total if total else 0.0

    def get(self, key: tuple[int, int]) -> bytes | None:
        with self._lock:
            if self._sketch is not None:
                self._sketch.record(key)  # every access attempt counts
            data = self._entries.get(key)
            if data is None:
                self.misses += 1
                return None
            self._entries.move_to_end(key)
            self.hits += 1
            return data

    def put(self, key: tuple[int, int], data: bytes) -> bool:
        """Admit ``data``; returns False when it exceeds the budget or
        (TinyLFU) loses the admission duel against the eviction victim."""
        size = len(data)
        with self._lock:
            if self._sketch is not None:
                # an insertion attempt is an access attempt too: without
                # this, a put-without-prior-get workload leaves every
                # candidate at estimate 0 and the duel (<=) freezes the
                # cache on whatever was admitted first
                self._sketch.record(key)
            if size > self.budget_bytes:
                self.rejected_oversize += 1
                return False
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= len(old)
            if self._sketch is not None and self._bytes + size > \
                    self.budget_bytes:
                # the insert must evict: the candidate duels *every* entry
                # it would displace (LRU → MRU until enough bytes free) —
                # dueling only the LRU head would let one large candidate
                # beat a stale victim and then flush arbitrarily many hot
                # entries the duel never consulted
                cand_freq = self._sketch.estimate(key)
                need = self._bytes + size - self.budget_bytes
                freed = 0
                admitted = True
                for vkey, vdata in self._entries.items():
                    if freed >= need:
                        break
                    if cand_freq <= self._sketch.estimate(vkey):
                        admitted = False
                        break
                    freed += len(vdata)
                if not admitted:
                    self.rejected_admission += 1
                    if old is not None:  # key was resident: keep old value
                        self._entries[key] = old
                        self._bytes += len(old)
                        self._entries.move_to_end(key)
                    return False
            self._entries[key] = data
            self._bytes += size
            self.bytes_filled += size
            while self._bytes > self.budget_bytes:
                _, evicted = self._entries.popitem(last=False)
                self._bytes -= len(evicted)
                self.evictions += 1
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0

    def snapshot(self) -> dict:
        """Counters for the metrics surface."""
        with self._lock:
            return {
                "entries": len(self._entries),
                "bytes_cached": self._bytes,
                "budget_bytes": self.budget_bytes,
                "admission": self.admission,
                "hits": self.hits,
                "misses": self.misses,
                "evictions": self.evictions,
                "rejected_oversize": self.rejected_oversize,
                "rejected_admission": self.rejected_admission,
                "bytes_filled": self.bytes_filled,
                "hit_rate": self.hit_rate,
            }
