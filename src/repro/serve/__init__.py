"""Serving substrate: KV-cache decode loop with batched request handling."""
