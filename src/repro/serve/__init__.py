"""Serving substrate: the "heavy traffic" layers of the reproduction.

* :mod:`.engine` — batched LM serving (KV-cache prefill + decode loop);
* :mod:`.archive` — the async archive query gateway: admission queue
  with backpressure, request coalescing, cross-request kernel batching
  and a byte-budgeted record cache over :mod:`repro.index`;
* :mod:`.cache` / :mod:`.metrics` — the gateway's payload LRU and its
  measurement surface (a facade over :mod:`repro.obs` since PR 7;
  ``ArchiveGateway.snapshot()`` exports a mergeable ``ObsSnapshot``).

``.engine`` pulls in jax + the model stack, so it is imported lazily by
its users rather than here; the archive gateway imports light.
"""
from .archive import (ArchiveGateway, GatewayClosed, GatewayOverloaded,
                      GatewayShardDown, GatewayTimeout)
from .cache import RecordCache, ShardedRecordCache
from .metrics import GatewayMetrics, percentile
from .shard import ShardScheduler

__all__ = [
    "ArchiveGateway",
    "GatewayClosed",
    "GatewayOverloaded",
    "GatewayShardDown",
    "GatewayTimeout",
    "GatewayMetrics",
    "RecordCache",
    "ShardedRecordCache",
    "ShardScheduler",
    "percentile",
]
