"""``repro.serve.archive`` — async archive query gateway (DESIGN.md §8).

PR 2's :class:`~repro.index.service.IndexQueryService` is synchronous:
every request pays for its own scan, so concurrent clients asking
overlapping questions redundantly decompress the same records and issue
near-identical kernel dispatches. This module is the multi-tenant layer
that aggregates that work *before* touching the archive:

* **admission queue with backpressure** — a bounded queue; ``submit``
  blocks (or raises :class:`GatewayOverloaded`) when serving cannot keep
  up, so memory stays bounded under heavy traffic;
* **request coalescing** — identical in-flight scans (same pattern +
  predicates + prefilter, see ``QueryRequest.scan_key``) are executed
  **once**; every waiter gets the same hit list, shaped per-request
  (``top_k``). Late arrivals attach to an executing scan without ever
  entering the queue;
* **cross-request kernel batching** — candidate records from
  *different* concurrent queries are packed into shared
  :func:`~repro.kernels.pattern_scan.find_pattern_masks_multi`
  dispatches (the per-row-pattern kernel): one Pallas call serves many
  requests, with padding bounded by the usual power-of-two width
  buckets;
* **record cache** — a byte-budgeted LRU of decompressed payloads
  (:mod:`repro.serve.cache`) keyed by ``(shard, offset)``, so repeat
  candidates across requests skip the decompress entirely;
* **metrics** — :mod:`repro.serve.metrics` records p50/p99 latency,
  coalesce rate, dispatches-per-request and cache hit rate, making the
  aggregation wins checkable (``BENCH_serve.json``);
* **request-scoped tracing** (PR 8, on by default, ≤1.05× gated
  in-bench) — every request gets a trace id at submit; its time
  decomposes into true parent/child spans across the thread boundary
  (admission → queue wait → coalesce/attach → batch formation →
  prefilter → cache fill → kernel dispatch → host verify → respond,
  names in :mod:`repro.obs.trace`). Stage durations land in the
  gateway registry as ``gateway.stage.<name>_s`` histograms (the
  attribution surface of ``benchmarks/serve_bench.py`` and
  ``python -m repro.obs.top``); finished spans land in the always-on
  bounded flight recorder (:mod:`repro.obs.flight`), which auto-dumps
  the recent span history to a file whenever an anomaly trips —
  :class:`GatewayTimeout`, :class:`GatewayOverloaded`, queue-depth
  high-water, or p99 above the ``slo_p99_s`` gauge.

Correctness bar: responses are **byte-identical** to what an independent
synchronous :class:`~repro.index.query.QueryEngine` run would produce —
coalescing, caching and shared dispatch change *when* work happens,
never *what* is computed (the soak + property tests assert exactly
this).

One scheduler thread owns the engine, the cache fills, and the device;
submission is thread-safe from any number of client threads.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field

import numpy as np

from repro.core.warc.errors import RecordReadError
from repro.index.cdx import CdxIndex
from repro.index.query import PatternHit, QueryEngine, QueryPlan
from repro.index.service import QueryRequest, QueryResponse
from repro.obs import flight as obs_flight
from repro.obs import trace as obs_trace
from .cache import RecordCache
from .metrics import GatewayMetrics

__all__ = ["ArchiveGateway", "GatewayClosed", "GatewayOverloaded",
           "GatewayTimeout"]


class GatewayOverloaded(RuntimeError):
    """Admission queue full: backpressure instead of unbounded growth."""


class GatewayClosed(RuntimeError):
    """Request submitted to (or still pending in) a closed gateway."""


class GatewayTimeout(RuntimeError):
    """Per-request deadline expired before the scan could resolve it.

    Distinct from :class:`GatewayOverloaded` (rejected at admission) —
    a timed-out request was *accepted* but couldn't be served in time;
    the caller can tell load shedding apart from slow serving.
    """


@dataclass
class _Ticket:
    """One submitted request and its completion future."""

    request: QueryRequest
    future: Future = field(default_factory=Future)
    t_submit: float = field(default_factory=time.perf_counter)
    deadline: float | None = None  # absolute perf_counter time, or None
    # request-scoped tracing (None when trace_requests=False): the root
    # span carries the trace across the submit-thread → scheduler-thread
    # boundary; wait_span times queue residency (opened by the submitter,
    # closed by the scheduler)
    span: obs_trace.Span | None = None
    wait_span: obs_trace.Span | None = None

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now > self.deadline


class _StageCM:
    """``with gw._stage("gw.cache_fill") as sp:`` — span + stage
    histogram, or a no-op when the gateway isn't tracing."""

    __slots__ = ("_gw", "span")

    def __init__(self, gw: "ArchiveGateway", name: str,
                 parent=None, attrs=None):
        self._gw = gw
        self.span = obs_trace.start_span(name, parent, attrs=attrs)

    def __enter__(self) -> obs_trace.Span:
        return self.span

    def __exit__(self, *exc) -> None:
        self._gw._end_span(self.span)


class _NullCM:
    __slots__ = ()
    span = None

    def __enter__(self):
        return None

    def __exit__(self, *exc) -> None:
        pass


_NULL_CM = _NullCM()


class ArchiveGateway:
    """Asynchronous, coalescing, cross-request-batching query front end.

    >>> with ArchiveGateway(index) as gw:
    ...     fut = gw.submit(QueryRequest(b"nginx"))
    ...     response = fut.result()
    ...     gw.metrics.snapshot(gw.cache)["dispatches_per_request"]

    Parameters
    ----------
    index:
        the corpus CDX index the gateway serves.
    engine:
        optional pre-built :class:`QueryEngine`; owned (and closed) by
        the gateway either way. Only the scheduler thread touches it.
    max_pending:
        admission-queue bound — the backpressure knob.
    max_batch_requests:
        how many queued requests one scheduler drain may aggregate.
    cache_bytes:
        byte budget of the decompressed-payload LRU.
    cache_admission:
        ``"tinylfu"`` (default) guards the record cache with a
        scan-resistant frequency-sketch admission duel — one-shot query
        sweeps can no longer flush the hot working set; ``"lru"`` is
        the PR 3 admit-always cache.
    default_deadline_s:
        deadline applied to every request that doesn't carry its own
        ``deadline_s`` at :meth:`submit`; ``None`` (default) means no
        deadline. Expired requests resolve with :class:`GatewayTimeout`
        instead of occupying scan capacity.
    trace_requests:
        request-scoped span tracing (default on; the serve bench gates
        the traced path at ≤1.05× the untraced one). Off, the only cost
        left is one branch per stage.
    flight_recorder:
        where finished spans and anomaly dumps go; ``None`` uses the
        process-default :func:`repro.obs.flight.recorder`.
    slo_p99_s:
        latency objective: after a batch resolves, a measured p99 above
        this trips an anomaly dump (needs ≥32 latency samples so one
        cold scan can't cry wolf). ``None`` disables the check.
    queue_highwater:
        admission-queue depth that trips an anomaly dump when first
        crossed (default: ¾ of ``max_pending``).
    """

    def __init__(self, index: CdxIndex, *, engine: QueryEngine | None = None,
                 max_pending: int = 256, max_batch_requests: int = 16,
                 cache_bytes: int = 64 << 20, cache_admission: str = "tinylfu",
                 use_kernel: bool = True,
                 interpret: bool = True, poll_interval_s: float = 0.02,
                 default_deadline_s: float | None = None,
                 trace_requests: bool = True,
                 flight_recorder: obs_flight.FlightRecorder | None = None,
                 slo_p99_s: float | None = None,
                 queue_highwater: int | None = None,
                 ) -> None:
        self.engine = engine if engine is not None else QueryEngine(
            index, use_kernel=use_kernel, interpret=interpret)
        self.index = self.engine.index
        self.cache = RecordCache(cache_bytes, admission=cache_admission)
        self.metrics = GatewayMetrics()
        self.max_batch_requests = max(1, max_batch_requests)
        self.default_deadline_s = default_deadline_s
        self._poll = poll_interval_s
        self._trace = bool(trace_requests)
        self._flight = flight_recorder if flight_recorder is not None \
            else obs_flight.recorder()
        self._slo_p99_s = slo_p99_s
        self._highwater = queue_highwater if queue_highwater is not None \
            else max(4, (max_pending * 3) // 4)
        self._above_highwater = False
        self._queue_hw_seen = 0
        self._queue: "queue.Queue[_Ticket]" = queue.Queue(max(1, max_pending))
        self._inflight: dict[tuple, list[_Ticket]] = {}
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self._closed = False
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="archive-gateway")
        self._thread.start()

    # -- tracing plumbing -------------------------------------------------
    def _end_span(self, span: obs_trace.Span | None) -> None:
        """Finish a span into the flight recorder and fold its duration
        into the ``gateway.stage.*`` histogram of the same name."""
        if span is not None:
            self.metrics.observe_stage(span.name,
                                       span.finish(recorder=self._flight))

    def _stage(self, name: str, parent=None, attrs=None):
        """Context manager for one scheduler-side stage (no-op untraced)."""
        if not self._trace:
            return _NULL_CM
        return _StageCM(self, name, parent, attrs)

    def _trip(self, reason: str, attrs: dict | None = None) -> None:
        """Anomaly: auto-dump the flight recorder (rate-limited inside)."""
        if self._flight.trip(reason, attrs) is not None:
            self.metrics.inc("flight_dumps")

    def _note_queue_depth(self, depth: int) -> None:
        self.metrics.gauge_set("queue_depth", depth)
        if depth > self._queue_hw_seen:
            self._queue_hw_seen = depth
            self.metrics.gauge_set("queue_depth_highwater", depth)
        if depth >= self._highwater:
            if not self._above_highwater:  # trip on the crossing, not
                self._above_highwater = True  # on every submit above it
                self._trip("queue_highwater",
                           {"depth": depth, "highwater": self._highwater})
        else:
            self._above_highwater = False

    # -- client side -----------------------------------------------------
    def submit(self, request: QueryRequest, *, block: bool = True,
               timeout: float | None = None,
               deadline_s: float | None = None) -> "Future[QueryResponse]":
        """Queue one request; returns the future of its response.

        An identical scan already **executing** is joined directly (the
        in-flight coalescing fast path, no queue slot); identical
        requests sitting in the queue merge when the scheduler drains
        them into the same batch. With ``block=False`` (or on
        ``timeout``) a full queue raises :class:`GatewayOverloaded` —
        backpressure the caller can see.

        ``deadline_s`` (default: the gateway's ``default_deadline_s``)
        bounds how long the request may wait end-to-end: a ticket whose
        deadline expires before its batch resolves gets
        :class:`GatewayTimeout` instead of a response — under overload
        the scheduler sheds expired queue entries without scanning for
        them.
        """
        if self._closed:
            raise GatewayClosed("gateway is closed")
        ticket = _Ticket(request)
        budget = deadline_s if deadline_s is not None else self.default_deadline_s
        if budget is not None:
            ticket.deadline = ticket.t_submit + budget
        adm = None
        if self._trace:
            # root span: the whole request, submit → resolution; its
            # trace id rides the ticket across the scheduler boundary
            ticket.span = obs_trace.start_span(
                "gw.request", parent=obs_trace.ROOT, t0=ticket.t_submit,
                attrs={"pattern": repr(request.pattern[:64]),
                       "regex": request.regex, "top_k": request.top_k})
            adm = obs_trace.start_span("gw.admission", ticket.span,
                                       t0=ticket.t_submit)
        with self._lock:
            waiters = self._inflight.get(request.scan_key())
            if waiters is not None:
                waiters.append(ticket)
                self.metrics.inc("requests")
                self.metrics.inc("coalesced")
                if adm is not None:
                    self._end_span(adm)
                    with self._stage("gw.coalesce_attach", ticket.span,
                                     attrs={"inflight_waiters":
                                            len(waiters)}):
                        pass
                return ticket.future
        try:
            self._queue.put(ticket, block=block, timeout=timeout)
        except queue.Full:
            self.metrics.inc("rejected")
            if adm is not None:
                adm.set_attr("rejected", True)
                self._end_span(adm)
                ticket.span.set_attr("error", "GatewayOverloaded")
                ticket.span.finish(recorder=self._flight)
            self._trip("gateway_overloaded",
                       {"max_pending": self._queue.maxsize})
            raise GatewayOverloaded(
                f"admission queue full ({self._queue.maxsize} pending)")
        if adm is not None:
            self._end_span(adm)
            ticket.wait_span = obs_trace.start_span("gw.queue_wait",
                                                    ticket.span)
        self._note_queue_depth(self._queue.qsize())
        if self._closed and not self._thread.is_alive():
            # raced close(): we passed the closed check before close()
            # flipped it, but enqueued after the scheduler exited — no
            # one will drain the queue again, so fail it now
            self._fail_queued()
        self.metrics.inc("requests")
        return ticket.future

    def query(self, request: QueryRequest,
              timeout: float | None = None) -> QueryResponse:
        """Synchronous convenience: submit and wait."""
        return self.submit(request).result(timeout)

    def pending(self) -> int:
        return self._queue.qsize()

    def snapshot(self):
        """Observability hook: one merged :class:`~repro.obs.ObsSnapshot`
        — this gateway's private metrics registry + cache counters
        (source ``"gateway"``) merged with the process-default registry
        (kernel dispatch profile, ingest counters, harvested children).
        For the raw dict surface keep using ``gateway.metrics.snapshot()``.
        """
        from repro import obs

        return obs.snapshot().merged_with(
            self.metrics.obs_snapshot(self.cache))

    # -- scheduler -------------------------------------------------------
    def _loop(self) -> None:
        while True:
            try:
                first = self._queue.get(timeout=self._poll)
            except queue.Empty:
                if self._stop.is_set():
                    return  # drained: every accepted request was served
                continue
            batch = [first]
            while len(batch) < self.max_batch_requests:
                try:
                    batch.append(self._queue.get_nowait())
                except queue.Empty:
                    break
            self._note_queue_depth(self._queue.qsize())
            try:
                self._serve_batch(batch)
            except BaseException:  # the scheduler must outlive any batch
                self.metrics.inc("errors")

    def _timeout(self, ticket: _Ticket) -> None:
        """Resolve one expired ticket (caller already claimed the future)."""
        waited = time.perf_counter() - ticket.t_submit
        ticket.future.set_exception(GatewayTimeout(
            f"deadline expired after {waited:.3f}s"))
        self.metrics.inc("timeouts")
        if ticket.span is not None:
            # marker child + closed root *before* the trip, so the dump
            # holds the offending request's complete span tree
            with self._stage("gw.timeout", ticket.span,
                             attrs={"waited_s": waited}):
                pass
            ticket.span.set_attr("error", "GatewayTimeout")
            ticket.span.finish(recorder=self._flight)
        self._trip("gateway_timeout",
                   {"waited_s": waited,
                    "trace_id": ticket.span.trace_id if ticket.span else None})

    def _serve_batch(self, tickets: list[_Ticket]) -> None:
        if not self._trace:
            self._serve_batch_body(tickets)
            return
        # the batch roots its own trace (a scan serves many requests —
        # span trees are strict, so waiter roots *link* to it via attrs
        # rather than parent it); installing it as the context's current
        # span lets every stage below default-parent to it
        for ticket in tickets:
            if ticket.wait_span is not None:  # queue residency ends here
                self._end_span(ticket.wait_span)
                ticket.wait_span = None
        batch_span = obs_trace.start_span(
            "gw.scan_batch", obs_trace.ROOT,
            attrs={"n_tickets": len(tickets),
                   "waiter_traces": [t.span.trace_id for t in tickets
                                     if t.span is not None]})
        try:
            with obs_trace.use_span(batch_span):
                self._serve_batch_body(tickets)
        finally:
            self._end_span(batch_span)
        if self._slo_p99_s is not None and self.metrics.latency_count() >= 32:
            p99 = self.metrics.latency_s(99)
            self.metrics.gauge_set("latency_p99_s", p99)
            if p99 > self._slo_p99_s:
                self._trip("slo_p99", {"p99_s": p99,
                                       "slo_s": self._slo_p99_s})

    def _serve_batch_body(self, tickets: list[_Ticket]) -> None:
        form = self._stage("gw.batch_form").__enter__()
        # shed already-expired tickets before planning anything: under
        # overload the queue ages, and scanning for a waiter that stopped
        # caring only makes every later deadline worse
        now = time.perf_counter()
        live: list[_Ticket] = []
        for ticket in tickets:
            if ticket.expired(now):
                if ticket.future.set_running_or_notify_cancel():
                    self._timeout(ticket)
            else:
                live.append(ticket)
        if not live:
            self._end_span(form)
            return
        tickets = live
        # group by scan identity; first occurrence keeps submission order
        groups: dict[tuple, list[_Ticket]] = {}
        for ticket in tickets:
            key = ticket.request.scan_key()
            if key in groups:
                groups[key].append(ticket)
                self.metrics.inc("coalesced")
            else:
                groups[key] = [ticket]
        with self._lock:
            # publish the in-flight registry: identical requests submitted
            # while we scan attach to these lists and never enter the queue
            self._inflight.update(groups)
        self._end_span(form)
        self.metrics.inc("scan_batches")
        self.metrics.inc("unique_scans", len(groups))
        results: dict[tuple, list[PatternHit]] = {}
        failures: dict[tuple, BaseException] = {}
        try:
            plans = {}
            for key, group_waiters in groups.items():
                try:
                    with self._stage("gw.prefilter",
                                     attrs={"pattern":
                                            repr(key[0][:64])}):
                        plans[key] = self._plan(group_waiters[0].request)
                except Exception as exc:  # malformed query: fail only its
                    failures[key] = exc   # own waiters, not the batch
                    self.metrics.inc("errors")
            results, scan_failures = self._execute_plans(plans)
            for key, exc in scan_failures.items():
                failures.setdefault(key, exc)
        except BaseException as exc:  # scan failure: resolve all, keep serving
            self.metrics.inc("errors")
            failures = {key: failures.get(key, exc) for key in groups}
        finally:
            with self._lock:
                waiters = {key: self._inflight.pop(key) for key in groups}
        with self._stage("gw.respond"):
            now = time.perf_counter()
            for key, tickets_for_key in waiters.items():
                hits = results.get(key, [])
                error = failures.get(key)
                # rank: most matches first, index order breaks ties
                # (stable) — identical to IndexQueryService
                ranked = sorted(hits, key=lambda h: -h.n_matches)
                for ticket in tickets_for_key:
                    # a client may have cancel()ed while we scanned;
                    # claiming the future first makes the set_* below
                    # race-free (and a cancelled ticket must not kill the
                    # scheduler)
                    if not ticket.future.set_running_or_notify_cancel():
                        if ticket.span is not None:
                            ticket.span.set_attr("cancelled", True)
                            ticket.span.finish(recorder=self._flight)
                        continue
                    if error is not None:
                        ticket.future.set_exception(error)
                        if ticket.span is not None:
                            ticket.span.set_attr("error",
                                                 type(error).__name__)
                            ticket.span.finish(recorder=self._flight)
                        continue
                    if ticket.expired(now):  # scan outlived the deadline
                        self._timeout(ticket)
                        continue
                    latency = now - ticket.t_submit
                    ticket.future.set_result(QueryResponse(
                        request=ticket.request,
                        hits=ranked[:ticket.request.top_k],
                        total_matches=len(hits), latency_s=latency))
                    self.metrics.observe_latency(latency)
                    self.metrics.inc("responses")
                    if ticket.span is not None:
                        ticket.span.finish(recorder=self._flight)

    def _plan(self, request: QueryRequest) -> QueryPlan:
        if request.regex:
            return self.engine.plan_regex(request.pattern, request.filters,
                                          prefilter=request.prefilter)
        return self.engine.plan(request.pattern, request.filters,
                                prefilter=request.prefilter)

    # -- cache-aware fetch ----------------------------------------------
    def _fetch(self, row: int) -> bytes:
        key = (int(self.index.shard_id[row]), int(self.index.offset[row]))
        data = self.cache.get(key)
        if data is None:
            data = self.engine._fetch(row)
            self.cache.put(key, data)
            self.metrics.inc("records_fetched")
        return data

    def _fetch_chunk(self, chunk: list[tuple[tuple, int]]
                     ) -> tuple[dict[int, bytes], list[tuple[tuple, int]]]:
        """Fetch one chunk's payloads, quarantining unreadable rows.

        A row whose record can't be parsed (:class:`RecordReadError` —
        damaged member, bad framing) is dropped from the chunk instead
        of failing any query: a damaged record simply can't match, and
        every plan sharing the row keeps its other candidates. Counted
        under ``read_errors`` (fetch attempts that failed) and
        ``quarantined_rows`` (distinct rows skipped).
        """
        bufs: dict[int, bytes] = {}
        dead: set[int] = set()
        with self._stage("gw.cache_fill",
                         attrs={"rows": len(chunk)}) as sp:
            for _, row in chunk:  # dedupe: shared rows fetched once
                if row in bufs or row in dead:
                    continue
                try:
                    bufs[row] = self._fetch(row)
                except RecordReadError:
                    dead.add(row)
                    self.metrics.inc("read_errors")
            if sp is not None:
                sp.set_attr("fetched", len(bufs))
        if not dead:
            return bufs, chunk
        self.metrics.inc("quarantined_rows", len(dead))
        return bufs, [(key, row) for key, row in chunk if row not in dead]

    def _fail_chunk(self, chunk: list[tuple[tuple, int]],
                    exc: BaseException,
                    failures: dict[tuple, BaseException]) -> None:
        self.metrics.inc("errors")
        for key in {key for key, _ in chunk}:
            failures.setdefault(key, exc)

    # -- cross-request scan ----------------------------------------------
    def _execute_plans(self, plans: dict[tuple, QueryPlan]
                       ) -> tuple[dict[tuple, list[PatternHit]],
                                  dict[tuple, BaseException]]:
        """Scan all plans' candidates through *shared* kernel dispatches.

        Every (plan, candidate row) pair becomes one scan item; items
        from different plans are chunked together under the engine's
        batch_records / batch_bytes limits (sized from the index's
        ``uncomp_len`` column, so chunking decides before any payload is
        decompressed) and each chunk goes through one multi-pattern
        dispatch per width bucket — the request count no longer shows up
        in the dispatch count. Payloads are fetched per chunk in
        shard/offset order (deduped inside the chunk, the cache absorbs
        repeats across chunks), scanned and verified, then released —
        resident memory stays bounded by chunk size + cache budget, like
        the sync engine's streaming execute.

        Failure isolation: unreadable rows are skipped per-row (see
        :meth:`_fetch_chunk`); a chunk whose scan/verify raises fails
        only the plans with items in that chunk (returned in the second
        element), never the whole batch — one poisoned query can't take
        down its co-batched neighbours.
        """
        results: dict[tuple, list[PatternHit]] = {key: [] for key in plans}
        failures: dict[tuple, BaseException] = {}
        kernel_items: list[tuple[tuple, int]] = []  # (plan key, row)
        host_items: list[tuple[tuple, int]] = []
        for key, plan in plans.items():
            target = (host_items if plan.needs_host_scan
                      or not self.engine.use_kernel else kernel_items)
            target.extend((key, int(r)) for r in plan.rows)

        def fetch_order(item: tuple[tuple, int]) -> tuple[int, int]:
            return (int(self.index.shard_id[item[1]]),
                    int(self.index.offset[item[1]]))

        kernel_items.sort(key=fetch_order)
        host_items.sort(key=fetch_order)

        n_scanned = bytes_scanned = 0
        for chunk in self._chunks(kernel_items):
            chunk = [item for item in chunk if item[0] not in failures]
            if not chunk:
                continue
            try:
                bufs, chunk = self._fetch_chunk(chunk)
                if chunk:
                    self._scan_chunk(chunk, plans, bufs, results)
                n_scanned += len(chunk)
                bytes_scanned += sum(len(bufs[row]) for _, row in chunk)
            except Exception as exc:
                self._fail_chunk(chunk, exc, failures)

        # host path (literal sweep / regex gate, no device work): same
        # chunked fetch-dedup-release structure as the kernel path
        for chunk in self._chunks(host_items):
            chunk = [item for item in chunk if item[0] not in failures]
            if not chunk:
                continue
            try:
                bufs, chunk = self._fetch_chunk(chunk)
                with self._stage("gw.host_verify",
                                 attrs={"rows": len(chunk)}):
                    for key, row in chunk:
                        plan = plans[key]
                        buf = bufs[row]
                        self._finish_row(plan, key, row, buf,
                                         plan.host_scan(buf), results)
                        n_scanned += 1
                        bytes_scanned += len(buf)
            except Exception as exc:
                self._fail_chunk(chunk, exc, failures)

        self.metrics.inc("host_scans", len(host_items))
        self.metrics.inc("records_scanned", n_scanned)
        self.metrics.inc("bytes_scanned", bytes_scanned)
        for hits in results.values():
            hits.sort(key=lambda h: h.index_row)
        return results, failures

    def _chunks(self, items: list[tuple[tuple, int]]
                ) -> "list[list[tuple[tuple, int]]]":
        """Split scan items under the engine's batch record/byte limits,
        sized from the index (``uncomp_len`` == payload length)."""
        chunks: list[list[tuple[tuple, int]]] = []
        current: list[tuple[tuple, int]] = []
        pending = 0
        for item in items:
            current.append(item)
            pending += int(self.index.uncomp_len[item[1]])
            if (len(current) >= self.engine.batch_records
                    or pending >= self.engine.batch_bytes):
                chunks.append(current)
                current, pending = [], 0
        if current:
            chunks.append(current)
        return chunks

    def _finish_row(self, plan: QueryPlan, key: tuple, row: int, buf: bytes,
                    lit_positions: np.ndarray,
                    results: dict[tuple, list[PatternHit]]) -> None:
        final, first_len = plan.verify(buf, lit_positions)
        if final.size:
            results[key].append(self.engine.make_hit(row, buf, final,
                                                     first_len))

    def _scan_chunk(self, chunk: list[tuple[tuple, int]],
                    plans: dict[tuple, QueryPlan], bufs: dict[int, bytes],
                    results: dict[tuple, list[PatternHit]]) -> None:
        from repro.kernels.bucketing import dispatch_count
        from repro.kernels.pattern_scan import find_pattern_masks_multi

        chunk_bufs = [bufs[row] for _, row in chunk]
        chunk_pats = [plans[key].kernel_pattern for key, _ in chunk]
        with self._stage("gw.kernel_dispatch",
                         attrs={"rows": len(chunk)}) as sp:
            masks = find_pattern_masks_multi(chunk_bufs, chunk_pats,
                                             block=self.engine.scan_block,
                                             interpret=self.engine.interpret)
            dispatches = dispatch_count(
                [len(b) for b in chunk_bufs], self.engine.scan_block)
            if sp is not None:
                sp.set_attr("dispatches", dispatches)
        self.metrics.inc("kernel_dispatches", dispatches)
        with self._stage("gw.host_verify", attrs={"rows": len(chunk)}):
            for (key, row), mask, buf in zip(chunk, masks, chunk_bufs):
                self._finish_row(plans[key], key, row, buf,
                                 np.flatnonzero(mask), results)

    # -- lifecycle -------------------------------------------------------
    def _fail_queued(self) -> None:
        """Fail every currently queued ticket with :class:`GatewayClosed`
        (queue gets hand tickets to exactly one caller each, so this can
        race a live scheduler without double-resolving any future)."""
        while True:
            try:
                ticket = self._queue.get_nowait()
            except queue.Empty:
                return
            if ticket.future.set_running_or_notify_cancel():
                ticket.future.set_exception(GatewayClosed("gateway closed"))

    def close(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the scheduler; by default serve everything already queued.

        ``drain=False`` fails queued-but-unserved requests with
        :class:`GatewayClosed` instead of serving them. Raises
        ``TimeoutError`` if the scheduler is still mid-scan after
        ``timeout`` — the engine is left open for it; call ``close``
        again to retry teardown.
        """
        if self._closed and not self._thread.is_alive():
            return
        self._closed = True  # reject new submissions immediately
        if not drain:
            self._fail_queued()
        self._stop.set()
        self._thread.join(timeout)
        if self._thread.is_alive():
            raise TimeoutError(
                f"gateway scheduler still serving after {timeout}s; "
                f"engine left open — retry close() to finish teardown")
        # a submit that passed the closed check concurrently with close()
        # may have enqueued after the scheduler exited — fail it rather
        # than leave its future forever pending
        self._fail_queued()
        self.engine.close()

    def __enter__(self) -> "ArchiveGateway":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
