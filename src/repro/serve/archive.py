"""``repro.serve.archive`` — sharded async archive query gateway
(DESIGN.md §8 and §12).

PR 2's :class:`~repro.index.service.IndexQueryService` is synchronous:
every request pays for its own scan. PR 3 added this multi-tenant layer
— admission queue, request coalescing, cross-request kernel batching, a
byte-budgeted record cache — but with **one** scheduler thread, and
BENCH_serve.json recorded the consequence: throughput collapsed ~5×
from 8 to 64 clients while PR 8's stage attribution showed 90% of
request time was ``queue_wait`` behind that single drain loop.

PR 9 makes the gateway a **supervised shard pool**:

* **router front end** (this class) — :meth:`submit` hashes the
  request's *scan identity* (``QueryRequest.scan_key``) onto one of N
  :class:`~repro.serve.shard.ShardScheduler` shards. Affinity hashing
  is what keeps coalescing intact: identical scans always route to the
  same shard, so its in-flight registry sees every duplicate, exactly
  as the single scheduler did;
* **per-shard admission budgets** — each shard bounds its own queue
  depth (``max_pending`` is per shard) and optionally its pending
  estimated scan bytes; rejections are typed, shard-tagged
  :class:`GatewayOverloaded` (``.shard``/``.reason``) instead of one
  global cliff. Overload never spills to a sibling shard — that would
  split a scan identity across two in-flight registries and silently
  un-coalesce it;
* **sharded record cache** — :class:`~repro.serve.cache.
  ShardedRecordCache` consistent-hashes payload keys over per-slice
  TinyLFU caches: shards never duplicate hot bytes, and a shard death
  evicts only its slice;
* **supervision + re-drive** — a supervisor thread watches shard
  heartbeats/liveness, reaps a dead shard's tickets (queued, serving,
  and coalesce-attached alike), respawns it with capped backoff, and
  re-drives every orphan through the router **exactly once**; a ticket
  whose re-drive also dies fails with a typed
  :class:`GatewayShardDown`. Nothing is silently dropped and no future
  resolves twice (futures are claimed with
  ``set_running_or_notify_cancel`` before every resolution, everywhere).

``shards=1`` (the default) preserves the PR 3–8 topology and behaviour
exactly; the serving machinery itself lives in
:mod:`repro.serve.shard`. Request-scoped tracing (PR 8) is unchanged
but spans now carry a ``shard`` attribute and anomaly flight dumps are
shard-tagged.

Correctness bar: responses are **byte-identical** to what an independent
synchronous :class:`~repro.index.query.QueryEngine` run would produce —
routing, coalescing, caching, shared dispatch and re-drive change *when*
and *where* work happens, never *what* is computed (the soak + chaos
tests assert exactly this).
"""
from __future__ import annotations

import hashlib
import threading
import time
from concurrent.futures import Future

from repro.index.cdx import CdxIndex
from repro.index.query import QueryEngine
from repro.index.service import QueryRequest, QueryResponse
from repro.obs import flight as obs_flight
from repro.obs import trace as obs_trace
from .cache import ShardedRecordCache
from .metrics import GatewayMetrics
from .shard import (_NULL_CM, GatewayClosed, GatewayOverloaded,
                    GatewayShardDown, GatewayTimeout, ShardScheduler,
                    _StageCM, _Ticket)

__all__ = ["ArchiveGateway", "GatewayClosed", "GatewayOverloaded",
           "GatewayShardDown", "GatewayTimeout"]


def _key_hash(key: tuple) -> int:
    """Stable 64-bit hash of a scan identity (process-independent —
    ``repr`` of the key tuple, not Python's seeded ``hash``)."""
    digest = hashlib.blake2b(repr(key).encode("utf-8", "backslashreplace"),
                             digest_size=8).digest()
    return int.from_bytes(digest, "big")


class ArchiveGateway:
    """Sharded, coalescing, cross-request-batching query front end.

    >>> with ArchiveGateway(index, shards=4) as gw:
    ...     fut = gw.submit(QueryRequest(b"nginx"))
    ...     response = fut.result()
    ...     gw.metrics.snapshot(gw.cache)["dispatches_per_request"]

    Parameters
    ----------
    index:
        the corpus CDX index the gateway serves.
    shards:
        scheduler shard count (default 1 — the pre-PR 9 topology).
        Each shard owns an engine, a drain thread and its own admission
        budget; requests route by scan-identity affinity hashing.
    engine:
        optional pre-built :class:`QueryEngine` for shard 0; owned (and
        closed) by its shard either way. Additional shards build their
        own via ``engine_factory`` / the default constructor args.
    engine_factory:
        ``callable(shard_id) -> QueryEngine`` for building per-shard
        engines (tests inject instrumented engines this way).
    max_pending:
        **per-shard** admission-queue bound — the backpressure knob.
    shard_byte_budget:
        optional per-shard bound on *pending estimated scan bytes*:
        each unique queued scan identity charges ``est_scan_bytes``
        (coalesced duplicates are free); over budget, new identities
        are rejected with ``GatewayOverloaded(reason="bytes")``.
    est_scan_bytes:
        the per-unique-scan byte charge above (default 1 MiB).
    max_batch_requests:
        how many queued requests one shard drain may aggregate.
    cache_bytes:
        byte budget of the decompressed-payload cache, split evenly
        across per-shard consistent-hash slices.
    cache_admission:
        ``"tinylfu"`` (default) or ``"lru"`` — per slice, as before.
    default_deadline_s:
        deadline applied to every request that doesn't carry its own
        ``deadline_s`` at :meth:`submit`; expired requests resolve with
        :class:`GatewayTimeout` instead of occupying scan capacity.
    trace_requests:
        request-scoped span tracing (default on; the serve bench gates
        the traced path at ≤1.05× the untraced one).
    flight_recorder:
        where finished spans and anomaly dumps go; ``None`` uses the
        process-default :func:`repro.obs.flight.recorder`. Dumps
        tripped by a shard carry a ``shard<i>`` tag.
    slo_p99_s / queue_highwater:
        anomaly-dump trips, unchanged from PR 8 (highwater is per
        shard, default ¾ of ``max_pending``).
    max_respawns:
        how many times a dying shard is respawned before it is retired
        (marked permanently down; traffic routes around it and its
        cache slice is removed from the ring).
    respawn_backoff_s:
        base of the capped exponential respawn backoff
        (``min(1s, base·2^respawns)``).
    """

    def __init__(self, index: CdxIndex, *, engine: QueryEngine | None = None,
                 shards: int = 1,
                 engine_factory=None,
                 max_pending: int = 256, max_batch_requests: int = 16,
                 cache_bytes: int = 64 << 20, cache_admission: str = "tinylfu",
                 use_kernel: bool = True,
                 interpret: bool = True, poll_interval_s: float = 0.02,
                 default_deadline_s: float | None = None,
                 trace_requests: bool = True,
                 flight_recorder: obs_flight.FlightRecorder | None = None,
                 slo_p99_s: float | None = None,
                 queue_highwater: int | None = None,
                 shard_byte_budget: int | None = None,
                 est_scan_bytes: int = 1 << 20,
                 max_respawns: int = 3,
                 respawn_backoff_s: float = 0.05,
                 ) -> None:
        n = max(1, int(shards))
        self.index = index
        self.cache = ShardedRecordCache(cache_bytes, n,
                                        admission=cache_admission)
        self.metrics = GatewayMetrics()
        self.default_deadline_s = default_deadline_s
        self._trace = bool(trace_requests)
        self._flight = flight_recorder if flight_recorder is not None \
            else obs_flight.recorder()
        self._max_respawns = max(0, int(max_respawns))
        self._backoff = max(0.0, respawn_backoff_s)
        self._closed = False
        self._reap_lock = threading.Lock()

        def _default_engine(_i: int) -> QueryEngine:
            return QueryEngine(index, use_kernel=use_kernel,
                               interpret=interpret)

        factory = engine_factory if engine_factory is not None \
            else _default_engine
        self._shards: list[ShardScheduler] = []
        for i in range(n):
            eng = engine if (i == 0 and engine is not None) else factory(i)
            self._shards.append(ShardScheduler(
                i, engine=eng, cache=self.cache, metrics=self.metrics,
                max_pending=max_pending, byte_budget=shard_byte_budget,
                est_scan_bytes=est_scan_bytes,
                max_batch_requests=max_batch_requests,
                poll_interval_s=poll_interval_s,
                trace_requests=trace_requests,
                flight_recorder=self._flight,
                slo_p99_s=slo_p99_s, queue_highwater=queue_highwater))
        self.metrics.gauge_set("shards", n)
        for shard in self._shards:
            shard.start()
        self._sup_stop = threading.Event()
        self._sup_thread = threading.Thread(
            target=self._supervise, daemon=True, name="gw-supervisor")
        self._sup_thread.start()

    # -- public surface ---------------------------------------------------
    @property
    def shards(self) -> list[ShardScheduler]:
        return self._shards

    @property
    def engine(self) -> QueryEngine:
        """Shard 0's engine (single-shard compatibility surface)."""
        return self._shards[0].engine

    def pending(self) -> int:
        return sum(shard.pending() for shard in self._shards)

    # -- tracing plumbing -------------------------------------------------
    def _end_span(self, span: obs_trace.Span | None) -> None:
        if span is not None:
            self.metrics.observe_stage(span.name,
                                       span.finish(recorder=self._flight))

    def _stage(self, name: str, parent=None, attrs=None):
        if not self._trace:
            return _NULL_CM
        return _StageCM(self, name, parent, attrs)

    def _trip(self, reason: str, attrs: dict | None = None,
              tag: str | None = None) -> None:
        if self._flight.trip(reason, attrs, tag=tag) is not None:
            self.metrics.inc("flight_dumps")

    # -- routing ----------------------------------------------------------
    def _shard_index(self, key: tuple) -> int:
        """Affinity home of a scan identity (ignoring down shards)."""
        return _key_hash(key) % len(self._shards)

    def _candidates(self, key: tuple):
        """The affinity ring walk: owner shard first, then successors,
        skipping permanently-down shards. Affinity is what preserves
        coalescing — every candidate order for a given key is stable
        while the down-set is stable."""
        shards = self._shards
        start = _key_hash(key) % len(shards)
        for j in range(len(shards)):
            shard = shards[(start + j) % len(shards)]
            if not shard.down:
                yield shard

    def _admit(self, key: tuple, ticket: _Ticket, *, block: bool,
               timeout: float | None, force: bool = False
               ) -> tuple[str, int, ShardScheduler]:
        last: GatewayShardDown | None = None
        for shard in self._candidates(key):
            try:
                status, detail = shard.admit(ticket, block=block,
                                             timeout=timeout, force=force)
                return status, detail, shard
            except GatewayShardDown as exc:
                last = exc  # raced a retirement: next ring candidate
                continue
        raise last if last is not None else GatewayShardDown(
            "all gateway shards are down")

    # -- client side -----------------------------------------------------
    def submit(self, request: QueryRequest, *, block: bool = True,
               timeout: float | None = None,
               deadline_s: float | None = None) -> "Future[QueryResponse]":
        """Route one request to its affinity shard; returns the future.

        An identical scan already **executing** on the shard is joined
        directly (the in-flight coalescing fast path, no queue slot);
        identical requests sitting in the shard queue merge when it
        drains them into the same batch. With ``block=False`` (or on
        ``timeout``) an over-budget shard raises
        :class:`GatewayOverloaded` — typed, shard-tagged backpressure.

        ``deadline_s`` (default: the gateway's ``default_deadline_s``)
        bounds how long the request may wait end-to-end: a ticket whose
        deadline expires before its batch resolves gets
        :class:`GatewayTimeout` instead of a response — under overload
        the shards shed expired queue entries without scanning for them.
        """
        if self._closed:
            raise GatewayClosed("gateway is closed")
        ticket = _Ticket(request)
        budget = deadline_s if deadline_s is not None \
            else self.default_deadline_s
        if budget is not None:
            ticket.deadline = ticket.t_submit + budget
        adm = None
        if self._trace:
            # root span: the whole request, submit → resolution; its
            # trace id rides the ticket across the scheduler boundary
            ticket.span = obs_trace.start_span(
                "gw.request", parent=obs_trace.ROOT, t0=ticket.t_submit,
                attrs={"pattern": repr(request.pattern[:64]),
                       "regex": request.regex, "top_k": request.top_k})
            adm = obs_trace.start_span("gw.admission", ticket.span,
                                       t0=ticket.t_submit)
        key = request.scan_key()
        try:
            status, detail, shard = self._admit(key, ticket, block=block,
                                                timeout=timeout)
        except (GatewayOverloaded, GatewayShardDown) as exc:
            if adm is not None:
                adm.set_attr("rejected", True)
                if getattr(exc, "shard", None) is not None:
                    adm.set_attr("shard", exc.shard)
                self._end_span(adm)
                ticket.span.set_attr("error", type(exc).__name__)
                ticket.span.finish(recorder=self._flight)
            raise
        if adm is not None:
            adm.set_attr("shard", shard.shard_id)
            self._end_span(adm)
            if status == "attached":
                with self._stage("gw.coalesce_attach", ticket.span,
                                 attrs={"inflight_waiters": detail,
                                        "shard": shard.shard_id}):
                    pass
            else:
                ticket.wait_span = obs_trace.start_span(
                    "gw.queue_wait", ticket.span,
                    attrs={"shard": shard.shard_id})
        if status == "queued" and self._closed and not shard.alive():
            # raced close(): we passed the closed check before close()
            # flipped it, but enqueued after the drain thread exited —
            # no one will serve the queue again, so fail it now
            shard.fail_queued()
        return ticket.future

    def query(self, request: QueryRequest,
              timeout: float | None = None) -> QueryResponse:
        """Synchronous convenience: submit and wait."""
        return self.submit(request).result(timeout)

    def snapshot(self):
        """Observability hook: one merged :class:`~repro.obs.ObsSnapshot`
        — this gateway's private metrics registry + cache counters
        (source ``"gateway"``) merged with the process-default registry
        (kernel dispatch profile, ingest counters, harvested children).
        For the raw dict surface keep using ``gateway.metrics.snapshot()``.
        """
        from repro import obs

        return obs.snapshot().merged_with(
            self.metrics.obs_snapshot(self.cache))

    # -- supervision + re-drive -------------------------------------------
    def _supervise(self) -> None:
        while not self._sup_stop.wait(0.02):
            for shard in self._shards:
                if shard.dead and not shard.alive() and not shard.closed:
                    self._reap(shard)

    def _reap(self, shard: ShardScheduler, closing: bool = False) -> None:
        """Handle one shard death: collect its tickets exactly once,
        respawn (capped backoff) or retire it, re-drive the orphans."""
        with self._reap_lock:
            if shard._reaped or not shard.dead:
                return  # lost the race: someone else already reaped it
            sid = shard.shard_id
            self.metrics.inc("shard_deaths")
            self._trip("shard_down",
                       {"shard": sid, "respawns": shard.respawns},
                       tag=f"shard{sid}")
            retire = closing or shard.respawns >= self._max_respawns
            if retire:
                # retirement: route around it and drop its cache slice
                # from the ring (only *its* keys are invalidated)
                shard.mark_down()
                self.metrics.inc("shards_down")
                self.cache.remove_slice(sid)
            orphans = shard.take_orphans()
            if not retire:
                delay = min(1.0, self._backoff * (2 ** shard.respawns))
                if delay > 0:
                    time.sleep(delay)
                # a dirty death may have left mid-fill entries behind:
                # evict this shard's slice only, siblings keep their heat
                self.cache.clear_slice(sid)
                shard.respawn()
                self.metrics.inc("shard_respawns")
        for ticket in orphans:
            self._redrive(ticket, sid)

    def _redrive(self, ticket: _Ticket, from_shard: int) -> None:
        """Recover one orphaned ticket: exactly one re-route through the
        affinity ring (budgets bypassed — it was already admitted once);
        a second death fails it with :class:`GatewayShardDown`."""
        if ticket.future.done():
            return
        if ticket.redriven:
            self._fail_shard_down(ticket, from_shard)
            return
        ticket.redriven = True
        self.metrics.inc("redriven")
        if ticket.span is not None:
            with self._stage("gw.redrive", ticket.span,
                             attrs={"from_shard": from_shard}):
                pass
        try:
            self._admit(ticket.request.scan_key(), ticket,
                        block=False, timeout=None, force=True)
        except GatewayShardDown:
            self._fail_shard_down(ticket, from_shard)

    def _fail_shard_down(self, ticket: _Ticket, shard_id: int) -> None:
        """Typed terminal failure for an unrecoverable orphan (claimed
        first, so a raced resolution can never double-resolve)."""
        if not ticket.future.set_running_or_notify_cancel():
            return
        ticket.future.set_exception(GatewayShardDown(
            f"shard {shard_id} died before serving this request",
            shard=shard_id))
        self.metrics.inc("shard_down_errors")
        if ticket.span is not None:
            ticket.span.set_attr("error", "GatewayShardDown")
            ticket.span.finish(recorder=self._flight)

    # -- lifecycle -------------------------------------------------------
    def close(self, *, drain: bool = True, timeout: float = 30.0) -> None:
        """Stop the pool; by default serve everything already queued.

        Order matters for the close audit: (1) reject new submissions,
        (2) stop the supervisor (no respawns during teardown), (3) reap
        any already-dead shard — its orphans re-drive into siblings that
        are *still open* and will drain them, (4) close shards one by
        one (each serves its queue), (5) fail anything a shard that died
        *during* its own drain left behind, with :class:`GatewayShardDown`.
        A waiter attached to an in-flight batch on shard A is resolved by
        step (4) regardless of what order siblings closed in — shards
        never wait on each other, so there is no deadlock to have.

        ``drain=False`` fails queued-but-unserved requests with
        :class:`GatewayClosed` instead of serving them. Raises
        ``TimeoutError`` if any shard is still mid-scan after
        ``timeout`` — its engine is left open; call ``close`` again to
        retry teardown.
        """
        self._closed = True  # reject new submissions immediately
        self._sup_stop.set()
        if self._sup_thread.is_alive():
            self._sup_thread.join(5.0)
        for shard in self._shards:
            if shard.dead and not shard.alive():
                self._reap(shard, closing=True)
        timeout_exc: TimeoutError | None = None
        for shard in self._shards:
            try:
                shard.close(drain=drain, timeout=timeout)
            except TimeoutError as exc:
                timeout_exc = timeout_exc or exc
        for shard in self._shards:
            # a death mid-close-drain cannot re-drive (siblings are
            # closing/closed): typed failure, never a silent drop
            if shard.dead:
                for ticket in shard.take_orphans():
                    self._fail_shard_down(ticket, shard.shard_id)
        if timeout_exc is not None:
            raise timeout_exc

    def __enter__(self) -> "ArchiveGateway":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
