"""Gateway metrics: the numbers that make the serving wins *measurable*.

The ISSUE's acceptance criterion is not "the gateway feels faster" but
"fewer kernel dispatches per request, observable in metrics" — so the
gateway counts everything that matters (requests, coalesced waiters,
unique scans, kernel dispatches, records/bytes scanned, fetches).

Since PR 7 this is a thin facade over :class:`repro.obs.Registry` — the
same counter/histogram machinery the ingest path, the worker pools and
the kernel profiler publish through — instead of its own lock + dict +
latency list. The unbounded per-request latency list is gone: latencies
land in the registry's bounded reservoir histogram (exact below
``repro.obs.HISTOGRAM_CAP`` samples, deterministic Algorithm-R sampling
beyond), which is what the PR 3 docstring deferred to "if that ever
changes". p50/p99 keep the same linear interpolation, so numbers stay
comparable.

Each ``GatewayMetrics`` owns a private registry (source ``"gateway"``):
two gateways in one process never cross-count, and
:meth:`obs_snapshot` exports the whole surface as a mergeable
:class:`~repro.obs.ObsSnapshot`.

Thread-safe: submit-side counters race with the scheduler thread.
"""
from __future__ import annotations

from repro.obs.export import breakdown_from_snapshot
from repro.obs.registry import ObsSnapshot, Registry, percentile

__all__ = ["GatewayMetrics", "percentile"]

_LATENCY_HIST = "gateway.latency_s"
_STAGE_PREFIX = "gateway.stage."


class GatewayMetrics:
    """Counter + latency surface for :class:`repro.serve.archive.ArchiveGateway`."""

    _COUNTERS = (
        "requests",            # submitted (accepted) requests
        "rejected",            # admission-queue overflows (backpressure)
        "responses",           # resolved requests
        "coalesced",           # requests served by another request's scan
        "unique_scans",        # scans actually planned + executed
        "scan_batches",        # drained scheduler batches
        "kernel_dispatches",   # Pallas calls issued (shared across requests)
        "host_scans",          # records scanned on the host path
        "records_scanned",     # candidate records through the scan stage
        "bytes_scanned",
        "records_fetched",     # payload fetches that missed the cache
        "store_fetches",       # of "records_fetched": served from an
                               # attached columnar store (no seek/inflate)
        "errors",              # scans resolved with an exception
        "timeouts",            # requests resolved with GatewayTimeout
        "read_errors",         # damaged-record fetches (RecordReadError)
        "quarantined_rows",    # candidate rows skipped as unreadable
        "flight_dumps",        # anomaly-tripped flight-recorder dumps
        # PR 9 — sharded-gateway robustness surface
        "rejected_bytes",      # of "rejected": pending-byte-budget refusals
        "shard_deaths",        # drain threads that exited abnormally
        "shard_respawns",      # deaths recovered by a respawn
        "shards_down",         # shards retired permanently (respawns spent)
        "redriven",            # orphaned tickets re-routed exactly once
        "shard_down_errors",   # tickets failed typed with GatewayShardDown
    )

    def __init__(self, registry: Registry | None = None) -> None:
        self._reg = registry if registry is not None \
            else Registry(source="gateway")
        self._hw_seen = 0  # global queue-depth high-water across shards
        # declare every counter up front: count()/snapshot() report 0 for
        # untouched counters instead of KeyError/absence
        for name in self._COUNTERS:
            self._reg.counter_add(name, 0)

    @property
    def registry(self) -> Registry:
        return self._reg

    def inc(self, name: str, n: int = 1) -> None:
        self._reg.counter_add(name, n)

    def observe_latency(self, seconds: float) -> None:
        self._reg.observe(_LATENCY_HIST, seconds)

    def observe_stage(self, span_name: str, seconds: float) -> None:
        """Record one request-scoped stage duration (PR 8 tracing):
        span name ``gw.<stage>`` lands in the ``gateway.stage.<stage>_s``
        histogram, the source `repro.obs.export.breakdown_from_snapshot`
        attributes from."""
        stage = span_name[3:] if span_name.startswith("gw.") else span_name
        self._reg.observe(f"{_STAGE_PREFIX}{stage}_s", seconds)

    def gauge_set(self, name: str, value: float) -> None:
        """Set a gauge (prefixed ``gateway.`` for the merged snapshot)."""
        self._reg.gauge_set(f"gateway.{name}", value)

    def note_global_depth(self, depth: int) -> None:
        """Fold one shard's observed queue depth into the gateway-wide
        ``queue_depth`` gauge and its monotone high-water mark (each
        shard also publishes ``shard<i>.queue_depth`` for attribution —
        the global gauge is the most recent observation from any shard,
        kept for surface compatibility with the single-scheduler era)."""
        self.gauge_set("queue_depth", depth)
        if depth > self._hw_seen:
            self._hw_seen = depth
            self.gauge_set("queue_depth_highwater", depth)

    def count(self, name: str) -> int:
        return self._reg.counter(name)

    def latency_s(self, q: float) -> float:
        return self._reg.quantile(_LATENCY_HIST, q)

    def latency_count(self) -> int:
        return self._reg.hist_count(_LATENCY_HIST)

    def stage_quantile(self, stage: str, q: float) -> float:
        return self._reg.quantile(f"{_STAGE_PREFIX}{stage}_s", q)

    def snapshot(self, cache=None) -> dict:
        """One coherent view: raw counters + the derived headline rates.

        ``cache`` — optional :class:`repro.serve.cache.RecordCache`; its
        counters are folded in under ``cache_*`` keys.
        """
        snap = self._reg.snapshot()
        out: dict = {name: snap.counter(name) for name in self._COUNTERS}
        responses = max(out["responses"], 1)
        out["latency_p50_ms"] = snap.quantile(_LATENCY_HIST, 50) * 1e3
        out["latency_p99_ms"] = snap.quantile(_LATENCY_HIST, 99) * 1e3
        out["coalesce_rate"] = out["coalesced"] / max(out["requests"], 1)
        out["dispatches_per_request"] = out["kernel_dispatches"] / responses
        out["records_scanned_per_request"] = out["records_scanned"] / responses
        out["queue_depth"] = snap.gauge("gateway.queue_depth")
        out["queue_depth_highwater"] = snap.gauge(
            "gateway.queue_depth_highwater")
        stages = breakdown_from_snapshot(snap)
        if stages:  # request tracing on: per-stage attribution rides along
            out["stages"] = stages
        if cache is not None:
            for key, value in cache.snapshot().items():
                out[f"cache_{key}"] = value
        return out

    def obs_snapshot(self, cache=None) -> ObsSnapshot:
        """The same surface as a mergeable :class:`ObsSnapshot`, counters
        prefixed ``gateway.``; cache counters fold in as
        ``gateway.cache.*``."""
        raw = self._reg.snapshot()
        out = ObsSnapshot(sources=("gateway",))
        out.counters = {f"gateway.{k}": v for k, v in raw.counters.items()}
        # gauge_set already stores gauges gateway.-prefixed (snapshot()
        # reads them by that name); re-prefixing would yield gateway.gateway.*
        out.gauges = {k if k.startswith("gateway.") else f"gateway.{k}": v
                      for k, v in raw.gauges.items()}
        out.histograms = dict(raw.histograms)  # already gateway.-prefixed
        if cache is not None:
            for key, value in cache.snapshot().items():
                if isinstance(value, float):
                    out.gauges[f"gateway.cache.{key}"] = value
                elif isinstance(value, int):
                    out.counters[f"gateway.cache.{key}"] = value
                # non-numeric cache fields (e.g. the policy name) have no
                # counter/gauge representation and are skipped
        return out
