"""Gateway metrics: the numbers that make the serving wins *measurable*.

The ISSUE's acceptance criterion is not "the gateway feels faster" but
"fewer kernel dispatches per request, observable in metrics" — so the
gateway counts everything that matters (requests, coalesced waiters,
unique scans, kernel dispatches, records/bytes scanned, fetches) and
keeps every per-request latency so p50/p99 are exact, not bucketed
(serving-bench scale is thousands of requests, not millions; a
reservoir can replace the list if that ever changes).

Thread-safe: submit-side counters race with the scheduler thread.
"""
from __future__ import annotations

import threading

__all__ = ["GatewayMetrics", "percentile"]


def percentile(values: list[float], q: float) -> float:
    """Linear-interpolated percentile (``q`` in [0, 100]) of a list."""
    if not values:
        return 0.0
    data = sorted(values)
    if len(data) == 1:
        return data[0]
    rank = (q / 100.0) * (len(data) - 1)
    lo = int(rank)
    hi = min(lo + 1, len(data) - 1)
    frac = rank - lo
    return data[lo] * (1.0 - frac) + data[hi] * frac


class GatewayMetrics:
    """Counter + latency surface for :class:`repro.serve.archive.ArchiveGateway`."""

    _COUNTERS = (
        "requests",            # submitted (accepted) requests
        "rejected",            # admission-queue overflows (backpressure)
        "responses",           # resolved requests
        "coalesced",           # requests served by another request's scan
        "unique_scans",        # scans actually planned + executed
        "scan_batches",        # drained scheduler batches
        "kernel_dispatches",   # Pallas calls issued (shared across requests)
        "host_scans",          # records scanned on the host path
        "records_scanned",     # candidate records through the scan stage
        "bytes_scanned",
        "records_fetched",     # payload fetches that missed the cache
        "errors",              # scans resolved with an exception
        "timeouts",            # requests resolved with GatewayTimeout
        "read_errors",         # damaged-record fetches (RecordReadError)
        "quarantined_rows",    # candidate rows skipped as unreadable
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counts = {name: 0 for name in self._COUNTERS}
        self._latencies: list[float] = []

    def inc(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counts[name] += n

    def observe_latency(self, seconds: float) -> None:
        with self._lock:
            self._latencies.append(seconds)

    def count(self, name: str) -> int:
        with self._lock:
            return self._counts[name]

    def latency_s(self, q: float) -> float:
        with self._lock:
            return percentile(self._latencies, q)

    def snapshot(self, cache=None) -> dict:
        """One coherent view: raw counters + the derived headline rates.

        ``cache`` — optional :class:`repro.serve.cache.RecordCache`; its
        counters are folded in under ``cache_*`` keys.
        """
        with self._lock:
            out: dict = dict(self._counts)
            lat = list(self._latencies)
        responses = max(out["responses"], 1)
        out["latency_p50_ms"] = percentile(lat, 50) * 1e3
        out["latency_p99_ms"] = percentile(lat, 99) * 1e3
        out["coalesce_rate"] = out["coalesced"] / max(out["requests"], 1)
        out["dispatches_per_request"] = out["kernel_dispatches"] / responses
        out["records_scanned_per_request"] = out["records_scanned"] / responses
        if cache is not None:
            for key, value in cache.snapshot().items():
                out[f"cache_{key}"] = value
        return out
