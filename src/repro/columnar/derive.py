"""Derivation pipeline: parse a WARC corpus once → columnar shards.

``derive()`` is the "data-to-insight" compressor the related-work
papers argue for (ArchiveSpark, "The Case for Alternative Web Archival
Formats"): the zero-copy parser sweeps every source shard exactly once
(fanned out through :func:`repro.core.parallel.map_shards`, supervised
on request), and everything a query will ever touch comes out the other
side as :mod:`repro.columnar.store` columns —

* per-shard extraction (worker side): stream offsets, content lengths,
  record types, HTTP statuses, WARC-Date timestamps, URI/MIME heaps,
  and the raw content blocks concatenated into one picklable buffer;
* packing (parent side): a global :func:`~repro.columnar.store.pack_plan`
  over the merged lengths cuts half-step width-bucketed row-groups;
  each matrix is assembled once, streamed into the payload blob, and
  swept once by the **fused** row-group kernel
  (:func:`repro.kernels.digest_sig.digest_signature_rowgroup`) for the
  digest + signature columns — bit-identical to a CDX build of the same
  corpus, at row-group pad waste instead of ragged-batch pad waste.

So: each source byte is decompressed once, parsed once, and swept once
— after that, every query runs on the mmapped columns.
"""
from __future__ import annotations

import calendar
import functools
import os
import time
import zlib

import numpy as np

from repro.core.warc.fastwarc import FastWARCIterator
from repro.core.warc.streams import detect_compression
from repro.index.signature import SIG_BITS, SIG_HASHES, SIG_NGRAM
from repro.kernels.bucketing import ROWGROUP_PAD
from .codec import ColumnWriter
from .store import RG_MAX_BYTES, RG_MAX_ROWS, ColumnStore, FORMAT, \
    STORE_VERSION, pack_plan

__all__ = ["derive", "parse_warc_date"]

_DATE_FMT = "%Y-%m-%dT%H:%M:%SZ"
_BLOCK = 2048  # digest kernel Adler block (persisted in store meta)


def parse_warc_date(raw: bytes | None) -> int:
    """WARC-Date → epoch seconds (uint64 column value); 0 if unparsable.

    Zero is the documented "no timestamp" sentinel, not 1970-01-01T00:00:00
    — a real record carrying exactly the epoch would collide, which the
    synthetic and Common-Crawl corpora cannot produce.
    """
    if not raw:
        return 0
    try:
        return max(0, calendar.timegm(
            time.strptime(raw.decode("ascii").strip(), _DATE_FMT)))
    except (ValueError, UnicodeDecodeError):
        return 0


def _extract_shard(path: str, *, readahead: bool | None = None,
                   tolerant: bool = False) -> dict:
    """Worker-side single sweep of one shard → picklable column partial.

    Mirrors ``repro.index.cdx._index_shard``'s sweep (same iterator,
    same per-record fields, same row order) but carries the payload
    bytes out instead of digesting them in place — the parent packs
    them into row-groups and the fused kernel sweeps each group once.
    Content is appended to one buffer immediately, so the borrowed
    arena views never outlive the loop iteration.
    """
    with open(path, "rb") as f:
        kind = detect_compression(f.read(8))
    offsets: list[int] = []
    rtypes: list[int] = []
    statuses: list[int] = []
    stamps: list[int] = []
    payload = bytearray()
    pay_off = [0]
    uri_parts: list[bytes] = []
    mime_parts: list[bytes] = []
    uri_off = [0]
    mime_off = [0]
    it = FastWARCIterator(path, parse_http=True, readahead=readahead,
                          tolerant=tolerant)
    try:
        for record in it:
            offsets.append(record.stream_offset)
            payload += record.content_view()
            pay_off.append(len(payload))
            rtypes.append(int(record.record_type))
            http = record.http_headers
            status = (http.status_code if http is not None
                      and http.status_code is not None else -1)
            statuses.append(status if 0 <= status <= 0x7FFF else -1)
            stamps.append(parse_warc_date(
                record.header_bytes(b"WARC-Date:")))
            uri = record.header_bytes(b"WARC-Target-URI:") or b""
            mime = (http.get_bytes(b"Content-Type", b"") if http is not None
                    else record.header_bytes(b"Content-Type:") or b"")
            uri_parts.append(uri)
            mime_parts.append(mime)
            uri_off.append(uri_off[-1] + len(uri))
            mime_off.append(mime_off[-1] + len(mime))
    finally:
        it.close()
    return {
        "path": path, "kind": kind,
        "offsets": np.asarray(offsets, np.uint64),
        "rtypes": np.asarray(rtypes, np.uint16),
        "statuses": np.asarray(statuses, np.int16),
        "timestamps": np.asarray(stamps, np.uint64),
        "payload": bytes(payload),
        "pay_off": np.asarray(pay_off, np.uint64),
        "uri_heap": b"".join(uri_parts),
        "uri_off": np.asarray(uri_off, np.uint64),
        "mime_heap": b"".join(mime_parts),
        "mime_off": np.asarray(mime_off, np.uint64),
        "errors": list(it.error_ledger.entries()) if tolerant else [],
    }


def derive(paths, out_path: str, *, workers: int = 0,
           sig_bits: int = SIG_BITS, sig_ngram: int = SIG_NGRAM,
           sig_hashes: int = SIG_HASHES,
           max_rows: int = RG_MAX_ROWS, max_bytes: int = RG_MAX_BYTES,
           readahead: bool | None = None, tolerant: bool = False,
           supervise: bool = False, interpret: bool = True) -> ColumnStore:
    """Derive columnar shards from a WARC corpus; returns the opened store.

    One parser sweep per source shard (``workers > 0`` fans out through
    ``map_shards``; partials merge deterministically in shard order, so
    record rows match a CDX build of the same corpus 1:1), one fused
    kernel sweep per packed row-group. ``tolerant`` sweeps in recovery
    mode — skipped ranges surface on ``store.errors``; with
    ``supervise``, a shard that keeps killing workers is dropped and
    reported there too. The returned store carries the merged
    observability snapshot on ``store.obs`` (derive stage timings ride
    in the ``derive.*`` counters).
    """
    from repro import obs
    from repro.core.parallel import map_shards
    from repro.core.warc.errors import LedgerEntry
    from repro.index.cdx import _fused_supported
    from repro.index.signature import signature_of
    from repro.kernels.digest_sig import digest_signature_rowgroup

    if sig_bits <= 0 or sig_bits % 64:
        raise ValueError(f"sig_bits must be a positive multiple of 64, "
                         f"got {sig_bits}")
    if sig_ngram < 1 or sig_hashes < 1:
        raise ValueError("sig_ngram and sig_hashes must be >= 1")
    reg = obs.registry()
    paths = [str(p) for p in paths]
    t0 = time.perf_counter()
    sweep = functools.partial(_extract_shard, readahead=readahead,
                              tolerant=tolerant)
    partials, obs_snap = map_shards(sweep, paths, workers=workers,
                                    supervise=supervise, with_obs=True)
    t_parse = time.perf_counter()

    errors: list = []
    live: list[dict] = []
    shard_paths: list[str] = []
    shard_kinds: list[str] = []
    for path, part in zip(paths, partials):
        if part is None:  # quarantined by the pool supervisor
            try:
                size = os.path.getsize(path)
            except OSError:
                size = 0
            errors.append(LedgerEntry(
                shard=path, offset=0, error_class="shard_quarantined",
                bytes_skipped=size,
                message="shard repeatedly killed derive workers"))
            continue
        part["sid"] = len(shard_paths)
        shard_paths.append(part["path"])
        shard_kinds.append(part["kind"])
        errors.extend(part["errors"])
        live.append(part)
    if not live:
        raise ValueError("nothing to derive")

    # merge in shard order: row r of the store is row r of a CDX build
    shard_id = np.concatenate(
        [np.full(p["offsets"].size, p["sid"], np.uint32) for p in live])
    offset = np.concatenate([p["offsets"] for p in live])
    rtype = np.concatenate([p["rtypes"] for p in live])
    status = np.concatenate([p["statuses"] for p in live])
    timestamp = np.concatenate([p["timestamps"] for p in live])
    uri_off = [np.zeros(1, np.uint64)]
    mime_off = [np.zeros(1, np.uint64)]
    uri_base = mime_base = 0
    views: list[memoryview] = []  # per-record payload slices, row order
    lengths_l: list[np.ndarray] = []
    for p in live:
        uri_off.append(p["uri_off"][1:] + np.uint64(uri_base))
        mime_off.append(p["mime_off"][1:] + np.uint64(mime_base))
        uri_base += len(p["uri_heap"])
        mime_base += len(p["mime_heap"])
        mv = memoryview(p["payload"])
        po = p["pay_off"]
        views.extend(mv[int(po[i]):int(po[i + 1])]
                     for i in range(po.size - 1))
        lengths_l.append(np.diff(po).astype(np.uint64))
    length = (np.concatenate(lengths_l) if lengths_l
              else np.empty(0, np.uint64))
    n = int(length.size)
    plan = pack_plan(length, block=_BLOCK, max_rows=max_rows,
                     max_bytes=max_bytes)

    use_fused = _fused_supported(sig_bits, sig_ngram)
    digest = np.zeros(n, np.uint32)
    signatures = np.zeros((n, sig_bits // 64), np.uint64)
    rg_id = np.zeros(n, np.uint32)
    rg_row = np.zeros(n, np.uint32)
    rg_width = np.asarray([g.width for g in plan], np.uint64)
    rg_rows = np.asarray([g.rows.size for g in plan], np.uint64)
    rg_padded = np.asarray([g.padded_rows for g in plan], np.uint64)
    rg_byte_off = np.zeros(len(plan), np.uint64)
    rg_order = (np.concatenate([g.rows for g in plan]).astype(np.uint64)
                if plan else np.empty(0, np.uint64))

    writer = ColumnWriter(out_path, meta={
        "format": FORMAT, "store_version": STORE_VERSION,
        "sig_bits": sig_bits, "sig_ngram": sig_ngram,
        "sig_hashes": sig_hashes, "block": _BLOCK,
        "rowgroup_pad": ROWGROUP_PAD,
        "shard_paths": shard_paths, "shard_kinds": shard_kinds,
        "n_records": n,
    })
    t_sig = 0.0
    try:
        # payload first, streamed group-by-group: one transient matrix in
        # RAM at a time, and the same matrix feeds the fused sweep —
        # packing cost is paid exactly once
        writer.begin_blob("payload")
        for g, spec in enumerate(plan):
            mat = np.zeros((spec.padded_rows, spec.width + ROWGROUP_PAD),
                           np.uint8)
            for row, rec in enumerate(spec.rows):
                buf = views[rec]
                mat[row, :len(buf)] = np.frombuffer(buf, np.uint8)
            rg_byte_off[g] = writer.append(mat)
            rg_id[spec.rows] = g
            rg_row[spec.rows] = np.arange(spec.rows.size, dtype=np.uint32)
            glens = length[spec.rows].astype(np.int64)
            ts = time.perf_counter()
            if use_fused:
                d, s = digest_signature_rowgroup(
                    mat, glens, bits=sig_bits, n=sig_ngram, k=sig_hashes,
                    block=min(_BLOCK, spec.width), interpret=interpret)
            else:  # geometry outside the kernel: host two-pass per row
                d = np.asarray([zlib.adler32(views[rec]) & 0xFFFFFFFF
                                for rec in spec.rows], np.uint32)
                s = np.stack([signature_of(views[rec], bits=sig_bits,
                                           n=sig_ngram, k=sig_hashes)
                              for rec in spec.rows])
            t_sig += time.perf_counter() - ts
            digest[spec.rows] = d
            signatures[spec.rows] = s
        writer.end_blob()
        for name, arr in (
                ("shard_id", shard_id), ("offset", offset),
                ("length", length), ("rtype", rtype), ("status", status),
                ("timestamp", timestamp), ("digest", digest),
                ("signatures", signatures), ("rg_id", rg_id),
                ("rg_row", rg_row),
                ("uri_off", np.concatenate(uri_off)),
                ("mime_off", np.concatenate(mime_off)),
                ("rg_width", rg_width), ("rg_rows", rg_rows),
                ("rg_padded", rg_padded), ("rg_byte_off", rg_byte_off),
                ("rg_order", rg_order)):
            writer.add_array(name, arr)
        writer.add_blob("uri_heap", b"".join(p["uri_heap"] for p in live))
        writer.add_blob("mime_heap", b"".join(p["mime_heap"] for p in live))
        writer.close()
    except BaseException:
        writer._f.close()
        raise
    t_end = time.perf_counter()
    reg.counter_add("derive.records", n)
    reg.counter_add("derive.payload_bytes", int(length.sum()))
    reg.counter_add("derive.rowgroups", len(plan))
    reg.counter_add("derive.stage.parse_us",
                    int((t_parse - t0) * 1e6))
    reg.counter_add("derive.stage.digest_sig_us", int(t_sig * 1e6))
    reg.counter_add("derive.stage.pack_write_us",
                    int((t_end - t_parse - t_sig) * 1e6))

    store = ColumnStore(out_path)
    store.obs = obs_snap
    store.errors = errors
    return store
