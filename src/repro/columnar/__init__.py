"""``repro.columnar`` — derived columnar store: parse once, scan native.

The subsystem behind the ROADMAP "columnar derived store" item
(DESIGN.md §13): :mod:`.codec` is the generic column codec (shared with
the CDX v2 index), :mod:`.store` the versioned mmap-backed shard
format + reader, :mod:`.derive` the parse-once derivation pipeline.

Exports resolve lazily: :mod:`repro.index.cdx` imports :mod:`.codec`
while :mod:`.store` imports :mod:`repro.index` — eager re-exports here
would close that loop.
"""
from __future__ import annotations

__all__ = ["ArrayCursor", "ColumnFile", "ColumnStore", "ColumnWriter",
           "RowGroupSpec", "derive", "pack_arrays", "pack_plan",
           "parse_warc_date"]

_HOMES = {
    "ArrayCursor": "codec", "ColumnFile": "codec", "ColumnWriter": "codec",
    "pack_arrays": "codec",
    "ColumnStore": "store", "RowGroupSpec": "store", "pack_plan": "store",
    "derive": "derive", "parse_warc_date": "derive",
}


def __getattr__(name: str):
    home = _HOMES.get(name)
    if home is None:
        raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
    import importlib

    obj = getattr(importlib.import_module(f".{home}", __name__), name)
    # cache — and win over the submodule binding the import just made
    # (``derive`` names both the submodule and its entry point; the
    # exported callable must shadow the module object)
    globals()[name] = obj
    return obj


def __dir__():
    return sorted(set(globals()) | set(__all__))
