"""Columnar derived store: analytics-native shards of a WARC corpus.

The on-disk product of :mod:`repro.columnar.derive` (DESIGN.md §13):
one TOC'd container (:mod:`repro.columnar.codec`) holding, per record
of the source corpus —

* fixed-width metadata columns: ``offset`` (source stream offset),
  ``length`` (content bytes), ``rtype`` / ``status`` / ``timestamp``
  (WARC-Date as epoch seconds, 0 when unparsable), the Adler-32
  ``digest`` and the ``(n, bits//64)`` n-gram ``signatures`` matrix —
  the exact byte columns the CDX index stores, derived from the same
  single parse;
* URI / MIME byte heaps with ``(n+1)`` offset columns (CDX layout);
* the record's placement: ``rg_id`` / ``rg_row``;

plus the **payload row-groups**: extracted content blocks packed into
``(padded_rows, width + ROWGROUP_PAD)`` uint8 matrices in the kernels'
native layout — payload left-justified, zero tail — one matrix per
row-group, concatenated in one blob. Rows are grouped by half-step
width bucket at derive time (:func:`pack_plan`), so a full-corpus
kernel scan reads mmapped matrices **directly**: no per-record
decompression, HTTP parse, halo build, or re-bucketing on the query
path, and pad waste is the packer's (measured ~0.3, vs 0.90 for the
old ragged-batch bucketing).

Ownership: every matrix/column access is a zero-copy view on the
container mapping; :meth:`ColumnStore.close` raises ``BufferError``
while views are alive (the arena borrow rule, mmap edition — see
:mod:`repro.columnar.codec`).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.kernels.bucketing import (
    ROWGROUP_PAD,
    payload_width,
    quantize_count,
)
from .codec import ColumnFile

__all__ = ["ColumnStore", "FORMAT", "RowGroupSpec", "STORE_VERSION",
           "pack_plan"]

FORMAT = "repro-columnar"
STORE_VERSION = 1

# Row-group caps: bounded matrices keep a single kernel dispatch inside
# the VMEM-budgeted grouped grid and bound the transient matrix a derive
# holds in RAM while streaming the blob.
RG_MAX_ROWS = 1024
RG_MAX_BYTES = 8 << 20  # padded bytes per group

_BLOCK = 2048  # digest kernel Adler block (import-free: meta-validated)


@dataclass
class RowGroupSpec:
    """One planned row-group: which record rows share one matrix."""

    width: int            # payload columns (excl. ROWGROUP_PAD tail)
    rows: np.ndarray      # record rows packed here, in-group order
    padded_rows: int      # half-step quantized row count of the matrix

    @property
    def nbytes(self) -> int:
        return self.padded_rows * (self.width + ROWGROUP_PAD)


def pack_plan(lengths, *, block: int = _BLOCK, max_rows: int = RG_MAX_ROWS,
              max_bytes: int = RG_MAX_BYTES) -> list[RowGroupSpec]:
    """Plan row-groups for a corpus of payload lengths.

    Records are grouped by their half-step width bucket (equivalently:
    sorted by length and cut at bucket boundaries — every row in a group
    pads to the group width with ≤ 1.5× individual waste), then each
    bucket is chunked under the row/byte caps and its row count
    half-step quantized. Returned specs are ordered by ascending width,
    record order preserved within a bucket, so ``rg_id`` assignment is
    deterministic for a given corpus.
    """
    buckets: dict[int, list[int]] = {}
    for i, ln in enumerate(lengths):
        buckets.setdefault(payload_width(int(ln), block), []).append(i)
    plan: list[RowGroupSpec] = []
    for width in sorted(buckets):
        idxs = buckets[width]
        cap = max(1, min(max_rows, max_bytes // (width + ROWGROUP_PAD)))
        for s in range(0, len(idxs), cap):
            chunk = np.asarray(idxs[s:s + cap], np.int64)
            plan.append(RowGroupSpec(width=width, rows=chunk,
                                     padded_rows=quantize_count(chunk.size)))
    return plan


class ColumnStore:
    """mmap-backed reader over one derived columnar shard file."""

    def __init__(self, path: str) -> None:
        self._file = ColumnFile(path)
        meta = self._file.meta
        if meta.get("format") != FORMAT:
            self._file.close()
            raise ValueError(f"{path}: not a columnar store "
                             f"(format={meta.get('format')!r})")
        if meta.get("store_version") != STORE_VERSION:
            self._file.close()
            raise ValueError(f"{path}: unsupported store version "
                             f"{meta.get('store_version')}")
        self.path = path
        self.shard_paths: list[str] = list(meta["shard_paths"])
        self.shard_kinds: list[str] = list(meta["shard_kinds"])
        self.sig_bits: int = int(meta["sig_bits"])
        self.sig_ngram: int = int(meta["sig_ngram"])
        self.sig_hashes: int = int(meta["sig_hashes"])
        self.block: int = int(meta["block"])
        self.pad: int = int(meta["rowgroup_pad"])
        if self.pad != ROWGROUP_PAD:
            self._file.close()
            raise ValueError(
                f"{path}: row-group pad {self.pad} != kernel layout "
                f"{ROWGROUP_PAD}; re-derive with this build")
        f = self._file
        # per-record columns (zero-copy views on the mapping)
        self.shard_id = f.array("shard_id")
        self.offset = f.array("offset")
        self.length = f.array("length")
        self.rtype = f.array("rtype")
        self.status = f.array("status")
        self.timestamp = f.array("timestamp")
        self.digest = f.array("digest")
        self.signatures = f.array("signatures")
        self.rg_id = f.array("rg_id")
        self.rg_row = f.array("rg_row")
        self.uri_off = f.array("uri_off")
        self.mime_off = f.array("mime_off")
        # row-group table
        self.rg_width = f.array("rg_width")
        self.rg_rows = f.array("rg_rows")
        self.rg_padded = f.array("rg_padded")
        self.rg_byte_off = f.array("rg_byte_off")
        # record rows in row-group order: members of group g are
        # rg_order[rg_start[g]:rg_start[g+1]] in rg_row order
        self.rg_order = f.array("rg_order")
        self.rg_start = np.concatenate(
            [[0], np.cumsum(self.rg_rows)]).astype(np.int64)
        # heaps copied out (small): bytes slicing semantics, and uri()/
        # mime() results must outlive close()
        self.uri_heap: bytes = f.blob("uri_heap")
        self.mime_heap: bytes = f.blob("mime_heap")
        # attached by derive(): merged ObsSnapshot / damage ledger rows
        self.obs = None
        self.errors: list = []
        self._uris: np.ndarray | None = None
        self._mimes: np.ndarray | None = None

    # -- access ----------------------------------------------------------
    def __len__(self) -> int:
        return int(self.offset.size)

    @property
    def n_rowgroups(self) -> int:
        return int(self.rg_width.size)

    def rowgroup(self, g: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        """One packed row-group, kernel-ready and zero-copy.

        Returns ``(matrix, record_rows, lengths)``: the mmapped
        ``(padded_rows, width + pad)`` uint8 matrix, the record rows
        occupying its live rows (in row order), and their true payload
        lengths — exactly the inputs
        :func:`repro.kernels.pattern_scan.find_pattern_mask_rowgroup`
        and :func:`repro.kernels.digest_sig.digest_signature_rowgroup`
        take.
        """
        width = int(self.rg_width[g])
        matrix = self._file.view(
            "payload", int(self.rg_byte_off[g]),
            (int(self.rg_padded[g]), width + self.pad))
        record_rows = self.rg_order[self.rg_start[g]:self.rg_start[g + 1]]
        return matrix, record_rows, self.length[record_rows].astype(np.int64)

    def payload(self, row: int) -> bytes:
        """One record's content block, copied out of its row-group —
        byte-identical to ``WarcRecord.content`` of the source record
        (the store's fetch path: no seek, decompress, or parse)."""
        g = int(self.rg_id[row])
        width = int(self.rg_width[g])
        start = (int(self.rg_byte_off[g])
                 + int(self.rg_row[row]) * (width + self.pad))
        view = self._file.view("payload", start, (int(self.length[row]),))
        return view.tobytes()

    def uri(self, i: int) -> bytes:
        return self.uri_heap[self.uri_off[i]:self.uri_off[i + 1]]

    def mime(self, i: int) -> bytes:
        return self.mime_heap[self.mime_off[i]:self.mime_off[i + 1]]

    def pad_waste_ratio(self) -> float:
        """Padding share of the stored row-group bytes (the derive-time
        answer to the ragged-batch pad-waste counter)."""
        padded = int((self.rg_padded * (self.rg_width + self.pad)).sum())
        useful = int(self.length.sum())
        return 1.0 - useful / padded if padded else 0.0

    # -- interop ----------------------------------------------------------
    def as_index(self):
        """An in-memory :class:`~repro.index.cdx.CdxIndex` over this
        store's metadata columns — same rows, same row order, bit-equal
        digest/signature columns (the derive round-trip test asserts
        this against a real CDX build of the same corpus).

        Lets a :class:`~repro.index.query.QueryEngine` run standalone on
        a store, no CDX file needed: planner stages read these columns,
        the scan stage reads the row-groups. ``comp_len`` is zero (the
        store does not address compressed members) and zstd rows carry
        ``NO_FRAME`` — fetches should go through the store, not a
        reader; the columns exist so the engine's planner and hit
        assembly work unchanged.
        """
        from repro.index.cdx import NO_FRAME, CdxIndex

        n = len(self)
        frame_off = self.offset.copy()
        frame_base = self.offset.copy()
        zstd_rows = np.asarray(
            [k == "zstd" for k in self.shard_kinds], bool)[self.shard_id]
        frame_off[zstd_rows] = NO_FRAME
        frame_base[zstd_rows] = NO_FRAME
        columns = {
            "shard_id": self.shard_id,
            "offset": self.offset,
            "comp_len": np.zeros(n, np.uint64),
            "uncomp_len": self.length,
            "rtype": self.rtype,
            "status": self.status,
            "digest": self.digest,
            "signatures": self.signatures,
            "frame_off": frame_off,
            "frame_base": frame_base,
            "uri_off": self.uri_off,
            "mime_off": self.mime_off,
        }
        return CdxIndex(self.shard_paths, self.shard_kinds, columns,
                        self.uri_heap, self.mime_heap,
                        sig_bits=self.sig_bits, sig_ngram=self.sig_ngram,
                        sig_hashes=self.sig_hashes)

    # -- lifecycle --------------------------------------------------------
    def close(self) -> None:
        """Release the mapping. The column attributes and any row-group
        matrices handed out are borrowed views — drop them first or this
        raises ``BufferError`` (see module docstring)."""
        for name in ("shard_id", "offset", "length", "rtype", "status",
                     "timestamp", "digest", "signatures", "rg_id", "rg_row",
                     "uri_off", "mime_off", "rg_width", "rg_rows",
                     "rg_padded", "rg_byte_off", "rg_order"):
            if hasattr(self, name):
                delattr(self, name)
        self._file.close()

    def __enter__(self) -> "ColumnStore":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
