"""Generic column codec: contiguous-array packing + a TOC'd container.

Two layers, both shared between the CDX v2 index and the columnar
derived store (DESIGN.md §13):

* **array packing** — :func:`pack_arrays` / :class:`ArrayCursor`: the
  write-contiguous / ``np.frombuffer``-and-advance loops that
  :meth:`repro.index.cdx.CdxIndex.save` and ``load`` always were,
  extracted so the CDX byte format is produced and consumed by the same
  code the new shards use. CDX keeps its fixed implicit schema (the v2
  format is unchanged on disk); the cursor is the decode half.

* **TOC'd container** — :class:`ColumnWriter` / :class:`ColumnFile`:
  a versioned single-file layout for *self-describing* column sets —
  magic + header, 64-byte-aligned sections (named numpy arrays and raw
  byte blobs, blobs streamable chunk-by-chunk so a derive never holds
  the packed payload in RAM), and a trailing JSON table of contents
  (section name/kind/dtype/shape/offset plus free-form ``meta``).
  :class:`ColumnFile` mmaps the file and hands out **zero-copy views**:
  ``array()`` / ``view()`` return numpy arrays backed by the mapping.

Ownership rule (the mmap twin of the arena borrow/detach rule,
DESIGN.md §8): views borrow the mapping. ``close()`` refuses — raises
``BufferError`` — while borrowed views are alive; drop them (or copy
out) first. There is no detach here because the mapping is the point:
a columnar scan must not copy the corpus to read it.

This module deliberately imports nothing from :mod:`repro` — it sits
below both :mod:`repro.index` and :mod:`repro.columnar.store` in the
import graph.
"""
from __future__ import annotations

import json
import mmap
import struct
from typing import Any

import numpy as np

__all__ = ["ArrayCursor", "ColumnFile", "ColumnWriter", "pack_arrays"]

_MAGIC = b"REPROCOL"
_VERSION = 1
_ALIGN = 64  # section alignment: cache-line / lane friendly mmap views
_HEADER = "<IIQQ"  # version, reserved, toc_off, toc_len (after the magic)


# --------------------------------------------------------------------------
# Layer 1: bare contiguous-array packing (the CDX column region)
# --------------------------------------------------------------------------

def pack_arrays(out, arrays) -> None:
    """Write arrays back-to-back as contiguous bytes (no framing — the
    schema is the caller's contract, as in the CDX fixed column order)."""
    for arr in arrays:
        out.write(np.ascontiguousarray(arr).tobytes())


class ArrayCursor:
    """Decode arrays packed by :func:`pack_arrays` from a bytes-like.

    Zero-copy: each :meth:`take` is an ``np.frombuffer`` view advancing
    an offset — the decode half of the CDX column region.
    """

    def __init__(self, blob, pos: int = 0) -> None:
        self.blob = blob
        self.pos = pos

    def take(self, dtype, count: int, shape=None) -> np.ndarray:
        arr = np.frombuffer(self.blob, dtype, count, self.pos)
        self.pos += arr.nbytes
        return arr.reshape(shape) if shape else arr


# --------------------------------------------------------------------------
# Layer 2: the TOC'd container (columnar shards)
# --------------------------------------------------------------------------

class ColumnWriter:
    """Streaming writer for the TOC'd column container.

    Arrays are written whole; blobs are opened, appended chunk-by-chunk
    (:meth:`append` returns each chunk's blob-relative offset — row-group
    tables are built from these), and closed. :meth:`close` writes the
    TOC and patches the header; the file is invalid until then.
    """

    def __init__(self, path: str, *, meta: dict[str, Any] | None = None
                 ) -> None:
        self.path = path
        self._f = open(path, "wb")
        self._f.write(_MAGIC + struct.pack(_HEADER, _VERSION, 0, 0, 0))
        self._sections: list[dict[str, Any]] = []
        self._names: set[str] = set()
        self.meta: dict[str, Any] = dict(meta or {})
        self._blob: dict[str, Any] | None = None

    def _align(self) -> int:
        pad = -self._f.tell() % _ALIGN
        if pad:
            self._f.write(b"\0" * pad)
        return self._f.tell()

    def _claim(self, name: str) -> None:
        if self._blob is not None:
            raise ValueError(f"blob {self._blob['name']!r} still open")
        if name in self._names:
            raise ValueError(f"duplicate section {name!r}")
        self._names.add(name)

    def add_array(self, name: str, arr) -> None:
        self._claim(name)
        arr = np.ascontiguousarray(arr)
        off = self._align()
        self._f.write(arr.tobytes())
        self._sections.append({"name": name, "kind": "array",
                               "dtype": arr.dtype.str,
                               "shape": list(arr.shape),
                               "offset": off, "nbytes": arr.nbytes})

    def begin_blob(self, name: str) -> None:
        self._claim(name)
        self._blob = {"name": name, "kind": "blob",
                      "offset": self._align(), "nbytes": 0}

    def append(self, data) -> int:
        """Append a chunk to the open blob; returns its blob-relative
        start offset (what a row-group table records)."""
        if self._blob is None:
            raise ValueError("no blob open")
        rel = self._blob["nbytes"]
        mv = memoryview(data)  # any C-contiguous buffer (bytes, ndarray)
        self._f.write(mv)
        self._blob["nbytes"] += mv.nbytes
        return rel

    def end_blob(self) -> None:
        if self._blob is None:
            raise ValueError("no blob open")
        self._sections.append(self._blob)
        self._blob = None

    def add_blob(self, name: str, data) -> None:
        self.begin_blob(name)
        self.append(data)
        self.end_blob()

    def close(self) -> None:
        if self._f.closed:
            return
        if self._blob is not None:
            raise ValueError(f"blob {self._blob['name']!r} still open")
        toc = json.dumps({"meta": self.meta, "sections": self._sections},
                         separators=(",", ":")).encode("utf-8")
        toc_off = self._align()
        self._f.write(toc)
        self._f.seek(len(_MAGIC))
        self._f.write(struct.pack(_HEADER, _VERSION, 0, toc_off, len(toc)))
        self._f.close()

    def __enter__(self) -> "ColumnWriter":
        return self

    def __exit__(self, exc_type, *exc_info) -> None:
        if exc_type is None:
            self.close()
        else:  # failed write: don't persist a TOC for a truncated file
            self._f.close()


class ColumnFile:
    """mmap-backed reader for the TOC'd container — zero-copy views.

    ``array(name)`` returns the section as a read-only numpy view on the
    mapping; ``view(name, offset, shape, dtype)`` carves a typed view
    out of a blob section (how row-group matrices are read). Views
    borrow the mapping: :meth:`close` raises ``BufferError`` while any
    live (see the module docstring's ownership rule).
    """

    def __init__(self, path: str) -> None:
        self.path = path
        self._f = open(path, "rb")
        self._mm = mmap.mmap(self._f.fileno(), 0, access=mmap.ACCESS_READ)
        head = self._mm[:len(_MAGIC) + struct.calcsize(_HEADER)]
        if head[:len(_MAGIC)] != _MAGIC:
            self.close()
            raise ValueError(f"{path}: not a column container (bad magic)")
        version, _, toc_off, toc_len = struct.unpack_from(
            _HEADER, head, len(_MAGIC))
        if version != _VERSION:
            self.close()
            raise ValueError(f"{path}: unsupported container version "
                             f"{version}")
        if toc_off == 0:
            self.close()
            raise ValueError(f"{path}: no TOC (writer not closed?)")
        toc = json.loads(self._mm[toc_off:toc_off + toc_len].decode("utf-8"))
        self.meta: dict[str, Any] = toc["meta"]
        self._sections: dict[str, dict[str, Any]] = {
            s["name"]: s for s in toc["sections"]}

    def section_names(self) -> list[str]:
        return list(self._sections)

    def _section(self, name: str, kind: str) -> dict[str, Any]:
        sec = self._sections.get(name)
        if sec is None or sec["kind"] != kind:
            raise KeyError(f"{self.path}: no {kind} section {name!r}")
        return sec

    def array(self, name: str) -> np.ndarray:
        sec = self._section(name, "array")
        shape = tuple(sec["shape"])
        count = int(np.prod(shape)) if shape else 1
        arr = np.frombuffer(self._mm, np.dtype(sec["dtype"]), count,
                            sec["offset"])
        return arr.reshape(shape)

    def view(self, name: str, offset: int, shape, dtype=np.uint8
             ) -> np.ndarray:
        """Typed zero-copy view into a blob section at a relative offset."""
        sec = self._section(name, "blob")
        dtype = np.dtype(dtype)
        count = int(np.prod(shape))
        if offset < 0 or offset + count * dtype.itemsize > sec["nbytes"]:
            raise ValueError(f"view [{offset}, +{count * dtype.itemsize}) "
                             f"outside blob {name!r}")
        return np.frombuffer(self._mm, dtype, count,
                             sec["offset"] + offset).reshape(shape)

    def blob(self, name: str) -> bytes:
        """A blob section **copied out** as owning bytes (small heaps —
        URI/MIME — want bytes semantics; row-groups use :meth:`view`)."""
        sec = self._section(name, "blob")
        return self._mm[sec["offset"]:sec["offset"] + sec["nbytes"]]

    def close(self) -> None:
        """Release the mapping. Raises ``BufferError`` if zero-copy views
        handed out by :meth:`array` / :meth:`view` are still alive —
        drop or copy them first (the arena borrow rule, mmap edition).

        Views that are merely *unreachable* don't count as alive: a
        kernel dispatch over a row-group leaves the matrix view in a
        dead reference cycle (the device array aliases the mapping until
        collected), so one GC pass runs before the borrow check bites.
        """
        if self._mm is not None:
            try:
                self._mm.close()
            except BufferError:
                import gc

                gc.collect()  # drop cycle-held / deferred-freed views
                self._mm.close()  # still alive → genuinely borrowed
            self._mm = None
        self._f.close()

    def __enter__(self) -> "ColumnFile":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()
