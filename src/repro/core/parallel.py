"""Process-parallel shard ingestion engine (DESIGN.md §6).

The paper removes *per-record* overheads; at Common-Crawl scale the next
bottleneck is that one Python process parses one shard on one core. This
module provides the multi-core fan-out used across the stack:

* :class:`ParallelWarcPool` — a small process pool purpose-built for
  shard streaming: a lazy task feeder (so infinite shard sequences work),
  a **bounded** result queue (workers block instead of ballooning memory),
  chunked result transfer (amortizes pickling), and an *ordered* mode that
  re-sequences per-shard result streams so consumers see exactly the
  serial order (the token loader's exactly-resumable cursor depends on
  this).
* :func:`iter_documents_parallel` — the parallel twin of
  :func:`repro.core.pipeline.iter_documents` over many shards.
* :func:`map_shards` — one-result-per-shard map (map-reduce support; the
  web-graph builder merges per-shard partial graphs with host-id
  remapping, see :func:`repro.core.pipeline.web_graph_from_warcs`).

Workers run the FastWARC parse → HTML→text extraction entirely in the
child process; only the (much smaller) extracted results cross the
process boundary. Worker functions must be module-level (picklable) so
the pool also works under the ``spawn`` start method.

**Result transport** (DESIGN.md §9): by default chunks travel through
per-worker ``multiprocessing.shared_memory`` ring slots — the worker
serializes a chunk once into its next free slot (length-prefixed frames
when a ``frame_codec`` is given, one pickle blob otherwise) and sends
only a tiny descriptor through the queue; the parent decodes straight
out of a zero-copy ``memoryview`` of the slot and releases it via a
semaphore. This replaces the PR 1 path where every chunk was pickled
*into a pipe* (64 KiB writes, feeder-thread copies, then re-read and
re-assembled on the parent side). ``transport="pickle"`` keeps the old
queue path — the ingest benchmark measures one against the other.
"""
from __future__ import annotations

import collections
import functools
import os
import pickle
import queue as _queue_mod
import struct
import sys
import threading
import time
import traceback
from typing import Any, Callable, Iterable, Iterator

import multiprocessing as mp

from repro import obs
from repro.obs.registry import ObsSnapshot, Registry
from repro.obs.shmstats import (STATS_SLOT_BYTES, StatsSlotReader,
                                StatsSlotWriter)

from . import reaper as _reaper

try:
    from multiprocessing import shared_memory as _shm_mod
except ImportError:  # pragma: no cover - py>=3.8 everywhere we run
    _shm_mod = None

__all__ = [
    "ParallelWarcPool",
    "ParallelWorkerError",
    "iter_documents_parallel",
    "iter_records_parallel",
    "map_shards",
]

_CHUNK = 0       # payload: list of results
_DONE = 1        # payload: number of results produced for the task
_ERROR = 2       # payload: (repr(exc), formatted traceback)
_CHUNK_SHM = 3   # payload: (worker_id, slot, nbytes, count) ring descriptor
_CHUNK_BLOB = 4  # payload: pickled chunk bytes (ring-overflow fallback)
_QUAR = 5        # synthetic, parent-side only: shard quarantined as poison

_DEFAULT_CHUNK_SIZE = 64
_SHM_SLOT_BYTES = 4 << 20   # per-slot capacity; larger chunks fall back
_SHM_SLOTS = 4              # slots per worker (in-flight chunk window)
_PICKLE_MARK = 0xFFFFFFFF   # frame-count marker: slot holds one pickle blob


class ParallelWorkerError(RuntimeError):
    """A worker process raised while processing a shard."""

    def __init__(self, shard_index: int, message: str, worker_traceback: str):
        super().__init__(
            f"shard #{shard_index}: {message}\n--- worker traceback ---\n"
            f"{worker_traceback}")
        self.shard_index = shard_index


class _ShmSlotWriter:
    """Worker-side ring writer over one shared-memory segment.

    The segment is divided into fixed slots used round-robin; a
    semaphore (initially ``slots``) gates writes: the parent releases it
    after decoding a slot, and because the parent consumes descriptors
    in FIFO order, when ``acquire`` returns the round-robin target slot
    is always the oldest — already drained — one.
    """

    def __init__(self, name: str, slot_bytes: int, slots: int, sem,
                 worker_id: int) -> None:
        # the parent owns the segment's lifetime: attaching must not
        # (re-)register it with a resource tracker, or a tracker would
        # unlink it on child exit (spawn) / complain about the parent's
        # own unlink (fork, shared tracker) — py3.13 grew track=False
        # for exactly this; on 3.10 the registration hook is stubbed out
        # around the attach instead
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            self._shm = _shm_mod.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig_register
        self._slot_bytes = slot_bytes
        self._slots = slots
        self._sem = sem
        self._next = 0
        self.worker_id = worker_id

    def try_send(self, put, idx: int, frames, blob) -> bool:
        """Write one serialized chunk into the next free slot; False if it
        cannot fit (caller falls back to the queue path)."""
        if frames is not None:
            nbytes = sum(4 + len(f) for f in frames)
            count = len(frames)
        else:
            nbytes = len(blob)
            count = _PICKLE_MARK
        if nbytes > self._slot_bytes:
            return False
        self._sem.acquire()
        slot = self._next
        self._next = (slot + 1) % self._slots
        off = slot * self._slot_bytes
        buf = self._shm.buf
        if frames is not None:
            for f in frames:
                struct.pack_into("<I", buf, off, len(f))
                off += 4
                buf[off:off + len(f)] = f
                off += len(f)
        else:
            buf[off:off + nbytes] = blob
        put((idx, _CHUNK_SHM, (self.worker_id, slot, nbytes, count)))
        return True

    def close(self) -> None:
        try:
            self._shm.close()
        except OSError:  # pragma: no cover - teardown race
            pass


class _WorkerStatsPublisher:
    """Worker-side observability publisher over one seqlock stats slot.

    Installs a **fresh** process-default :class:`Registry` (a forked
    worker inherits the parent's counters — publishing those back would
    double-count them on merge) and pickles cumulative snapshots into
    this worker's slot of the parent-owned stats segment after every
    completed shard. The parent harvests whenever it likes; because it
    owns the segment, a SIGKILLed worker's last publish survives it.
    """

    def __init__(self, name: str, offset: int, source: str) -> None:
        # parent owns the segment: attach without (re-)registering, same
        # rationale as _ShmSlotWriter
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            self._shm = _shm_mod.SharedMemory(name=name)
        finally:
            resource_tracker.register = orig_register
        self._view = self._shm.buf[offset:offset + STATS_SLOT_BYTES]
        self._writer = StatsSlotWriter(self._view)
        obs.set_registry(Registry(source=source))

    def publish(self) -> None:
        self._writer.publish(obs.snapshot())

    def close(self) -> None:
        self.publish()
        self._writer.close()
        self._view.release()  # exports must be gone before shm.close()
        try:
            self._shm.close()
        except OSError:  # pragma: no cover - teardown race
            pass


def _maybe_worker_kill(counter: int, spec: str | None) -> None:
    """Fault-injection hook: die hard before sending result ``N``.

    Armed through ``REPRO_FAULT_WORKER_KILL="<latch-path>:<N>"``; the
    latch file is claimed with ``O_CREAT|O_EXCL`` so exactly one worker
    across the whole (fork or spawn) pool dies, exactly once — the
    supervision tests depend on deterministic single-kill behavior.

    ``spec`` is captured from the *parent's* environment at worker-spawn
    time and passed down explicitly rather than read from the worker's
    own ``os.environ``: under the forkserver start method every worker
    forks from a daemon that snapshotted the environment when it first
    started, so a worker's environment can be armed long after the test
    that armed it disarmed and deleted its latch (replaying the kill
    into an innocent pool) — or never armed at all.
    """
    if not spec:
        return
    latch, _, at = spec.rpartition(":")
    if counter != int(at):
        return
    try:
        fd = os.open(latch, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except OSError:  # another worker already took the kill
        return
    os.close(fd)
    os._exit(42)


def _worker_loop(task_q, result_q, worker_fn, chunk_size: int,
                 shm_args=None, encode=None, wid: int = 0,
                 hb=None, stop_ev=None, claims=None, hist=None,
                 hist_len: int = 0, credit=None,
                 fault_kill: str | None = None,
                 stats_args=None) -> None:
    """Child-process main: stream worker_fn(item) results back in chunks.

    With ``stop_ev`` set (supervised pools) the loop polls the task
    queue instead of blocking on a sentinel, stamps a heartbeat (``hb``,
    a shared double) whenever it makes progress, records each claimed
    task in ``claims[wid]`` (a shared array — written *synchronously*,
    because a queue message can die unflushed with the process and the
    parent must still know which shard to re-drive), and honors per-task
    resume cursors: a ``(idx, item, skip)`` task re-drives the shard but
    suppresses the first ``skip`` results — exactly the slice the parent
    already holds.

    ``credit`` (supervised pools) is this worker's result-credit
    semaphore: acquired before every queue put, released by the parent
    per message received. The result queue itself must stay unbounded in
    that mode — ``mp.Queue.put`` on a bounded queue takes a permit from
    a pool-wide semaphore that dies with the process when the message is
    still in the feeder buffer, and enough leaked permits wedge the
    queue "full" forever for every respawned worker. Per-worker credits
    give the same backpressure but let the supervisor drain-and-refill a
    dead worker's semaphore back to exactly its cap.
    """
    writer = None
    if shm_args is not None and _shm_mod is not None:
        try:
            writer = _ShmSlotWriter(*shm_args)
        except Exception:  # segment vanished: stay on the queue path
            writer = None
    stats_pub = None
    if stats_args is not None and _shm_mod is not None:
        try:
            stats_pub = _WorkerStatsPublisher(*stats_args)
        except Exception:  # segment vanished: run without obs publishing
            stats_pub = None

    def beat() -> None:
        if hb is not None:
            hb.value = time.monotonic()

    sent_total = 0
    nclaims = 0

    def put(msg) -> None:
        if credit is not None:
            while not credit.acquire(timeout=0.2):
                beat()  # backpressure stall, not a hang
                if stop_ev is not None and stop_ev.is_set():
                    return  # parent is tearing down; message is moot
            result_q.put(msg + (wid,))
            return
        result_q.put(msg)

    def send(idx: int, buf: list) -> None:
        if writer is None:
            put((idx, _CHUNK, buf))
            return
        # serialize exactly once; an over-slot chunk reuses the blob via
        # the queue (no re-pickling), frames fall back to a plain chunk
        frames = blob = None
        if encode is not None:
            frames = [encode(item) for item in buf]
        else:
            blob = pickle.dumps(buf, protocol=pickle.HIGHEST_PROTOCOL)
        if writer.try_send(put, idx, frames, blob):
            return
        if blob is not None:
            put((idx, _CHUNK_BLOB, blob))
        else:
            put((idx, _CHUNK, buf))

    try:
        while True:
            if stop_ev is not None:
                try:
                    task = task_q.get(timeout=0.25)
                except _queue_mod.Empty:
                    beat()
                    if stop_ev.is_set():
                        return
                    continue
                if task is None:  # stray sentinel: stop_ev is authoritative
                    continue
            else:
                task = task_q.get()
                if task is None:
                    return
            idx, item, *rest = task
            skip = rest[0] if rest else 0
            if claims is not None:
                # history first, then the live claim: a death between the
                # two writes still leaves the shard re-drivable
                hist[wid * hist_len + (nclaims % hist_len)] = idx
                nclaims += 1
                claims[wid] = idx
            beat()
            try:
                buf: list = []
                produced = 0
                seen = 0
                for out in worker_fn(item):
                    seen += 1
                    if seen <= skip:
                        continue
                    sent_total += 1
                    _maybe_worker_kill(sent_total, fault_kill)
                    buf.append(out)
                    if len(buf) >= chunk_size:
                        send(idx, buf)
                        produced += len(buf)
                        buf = []
                        beat()
                if buf:
                    send(idx, buf)
                    produced += len(buf)
                put((idx, _DONE, skip + produced))
                beat()
                if stats_pub is not None:  # per shard, never per record
                    stats_pub.publish()
            except Exception as exc:  # surfaced as ParallelWorkerError
                put((idx, _ERROR, (repr(exc), traceback.format_exc())))
                if stats_pub is not None:
                    stats_pub.publish()
    finally:
        if stats_pub is not None:
            stats_pub.close()
        if writer is not None:
            writer.close()


def _default_context() -> str:
    override = os.environ.get("REPRO_MP_CONTEXT")
    if override:
        return override
    methods = mp.get_all_start_methods()
    # fork is much cheaper to start and the workers only run pure-Python
    # parsing — but forking a process whose JAX/XLA runtime has started
    # its thread pools is a documented deadlock source (a child can
    # inherit a held lock). Once jax is imported, prefer forkserver
    # (children fork from a clean server process) or spawn — except when
    # __main__ has a pseudo-filename ("<stdin>"/"<string>"): spawn-style
    # preparation re-runs __main__ from its path and would crash there.
    main_file = getattr(sys.modules.get("__main__"), "__file__", None) or ""
    if "jax" in sys.modules and not main_file.startswith("<"):
        for method in ("forkserver", "spawn"):
            if method in methods:
                return method
    return "fork" if "fork" in methods else "spawn"


class ParallelWarcPool:
    """Process pool streaming per-shard results through bounded queues.

    Parameters
    ----------
    worker_fn:
        module-level callable; ``worker_fn(item)`` returns/yields the
        results for one shard. Use ``functools.partial`` for options.
    workers:
        process count (default: ``os.cpu_count()``).
    chunk_size:
        results per queue message (pickling amortization).
    queue_chunks:
        result-queue bound in messages (default ``4 × workers``) — the
        backpressure knob: workers stall rather than buffering a whole
        crawl in the parent. Supervised pools enforce the same bound
        per worker through credit semaphores instead of the queue's own
        maxsize (a bounded ``mp.Queue`` leaks its put-permits when a
        worker dies with messages unflushed, eventually wedging "full").
    mp_context:
        multiprocessing start method ("fork"/"spawn"/"forkserver");
        default from ``REPRO_MP_CONTEXT``, else fork-when-available —
        unless jax is already imported, where forkserver/spawn is
        chosen (forking under live XLA thread pools can deadlock).
    transport:
        ``"shm"`` (default where available) streams result chunks
        through per-worker shared-memory ring slots — no pipe copies;
        ``"pickle"`` is the PR 1 queue path. Chunks that overflow a
        ring slot transparently fall back to the queue.
    frame_codec:
        optional ``(encode, decode)`` pair of **module-level** functions
        for the shm transport: ``encode(result) -> bytes`` and
        ``decode(memoryview) -> result``. With a codec, results cross
        the process boundary as length-prefixed frames and are decoded
        straight from the shared-memory view — no pickling at all.
        Without one, shm slots carry a single pickle blob (still
        skipping the pipe).
    supervise:
        enable the fault-tolerance supervisor: workers poll for tasks
        under a shared stop event (no sentinels) and stamp heartbeats;
        the parent detects dead children (exitcode) and — with
        ``hang_timeout_s`` — hung ones (stale heartbeat while holding a
        task), reaps/reset their ring semaphore, respawns with capped
        exponential backoff, and **re-drives only the unfinished slice**
        of the interrupted shard (the worker skips exactly the results
        the parent already decoded). A shard that kills
        ``poison_kills`` workers is quarantined: the event stream emits
        ``("quarantined", idx, reason)`` instead of hanging or raising.
        Worker *exceptions* still raise :class:`ParallelWorkerError` —
        supervision retries process deaths, not bugs.
    max_respawns:
        total respawn budget for non-quarantine deaths; exceeding it
        raises (a crash-looping environment must not retry forever).
    """

    def __init__(self, worker_fn: Callable[[Any], Iterable],
                 *, workers: int | None = None,
                 chunk_size: int = _DEFAULT_CHUNK_SIZE,
                 queue_chunks: int | None = None,
                 mp_context: str | None = None,
                 transport: str | None = None,
                 frame_codec: tuple[Callable, Callable] | None = None,
                 slot_bytes: int = _SHM_SLOT_BYTES,
                 slots_per_worker: int = _SHM_SLOTS,
                 supervise: bool = False,
                 max_respawns: int = 3,
                 hang_timeout_s: float | None = None,
                 poison_kills: int = 2) -> None:
        self.workers = max(1, workers if workers else (os.cpu_count() or 1))
        self._ctx = mp.get_context(mp_context or _default_context())
        self._tasks = self._ctx.Queue(maxsize=2 * self.workers)
        self._queue_chunks = queue_chunks if queue_chunks else 4 * self.workers
        # Supervised pools must NOT bound the result queue itself: a
        # bounded mp.Queue takes its backpressure permit inside put(),
        # but the message sits in the dying process's feeder-thread
        # buffer — kill the worker and the permit leaks forever. After a
        # few deaths the queue reads as permanently full and every
        # respawned worker blocks in put() while the parent sees an
        # empty pipe (deadlock). Backpressure moves to per-worker credit
        # semaphores the supervisor can drain-and-refill exactly,
        # mirroring the shm slot rings.
        self._credits = ([self._ctx.Semaphore(self._queue_chunks)
                          for _ in range(self.workers)]
                         if supervise else None)
        self._results = self._ctx.Queue(
            maxsize=0 if supervise else self._queue_chunks)
        self._stop = threading.Event()
        self._feed_done = threading.Event()
        self._total: int | None = None
        self._feed_error: BaseException | None = None
        self._feeder: threading.Thread | None = None
        self._progress = 0          # consumer's cur (ordered mode)
        self._window: int | None = None  # max shards fed ahead of progress
        self.supervise = bool(supervise)
        self.max_respawns = max_respawns
        self.hang_timeout_s = hang_timeout_s
        self.poison_kills = poison_kills
        self._stop_ev = self._ctx.Event() if supervise else None
        self._claims = (self._ctx.Array("q", [-1] * self.workers, lock=False)
                        if supervise else None)
        # per-worker claim-history ring: a worker's queue messages die
        # unflushed with its feeder thread, so the parent must be able to
        # re-drive every shard whose results might still have been
        # buffered — the credit semaphore admits at most `queue_chunks`
        # unflushed messages per worker and every finished task emits at
        # least one (_DONE), so `queue_chunks + 2` claim slots cannot
        # wrap past a task that still owes the parent data
        self._hist_len = self._queue_chunks + 2
        self._hist = (self._ctx.Array(
            "q", [-1] * (self.workers * self._hist_len), lock=False)
            if supervise else None)
        self._respawns = 0
        self._task_items: dict[int, Any] = {}   # supervise: idx -> item
        self._synthetic: collections.deque = collections.deque()
        self.supervisor_stats = {"respawns": 0, "quarantined": 0, "hangs": 0}
        requested = transport
        if transport is None:
            transport = "shm" if _shm_mod is not None else "pickle"
        if transport not in ("shm", "pickle"):
            raise ValueError(f"unknown transport {transport!r}")
        if transport == "shm" and _shm_mod is None:  # pragma: no cover
            transport = "pickle"
        self._decode = frame_codec[1] if frame_codec else None
        self._slot_bytes = slot_bytes
        self._segments: list = []
        self._sems: list = []
        self._procs: list = []
        self._stats_seg = None
        self._stats_gen = [0] * self.workers   # per-wid incarnation counter
        self._worker_snaps: dict[str, ObsSnapshot] = {}
        self._stats_absorbed = False
        self._closed = False  # before any allocation: __del__ must be safe
        self.transport_stats = {"shm_chunks": 0, "shm_bytes": 0,
                                "queue_chunks": 0, "results": 0}
        if transport == "shm":
            # tmpfs-backed: a constrained /dev/shm (docker's 64 MB default
            # with several 16 MiB rings) makes allocation fail — the
            # *default* transport must degrade to the queue path, not
            # crash ingestion; an explicit transport="shm" still raises
            try:
                for _ in range(self.workers):
                    self._segments.append(
                        _reaper.create_segment(slot_bytes * slots_per_worker))
                    self._sems.append(self._ctx.Semaphore(slots_per_worker))
            except OSError:
                for seg in self._segments:
                    try:
                        seg.close()
                        seg.unlink()
                        _reaper.unregister(seg)
                    except OSError:  # pragma: no cover - teardown race
                        pass
                self._segments = []
                self._sems = []
                if requested == "shm":
                    raise
                transport = "pickle"
        self.transport = transport
        self._slots_per_worker = slots_per_worker
        # one seqlock stats slot per worker: workers publish cumulative
        # ObsSnapshots here after every shard; the parent harvests on
        # supervisor ticks / close / obs_snapshot(). Optional — a
        # constrained /dev/shm degrades to no worker stats, not a crash.
        if _shm_mod is not None:
            try:
                self._stats_seg = _reaper.create_segment(
                    STATS_SLOT_BYTES * self.workers)
            except OSError:
                self._stats_seg = None
        self._worker_fn = worker_fn
        self._chunk_size = chunk_size
        self._encode = frame_codec[0] if frame_codec else None
        self._hb = ([self._ctx.Value("d", 0.0, lock=False)
                     for _ in range(self.workers)] if supervise else [])
        for wid in range(self.workers):
            self._procs.append(self._make_worker(wid))

    def _make_worker(self, wid: int):
        """Spawn (or respawn) worker ``wid``; reuses its ring segment."""
        shm_args = None
        if self.transport == "shm":
            shm_args = (self._segments[wid].name, self._slot_bytes,
                        self._slots_per_worker, self._sems[wid], wid)
        hb = None
        if self.supervise:
            hb = self._hb[wid]
            hb.value = time.monotonic()
        stats_args = None
        if self._stats_seg is not None:
            # incarnation-tagged source: a respawned worker publishes
            # under a fresh key, so the dead incarnation's harvested
            # snapshot survives the slot being overwritten
            self._stats_gen[wid] += 1
            stats_args = (self._stats_seg.name, wid * STATS_SLOT_BYTES,
                          f"worker-{wid}.{self._stats_gen[wid]}")
        p = self._ctx.Process(
            target=_worker_loop,
            args=(self._tasks, self._results, self._worker_fn,
                  self._chunk_size, shm_args, self._encode, wid, hb,
                  self._stop_ev, self._claims, self._hist, self._hist_len,
                  self._credits[wid] if self._credits else None,
                  os.environ.get("REPRO_FAULT_WORKER_KILL"), stats_args),
            daemon=True)
        p.start()
        return p

    # -- shm decode ------------------------------------------------------
    def _decode_slot(self, desc: tuple) -> list:
        """Materialize one ring slot's chunk from a zero-copy view and
        hand the slot back to its worker."""
        wid, slot, nbytes, count = desc
        view = self._segments[wid].buf[slot * self._slot_bytes:
                                       slot * self._slot_bytes + nbytes]
        try:
            if count == _PICKLE_MARK:
                results = pickle.loads(view)
            else:
                results = []
                off = 0
                decode = self._decode
                for _ in range(count):
                    (flen,) = struct.unpack_from("<I", view, off)
                    off += 4
                    results.append(decode(view[off:off + flen]))
                    off += flen
        finally:
            del view  # release the buffer export before the slot recycles
            # hand the slot back even when decode raises: a leaked permit
            # would deadlock the worker's ring on a later event stream
            self._sems[wid].release()
        self.transport_stats["shm_chunks"] += 1
        self.transport_stats["shm_bytes"] += nbytes
        return results

    # -- task feeding ----------------------------------------------------
    def _feed(self, items: Iterable) -> None:
        count = 0
        try:
            for idx, item in enumerate(items):
                # ordered mode: don't run ahead of the consumer by more
                # than a window of shards — otherwise every faster shard's
                # full output piles up in the consumer's `pending` buffer
                # (unbounded memory) while one slow shard holds `cur`
                while (self._window is not None
                       and idx - self._progress > self._window
                       and not self._stop.is_set()):
                    time.sleep(0.01)
                if self.supervise:
                    # the supervisor re-drives interrupted shards: it
                    # needs the item long after the feeder moved on
                    self._task_items[idx] = item
                while not self._stop.is_set():
                    try:
                        self._tasks.put((idx, item), timeout=0.1)
                        break
                    except _queue_mod.Full:
                        continue
                if self._stop.is_set():
                    return
                count = idx + 1
        except BaseException as exc:  # surfaced by iter_events, not swallowed
            self._feed_error = exc
        finally:
            self._total = count
            self._feed_done.set()
            # release the workers; bounded put so close() can always win.
            # Supervised workers stop via the shared event instead — a
            # sentinel could race ahead of a requeued shard and kill the
            # worker meant to re-drive it.
            sentinels = 0 if self.supervise else self.workers
            while sentinels and not self._stop.is_set():
                try:
                    self._tasks.put(None, timeout=0.1)
                    sentinels -= 1
                except _queue_mod.Full:
                    continue

    # -- observability ---------------------------------------------------
    def _harvest_worker_stats(self) -> None:
        """Read every worker's latest published snapshot into
        ``self._worker_snaps``, keyed by incarnation source
        (``worker-<wid>.<gen>``) so a dead worker's harvest survives its
        replacement reusing the slot. Cheap enough for supervisor ticks:
        snapshots are a few KiB of counters per worker."""
        if self._stats_seg is None:
            return
        for wid in range(self.workers):
            view = self._stats_seg.buf[wid * STATS_SLOT_BYTES:
                                       (wid + 1) * STATS_SLOT_BYTES]
            reader = StatsSlotReader(view)
            snap = reader.read()
            reader.close()
            view.release()  # export gone before any close/unlink
            if snap is not None and snap.sources:
                self._worker_snaps[snap.sources[0]] = snap

    def obs_snapshot(self) -> ObsSnapshot:
        """Merged pool-level observability: transport + supervisor
        counters, the worst current heartbeat lag, and every worker
        incarnation's last published snapshot.

        This is the *live* view: while the pool runs, worker counters
        exist only here, so ``obs.snapshot().merged_with(pool.obs_snapshot())``
        is the mid-stream whole-tree picture with no double-count.
        ``close()`` then absorbs exactly the same counters into the
        process-default registry (the readahead-decoder harvest
        discipline), after which ``obs.snapshot()`` alone is the whole
        truth — do NOT also merge a post-close pool snapshot on top."""
        self._harvest_worker_stats()
        pool = ObsSnapshot(sources=("pool",))
        for k, v in self.transport_stats.items():
            pool.counters[f"pool.transport.{k}"] = int(v)
        for k, v in self.supervisor_stats.items():
            pool.counters[f"pool.{k}"] = int(v)
        if self.supervise and self._hb:
            now = time.monotonic()
            pool.gauges["pool.heartbeat_lag_s"] = max(
                0.0, max(now - hb.value for hb in self._hb))
        snaps = [pool] + [self._worker_snaps[k]
                          for k in sorted(self._worker_snaps)]
        return ObsSnapshot.merge(snaps)

    def _absorb_stats(self) -> None:
        """Fold the pool's own counters plus every harvested worker
        snapshot into the process-default registry, exactly once (from
        ``close()``). Counters are cumulative, so the guard is what
        keeps a double ``close()`` from double-counting."""
        if self._stats_absorbed:
            return
        self._stats_absorbed = True
        reg = obs.registry()
        reg.fold_counters({f"pool.transport.{k}": int(v)
                           for k, v in self.transport_stats.items()})
        reg.fold_counters({f"pool.{k}": int(v)
                           for k, v in self.supervisor_stats.items()})
        reg.attach_source("pool")
        for src in sorted(self._worker_snaps):
            reg.absorb(self._worker_snaps[src])

    # -- supervision -----------------------------------------------------
    def _supervise_tick(self, received: dict, kills: dict, terminal: set,
                        backoff: float) -> float:
        """Detect dead/hung workers; reap, respawn, re-drive, quarantine.

        Runs only from the event loop's idle branch *and* only when the
        result queue is empty: every descriptor a dead worker managed to
        deliver has been decoded (and its ring slot released) before we
        compute the resume cursor, so ``received[idx]`` is exact. The
        in-flight shard comes from the shared claims array, not a queue
        message — a worker that dies the instant it claims still leaves
        the claim behind.
        """
        # harvest first: a dead worker's last published counters must be
        # captured before its replacement starts overwriting the slot
        self._harvest_worker_stats()
        now = time.monotonic()
        for wid, p in enumerate(self._procs):
            claim = self._claims[wid]
            holds_task = claim >= 0 and claim not in terminal
            if (p.exitcode is None and self.hang_timeout_s is not None
                    and holds_task
                    and now - self._hb[wid].value > self.hang_timeout_s):
                # holds a task but hasn't made progress: stuck inside
                # worker_fn (idle workers heartbeat every poll timeout)
                self.supervisor_stats["hangs"] += 1
                p.terminate()
                p.join(timeout=1.0)
                if p.exitcode is None:  # pragma: no cover - SIGTERM masked
                    p.kill()
                    p.join(timeout=1.0)
            if p.exitcode is None:
                continue
            # any exit while the stream runs is abnormal: supervised
            # workers only return after close() sets the stop event
            idx = claim if holds_task else None
            # a death can also take *already-completed* shards with it:
            # results (even the _DONE) sit in the dead worker's queue
            # feeder buffer until flushed. The claim-history ring lists
            # every shard whose messages may have died there; any entry
            # that never reached terminal must be re-driven — blameless
            # (no kill attribution: the current claim did the killing).
            base = wid * self._hist_len
            lost: list[int] = []
            for j in range(self._hist_len):
                h = self._hist[base + j]
                if (h >= 0 and h not in terminal and h != idx
                        and h not in lost):
                    lost.append(h)
                self._hist[base + j] = -1
            quarantine = False
            if idx is not None:
                kills[idx] = kills.get(idx, 0) + 1
                quarantine = kills[idx] >= self.poison_kills
            if not quarantine:
                if self._respawns >= self.max_respawns:
                    raise ParallelWorkerError(
                        -1 if idx is None else idx,
                        f"worker {wid} died (exit {p.exitcode}) with "
                        f"respawn budget ({self.max_respawns}) exhausted",
                        "")
                self._respawns += 1
            self.supervisor_stats["respawns"] += 1
            time.sleep(backoff)
            backoff = min(backoff * 2.0, 1.0)
            if self.transport == "shm":
                # a kill between sem.acquire and the descriptor put
                # leaks a permit; the queue-empty guard above means
                # every delivered slot was decoded and released, so
                # draining and refilling restores exactly `slots`
                sem = self._sems[wid]
                while sem.acquire(False):
                    pass
                for _ in range(self._slots_per_worker):
                    sem.release()
            # same repair for the result credits: a credit held for a
            # message that died in the feeder buffer never comes back by
            # itself. The worker is fully dead (exitcode reaped) and the
            # queue is empty, so every message it flushed was already
            # credited back — the refill is exact, not approximate.
            credit = self._credits[wid]
            while credit.acquire(False):
                pass
            for _ in range(self._queue_chunks):
                credit.release()
            self._claims[wid] = -1  # the requeue below owns the shard now
            self._procs[wid] = self._make_worker(wid)
            if quarantine:
                self.supervisor_stats["quarantined"] += 1
                self._synthetic.append((idx, _QUAR,
                                        f"shard killed {kills[idx]} "
                                        f"worker(s); quarantined"))
            elif idx is not None:
                lost.append(idx)
            for i in lost:
                self._requeue(i, received.get(i, 0))
        return backoff

    def _requeue(self, idx: int, skip: int) -> None:
        while not self._stop.is_set():
            try:
                self._tasks.put((idx, self._task_items.get(idx), skip),
                                timeout=0.1)
                return
            except _queue_mod.Full:
                continue

    # -- event stream ----------------------------------------------------
    def iter_events(self, items: Iterable, *,
                    ordered: bool = True) -> Iterator[tuple]:
        """Stream ``("chunk", idx, results)`` / ``("done", idx, n)`` events.

        ``idx`` is the shard's enumeration index in ``items``. In ordered
        mode events are re-sequenced to exactly the serial order (chunks
        of shard *i* complete — ``("done", i, n)`` — before anything of
        shard *i+1* appears); unordered mode streams events as workers
        finish, which is faster when order is irrelevant.

        One event stream at a time per pool; ``items`` may be an infinite
        iterator (ordered consumption gives natural backpressure).
        """
        if self._feeder is not None:
            raise RuntimeError("pool already consumed; create a new one")
        # ordered mode bounds how far the feeder runs ahead of the
        # consumer's cursor, keeping the `pending` re-sequencing buffer
        # to a fixed number of shards even when shard sizes are skewed
        self._window = (2 * self.workers + 2) if ordered else None
        self._feeder = threading.Thread(
            target=self._feed, args=(items,), daemon=True)
        self._feeder.start()

        done_seen = 0
        cur = 0                       # next idx to emit (ordered mode)
        pending: dict[int, list] = {}  # idx -> buffered events (ordered mode)
        received: dict[int, int] = {}  # idx -> results decoded (supervise)
        kills: dict[int, int] = {}     # idx -> workers it killed (supervise)
        terminal: set[int] = set()     # idx done/quarantined (supervise)
        backoff = 0.05

        def finished() -> bool:
            if not self._feed_done.is_set() or self._total is None:
                return False
            return (cur if ordered else done_seen) >= self._total

        while not finished():
            if self._feed_error is not None:
                raise ParallelWorkerError(
                    -1, f"task iterable raised: {self._feed_error!r}",
                    "") from self._feed_error
            if self._synthetic:
                idx, kind, payload = self._synthetic.popleft()
            else:
                try:
                    msg = self._results.get(timeout=0.1)
                    if self._credits is not None:
                        # supervised messages are wid-tagged: hand the
                        # sender its result credit back
                        idx, kind, payload, src = msg
                        self._credits[src].release()
                    else:
                        idx, kind, payload = msg
                except _queue_mod.Empty:
                    if self.supervise:
                        if self._results.empty():
                            backoff = self._supervise_tick(
                                received, kills, terminal, backoff)
                        continue
                    # a worker killed from outside (OOM, segfault) never
                    # sends its _DONE: waiting on it would hang forever and
                    # balloon the ordered `pending` buffer
                    crashed = [p for p in self._procs
                               if p.exitcode not in (None, 0)]
                    if crashed and self._results.empty():
                        raise ParallelWorkerError(
                            -1, "worker process(es) died with exit code(s) "
                            f"{[p.exitcode for p in crashed]}", "")
                    if (not any(p.is_alive() for p in self._procs)
                            and self._results.empty() and not finished()):
                        raise ParallelWorkerError(
                            -1, "worker processes exited prematurely", "")
                    continue
            if kind == _ERROR:
                raise ParallelWorkerError(idx, payload[0], payload[1])
            if self.supervise and idx in terminal:
                # stale duplicate from a requeue race: the shard already
                # completed; drop the message (still release ring slots)
                if kind == _CHUNK_SHM:
                    self._decode_slot(payload)
                continue
            if kind == _CHUNK_SHM:
                # decode at dequeue time (FIFO per worker): the slot is
                # released immediately, so ordered-mode buffering holds
                # decoded results, never live ring views
                payload = self._decode_slot(payload)
                kind = _CHUNK
                self.transport_stats["results"] += len(payload)
            elif kind == _CHUNK_BLOB:
                payload = pickle.loads(payload)
                kind = _CHUNK
                self.transport_stats["queue_chunks"] += 1
                self.transport_stats["results"] += len(payload)
            elif kind == _CHUNK:
                self.transport_stats["queue_chunks"] += 1
                self.transport_stats["results"] += len(payload)
            if self.supervise and kind == _CHUNK:
                received[idx] = received.get(idx, 0) + len(payload)
            if kind in (_DONE, _QUAR):
                done_seen += 1
                if self.supervise:
                    terminal.add(idx)
                    self._task_items.pop(idx, None)
            if not ordered:
                if kind == _CHUNK:
                    yield ("chunk", idx, payload)
                elif kind == _DONE:
                    yield ("done", idx, payload)
                else:
                    yield ("quarantined", idx, payload)
                continue
            if idx != cur:
                pending.setdefault(idx, []).append((kind, payload))
                continue
            if kind == _CHUNK:
                yield ("chunk", idx, payload)
                continue
            yield (("done" if kind == _DONE else "quarantined"), idx, payload)
            cur += 1
            self._progress = cur
            # flush buffered successors (a worker's messages are FIFO, so
            # a buffered "done" is always last for its idx)
            while True:
                events = pending.pop(cur, None)
                if not events:
                    break
                advanced = False
                for kind2, payload2 in events:
                    if kind2 == _CHUNK:
                        yield ("chunk", cur, payload2)
                    else:
                        yield (("done" if kind2 == _DONE
                                else "quarantined"), cur, payload2)
                        advanced = True
                if not advanced:
                    break
                cur += 1
                self._progress = cur
        if self._feed_error is not None:
            # the items iterable died partway: the stream above was
            # silently truncated, which must not look like success
            raise ParallelWorkerError(
                -1, f"task iterable raised: {self._feed_error!r}",
                "") from self._feed_error

    def iter_results(self, items: Iterable, *,
                     ordered: bool = True) -> Iterator:
        """Flattened result stream (chunk boundaries dissolved)."""
        for event in self.iter_events(items, ordered=ordered):
            if event[0] == "chunk":
                yield from event[2]

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Stop feeding, tear down workers, release queue resources."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._stop_ev is not None:
            try:
                self._stop_ev.set()
            except (OSError, ValueError):  # pragma: no cover - teardown race
                pass
        if self._feeder is not None:
            self._feeder.join(timeout=2.0)
        for sem in self._sems:   # unblock writers stuck on a full ring
            try:
                for _ in range(_SHM_SLOTS):
                    sem.release()
            except (OSError, ValueError):  # pragma: no cover
                pass
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=2.0)
        self._harvest_worker_stats()  # final: before the segment unlinks
        self._absorb_stats()
        for q in (self._tasks, self._results):
            try:
                q.close()
                q.cancel_join_thread()
            except (OSError, ValueError):  # pragma: no cover - teardown race
                pass
        for seg in self._segments:
            try:
                seg.close()
                seg.unlink()
            except (OSError, FileNotFoundError):  # pragma: no cover
                pass
            _reaper.unregister(seg)
        self._segments = []
        self._sems = []
        if self._stats_seg is not None:
            try:
                self._stats_seg.close()
                self._stats_seg.unlink()
            except (OSError, FileNotFoundError):  # pragma: no cover
                pass
            _reaper.unregister(self._stats_seg)
            self._stats_seg = None

    def __enter__(self) -> "ParallelWarcPool":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC backstop
        try:
            self.close()
        except Exception:
            pass


# --------------------------------------------------------------------------
# Shard-level worker functions (module-level: picklable under spawn)
# --------------------------------------------------------------------------

def _extract_documents(path: str, *, min_length: int = 64,
                       status_ok_only: bool = True,
                       readahead: bool | None = None,
                       tolerant: bool = False):
    from repro.core.pipeline import iter_documents

    yield from iter_documents(path, min_length=min_length,
                              status_ok_only=status_ok_only,
                              readahead=readahead, tolerant=tolerant)


def _call_one(fn: Callable, item):
    yield fn(item)


# -- Document frame codec (module-level: picklable under spawn) ----------

_DOC_HEADER = struct.Struct("<iqI")  # uri_len (-1: None), offset, text_len


def _encode_document(doc) -> bytes:
    """One Document → one length-prefixable frame (no pickle)."""
    uri = doc.uri.encode("utf-8") if doc.uri is not None else None
    return (_DOC_HEADER.pack(-1 if uri is None else len(uri),
                             doc.record_offset, len(doc.text))
            + (uri or b"") + doc.text)


def _decode_document(view: memoryview):
    """Frame → Document; copies out of the borrowed ring view (the slot
    recycles right after decode)."""
    from repro.core.pipeline import Document

    uri_len, offset, text_len = _DOC_HEADER.unpack_from(view)
    off = _DOC_HEADER.size
    uri = None
    if uri_len >= 0:
        uri = bytes(view[off:off + uri_len]).decode("utf-8")
        off += uri_len
    return Document(uri, bytes(view[off:off + text_len]), offset)


def iter_documents_parallel(paths: Iterable[str], *,
                            workers: int | None = None,
                            ordered: bool = False,
                            min_length: int = 64,
                            status_ok_only: bool = True,
                            chunk_size: int = _DEFAULT_CHUNK_SIZE,
                            mp_context: str | None = None,
                            transport: str | None = None,
                            readahead: bool | None = None,
                            tolerant: bool = False,
                            supervise: bool = False) -> Iterator:
    """Parallel ``iter_documents`` over many WARC shards.

    Parse, HTTP decode, and HTML→text extraction all run in ``workers``
    processes; under the default transport each extracted
    :class:`~repro.core.pipeline.Document` chunk is serialized once into
    a shared-memory ring slot and the parent decodes it straight from a
    zero-copy view of the slot — no pipe traffic (``transport="pickle"``
    keeps the PR 1 queue path). ``workers=0`` is the serial fallback
    (identical output, one process). ``ordered=True`` reproduces the
    exact serial document order; the default streams documents as
    shards finish. ``readahead`` reaches each worker's parser: member
    inflate runs on a decoder thread inside the worker process, so
    decode overlaps extraction per shard on top of the process fan-out.
    """
    paths = [p for p in paths]
    if workers is not None and workers <= 0:
        from repro.core.pipeline import iter_documents

        for p in paths:
            yield from iter_documents(p, min_length=min_length,
                                      status_ok_only=status_ok_only,
                                      readahead=readahead,
                                      tolerant=tolerant)
        return
    fn = functools.partial(_extract_documents, min_length=min_length,
                           status_ok_only=status_ok_only,
                           readahead=readahead, tolerant=tolerant)
    with ParallelWarcPool(fn, workers=workers, chunk_size=chunk_size,
                          mp_context=mp_context, transport=transport,
                          frame_codec=(_encode_document, _decode_document),
                          supervise=supervise) as pool:
        yield from pool.iter_results(paths, ordered=ordered)


# -- WarcRecord frame codec (module-level: picklable under spawn) --------

_REC_HEADER = struct.Struct("<qHBI")  # stream_offset, type, http flag, hdr_len


def _encode_record(rec) -> bytes:
    """One detached WarcRecord → one length-prefixable frame."""
    hdr = rec._header_block
    return b"".join((_REC_HEADER.pack(rec.stream_offset,
                                      int(rec.record_type),
                                      1 if rec.http_headers is not None else 0,
                                      len(hdr)),
                     hdr, rec.content_view()))


def _decode_record(view: memoryview):
    """Frame → WarcRecord (owning copies; the ring slot recycles).

    HTTP parse state crosses the boundary as one flag: re-running
    ``parse_http_fast`` on the identical content bytes reproduces the
    worker's ``http_headers``/``http_content_offset`` exactly, so the
    shm path returns the same records the pickle path does."""
    from repro.core.warc.http import parse_http_fast
    from repro.core.warc.record import RECORD_TYPE_FROM_VALUE, WarcRecord

    offset, type_value, has_http, hdr_len = _REC_HEADER.unpack_from(view)
    off = _REC_HEADER.size
    rec = WarcRecord(bytes(view[off:off + hdr_len]),
                     RECORD_TYPE_FROM_VALUE[type_value],
                     bytes(view[off + hdr_len:]), offset)
    if has_http:
        http, body_off = parse_http_fast(rec._content)
        rec.http_headers = http
        rec.http_content_offset = body_off if http is not None else -1
    return rec


def _extract_records(path: str, *, types_value: int, parse_http: bool,
                     readahead: bool | None = None,
                     tolerant: bool = False):
    from repro.core.warc import FastWARCIterator, WarcRecordType

    it = FastWARCIterator(path, record_types=WarcRecordType(types_value),
                          parse_http=parse_http, readahead=readahead,
                          tolerant=tolerant)
    try:
        for rec in it:
            # detach: frames are encoded (and queue-fallback chunks
            # pickled) after the parse arena has moved on
            yield rec.detach()
    finally:
        # a worker torn down mid-shard (pool close) must join the
        # shard's decoder thread, not leak it
        it.close()


def iter_records_parallel(paths: Iterable[str], *,
                          record_types=None,
                          parse_http: bool = False,
                          workers: int | None = None,
                          ordered: bool = False,
                          chunk_size: int = _DEFAULT_CHUNK_SIZE,
                          mp_context: str | None = None,
                          transport: str | None = None,
                          readahead: bool | None = None,
                          tolerant: bool = False,
                          supervise: bool = False,
                          max_respawns: int = 3,
                          hang_timeout_s: float | None = None) -> Iterator:
    """Parallel bulk record export: full WARC records out of many shards.

    The payload-heavy sibling of :func:`iter_documents_parallel` (whole
    record blocks cross the process boundary, not just extracted text) —
    the workload the shared-memory transport exists for: each record
    travels as one length-prefixed frame in a ring slot instead of
    being pickled into a pipe. Records arrive detached (owning copies).

    ``tolerant=True`` makes each worker's parser recover from damaged
    records (only intact survivors are streamed back; per-range ledger
    detail stays in the worker — use :func:`repro.index.cdx.build_index`
    when the damage report itself is needed). ``supervise=True`` retries
    worker deaths mid-shard, resuming exactly after the records already
    delivered (see :class:`ParallelWarcPool`).
    """
    from repro.core.warc import WarcRecordType

    paths = [p for p in paths]
    if record_types is None:
        record_types = WarcRecordType.any_type
    if workers is not None and workers <= 0:
        for p in paths:
            yield from _extract_records(p, types_value=int(record_types),
                                        parse_http=parse_http,
                                        readahead=readahead,
                                        tolerant=tolerant)
        return
    fn = functools.partial(_extract_records, types_value=int(record_types),
                           parse_http=parse_http, readahead=readahead,
                           tolerant=tolerant)
    with ParallelWarcPool(fn, workers=workers, chunk_size=chunk_size,
                          mp_context=mp_context, transport=transport,
                          frame_codec=(_encode_record, _decode_record),
                          supervise=supervise, max_respawns=max_respawns,
                          hang_timeout_s=hang_timeout_s) as pool:
        yield from pool.iter_results(paths, ordered=ordered)


def map_shards(fn: Callable, items: Iterable, *,
               workers: int | None = None,
               mp_context: str | None = None,
               supervise: bool = False,
               max_respawns: int = 3,
               hang_timeout_s: float | None = None,
               poison_kills: int = 2,
               with_obs: bool = False) -> list:
    """Apply ``fn`` (module-level, one picklable result) per shard.

    Returns results in ``items`` order — the map half of map-reduce
    analytics over shard collections. With ``supervise=True`` worker
    deaths are retried (see :class:`ParallelWarcPool`); a shard
    quarantined as poison yields ``None`` in its slot instead of
    aborting the whole map.

    With ``with_obs=True`` returns ``(results, snapshot)`` where
    ``snapshot`` is one merged :class:`~repro.obs.ObsSnapshot` spanning
    the whole process tree: the parent registry, the pool's
    transport/supervisor counters, and every worker incarnation's
    published counters — all of which the pool's ``close()`` absorbed
    into the process-default registry, so the snapshot composes with
    later layers (e.g. a gateway's) without double-counting.
    """
    items = [it for it in items]
    if workers is not None and workers <= 0 or len(items) <= 1:
        out = [fn(it) for it in items]
        # serial path: fn ran in-process, its counters are already in
        # the parent registry
        return (out, obs.snapshot()) if with_obs else out
    out = [None] * len(items)
    with ParallelWarcPool(functools.partial(_call_one, fn), workers=workers,
                          chunk_size=1, mp_context=mp_context,
                          supervise=supervise, max_respawns=max_respawns,
                          hang_timeout_s=hang_timeout_s,
                          poison_kills=poison_kills) as pool:
        for event in pool.iter_events(items, ordered=True):
            if event[0] == "chunk":
                out[event[1]] = event[2][0]
    if with_obs:
        # after close(): the final harvest (post worker join) and the
        # pool's own counters were absorbed into the process registry —
        # one snapshot, nothing counted twice
        return out, obs.snapshot()
    return out
