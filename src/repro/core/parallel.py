"""Process-parallel shard ingestion engine (DESIGN.md §6).

The paper removes *per-record* overheads; at Common-Crawl scale the next
bottleneck is that one Python process parses one shard on one core. This
module provides the multi-core fan-out used across the stack:

* :class:`ParallelWarcPool` — a small process pool purpose-built for
  shard streaming: a lazy task feeder (so infinite shard sequences work),
  a **bounded** result queue (workers block instead of ballooning memory),
  chunked result transfer (amortizes pickling), and an *ordered* mode that
  re-sequences per-shard result streams so consumers see exactly the
  serial order (the token loader's exactly-resumable cursor depends on
  this).
* :func:`iter_documents_parallel` — the parallel twin of
  :func:`repro.core.pipeline.iter_documents` over many shards.
* :func:`map_shards` — one-result-per-shard map (map-reduce support; the
  web-graph builder merges per-shard partial graphs with host-id
  remapping, see :func:`repro.core.pipeline.web_graph_from_warcs`).

Workers run the FastWARC parse → HTML→text extraction entirely in the
child process; only the (much smaller) extracted results cross the
process boundary. Worker functions must be module-level (picklable) so
the pool also works under the ``spawn`` start method.
"""
from __future__ import annotations

import functools
import os
import queue as _queue_mod
import sys
import threading
import time
import traceback
from typing import Any, Callable, Iterable, Iterator

import multiprocessing as mp

__all__ = [
    "ParallelWarcPool",
    "ParallelWorkerError",
    "iter_documents_parallel",
    "map_shards",
]

_CHUNK = 0   # payload: list of results
_DONE = 1    # payload: number of results produced for the task
_ERROR = 2   # payload: (repr(exc), formatted traceback)

_DEFAULT_CHUNK_SIZE = 64


class ParallelWorkerError(RuntimeError):
    """A worker process raised while processing a shard."""

    def __init__(self, shard_index: int, message: str, worker_traceback: str):
        super().__init__(
            f"shard #{shard_index}: {message}\n--- worker traceback ---\n"
            f"{worker_traceback}")
        self.shard_index = shard_index


def _worker_loop(task_q, result_q, worker_fn, chunk_size: int) -> None:
    """Child-process main: stream worker_fn(item) results back in chunks."""
    while True:
        task = task_q.get()
        if task is None:
            return
        idx, item = task
        try:
            buf: list = []
            produced = 0
            for out in worker_fn(item):
                buf.append(out)
                if len(buf) >= chunk_size:
                    result_q.put((idx, _CHUNK, buf))
                    produced += len(buf)
                    buf = []
            if buf:
                result_q.put((idx, _CHUNK, buf))
                produced += len(buf)
            result_q.put((idx, _DONE, produced))
        except Exception as exc:  # surfaced in the parent as ParallelWorkerError
            result_q.put((idx, _ERROR, (repr(exc), traceback.format_exc())))


def _default_context() -> str:
    override = os.environ.get("REPRO_MP_CONTEXT")
    if override:
        return override
    methods = mp.get_all_start_methods()
    # fork is much cheaper to start and the workers only run pure-Python
    # parsing — but forking a process whose JAX/XLA runtime has started
    # its thread pools is a documented deadlock source (a child can
    # inherit a held lock). Once jax is imported, prefer forkserver
    # (children fork from a clean server process) or spawn — except when
    # __main__ has a pseudo-filename ("<stdin>"/"<string>"): spawn-style
    # preparation re-runs __main__ from its path and would crash there.
    main_file = getattr(sys.modules.get("__main__"), "__file__", None) or ""
    if "jax" in sys.modules and not main_file.startswith("<"):
        for method in ("forkserver", "spawn"):
            if method in methods:
                return method
    return "fork" if "fork" in methods else "spawn"


class ParallelWarcPool:
    """Process pool streaming per-shard results through bounded queues.

    Parameters
    ----------
    worker_fn:
        module-level callable; ``worker_fn(item)`` returns/yields the
        results for one shard. Use ``functools.partial`` for options.
    workers:
        process count (default: ``os.cpu_count()``).
    chunk_size:
        results per queue message (pickling amortization).
    queue_chunks:
        result-queue bound in messages (default ``4 × workers``) — the
        backpressure knob: workers stall rather than buffering a whole
        crawl in the parent.
    mp_context:
        multiprocessing start method ("fork"/"spawn"/"forkserver");
        default from ``REPRO_MP_CONTEXT``, else fork-when-available —
        unless jax is already imported, where forkserver/spawn is
        chosen (forking under live XLA thread pools can deadlock).
    """

    def __init__(self, worker_fn: Callable[[Any], Iterable],
                 *, workers: int | None = None,
                 chunk_size: int = _DEFAULT_CHUNK_SIZE,
                 queue_chunks: int | None = None,
                 mp_context: str | None = None) -> None:
        self.workers = max(1, workers if workers else (os.cpu_count() or 1))
        self._ctx = mp.get_context(mp_context or _default_context())
        self._tasks = self._ctx.Queue(maxsize=2 * self.workers)
        self._results = self._ctx.Queue(
            maxsize=queue_chunks if queue_chunks else 4 * self.workers)
        self._stop = threading.Event()
        self._feed_done = threading.Event()
        self._total: int | None = None
        self._feed_error: BaseException | None = None
        self._feeder: threading.Thread | None = None
        self._progress = 0          # consumer's cur (ordered mode)
        self._window: int | None = None  # max shards fed ahead of progress
        self._procs = [
            self._ctx.Process(
                target=_worker_loop,
                args=(self._tasks, self._results, worker_fn, chunk_size),
                daemon=True)
            for _ in range(self.workers)
        ]
        for p in self._procs:
            p.start()
        self._closed = False

    # -- task feeding ----------------------------------------------------
    def _feed(self, items: Iterable) -> None:
        count = 0
        try:
            for idx, item in enumerate(items):
                # ordered mode: don't run ahead of the consumer by more
                # than a window of shards — otherwise every faster shard's
                # full output piles up in the consumer's `pending` buffer
                # (unbounded memory) while one slow shard holds `cur`
                while (self._window is not None
                       and idx - self._progress > self._window
                       and not self._stop.is_set()):
                    time.sleep(0.01)
                while not self._stop.is_set():
                    try:
                        self._tasks.put((idx, item), timeout=0.1)
                        break
                    except _queue_mod.Full:
                        continue
                if self._stop.is_set():
                    return
                count = idx + 1
        except BaseException as exc:  # surfaced by iter_events, not swallowed
            self._feed_error = exc
        finally:
            self._total = count
            self._feed_done.set()
            # release the workers; bounded put so close() can always win
            sentinels = self.workers
            while sentinels and not self._stop.is_set():
                try:
                    self._tasks.put(None, timeout=0.1)
                    sentinels -= 1
                except _queue_mod.Full:
                    continue

    # -- event stream ----------------------------------------------------
    def iter_events(self, items: Iterable, *,
                    ordered: bool = True) -> Iterator[tuple]:
        """Stream ``("chunk", idx, results)`` / ``("done", idx, n)`` events.

        ``idx`` is the shard's enumeration index in ``items``. In ordered
        mode events are re-sequenced to exactly the serial order (chunks
        of shard *i* complete — ``("done", i, n)`` — before anything of
        shard *i+1* appears); unordered mode streams events as workers
        finish, which is faster when order is irrelevant.

        One event stream at a time per pool; ``items`` may be an infinite
        iterator (ordered consumption gives natural backpressure).
        """
        if self._feeder is not None:
            raise RuntimeError("pool already consumed; create a new one")
        # ordered mode bounds how far the feeder runs ahead of the
        # consumer's cursor, keeping the `pending` re-sequencing buffer
        # to a fixed number of shards even when shard sizes are skewed
        self._window = (2 * self.workers + 2) if ordered else None
        self._feeder = threading.Thread(
            target=self._feed, args=(items,), daemon=True)
        self._feeder.start()

        done_seen = 0
        cur = 0                       # next idx to emit (ordered mode)
        pending: dict[int, list] = {}  # idx -> buffered events (ordered mode)

        def finished() -> bool:
            if not self._feed_done.is_set() or self._total is None:
                return False
            return (cur if ordered else done_seen) >= self._total

        while not finished():
            if self._feed_error is not None:
                raise ParallelWorkerError(
                    -1, f"task iterable raised: {self._feed_error!r}",
                    "") from self._feed_error
            try:
                idx, kind, payload = self._results.get(timeout=0.1)
            except _queue_mod.Empty:
                # a worker killed from outside (OOM, segfault) never sends
                # its _DONE: waiting on it would hang forever and balloon
                # the ordered `pending` buffer
                crashed = [p for p in self._procs
                           if p.exitcode not in (None, 0)]
                if crashed and self._results.empty():
                    raise ParallelWorkerError(
                        -1, "worker process(es) died with exit code(s) "
                        f"{[p.exitcode for p in crashed]}", "")
                if (not any(p.is_alive() for p in self._procs)
                        and self._results.empty() and not finished()):
                    raise ParallelWorkerError(
                        -1, "worker processes exited prematurely", "")
                continue
            if kind == _ERROR:
                raise ParallelWorkerError(idx, payload[0], payload[1])
            if kind == _DONE:
                done_seen += 1
            if not ordered:
                yield ("chunk", idx, payload) if kind == _CHUNK \
                    else ("done", idx, payload)
                continue
            if idx != cur:
                pending.setdefault(idx, []).append((kind, payload))
                continue
            if kind == _CHUNK:
                yield ("chunk", idx, payload)
                continue
            yield ("done", idx, payload)
            cur += 1
            self._progress = cur
            # flush buffered successors (a worker's messages are FIFO, so
            # a buffered "done" is always last for its idx)
            while True:
                events = pending.pop(cur, None)
                if not events:
                    break
                advanced = False
                for kind2, payload2 in events:
                    if kind2 == _CHUNK:
                        yield ("chunk", cur, payload2)
                    else:
                        yield ("done", cur, payload2)
                        advanced = True
                if not advanced:
                    break
                cur += 1
                self._progress = cur
        if self._feed_error is not None:
            # the items iterable died partway: the stream above was
            # silently truncated, which must not look like success
            raise ParallelWorkerError(
                -1, f"task iterable raised: {self._feed_error!r}",
                "") from self._feed_error

    def iter_results(self, items: Iterable, *,
                     ordered: bool = True) -> Iterator:
        """Flattened result stream (chunk boundaries dissolved)."""
        for event in self.iter_events(items, ordered=ordered):
            if event[0] == "chunk":
                yield from event[2]

    # -- lifecycle -------------------------------------------------------
    def close(self) -> None:
        """Stop feeding, tear down workers, release queue resources."""
        if self._closed:
            return
        self._closed = True
        self._stop.set()
        if self._feeder is not None:
            self._feeder.join(timeout=2.0)
        for p in self._procs:
            if p.is_alive():
                p.terminate()
        for p in self._procs:
            p.join(timeout=2.0)
        for q in (self._tasks, self._results):
            try:
                q.close()
                q.cancel_join_thread()
            except (OSError, ValueError):  # pragma: no cover - teardown race
                pass

    def __enter__(self) -> "ParallelWarcPool":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    def __del__(self) -> None:  # pragma: no cover - GC backstop
        try:
            self.close()
        except Exception:
            pass


# --------------------------------------------------------------------------
# Shard-level worker functions (module-level: picklable under spawn)
# --------------------------------------------------------------------------

def _extract_documents(path: str, *, min_length: int = 64,
                       status_ok_only: bool = True):
    from repro.core.pipeline import iter_documents

    yield from iter_documents(path, min_length=min_length,
                              status_ok_only=status_ok_only)


def _call_one(fn: Callable, item):
    yield fn(item)


def iter_documents_parallel(paths: Iterable[str], *,
                            workers: int | None = None,
                            ordered: bool = False,
                            min_length: int = 64,
                            status_ok_only: bool = True,
                            chunk_size: int = _DEFAULT_CHUNK_SIZE,
                            mp_context: str | None = None) -> Iterator:
    """Parallel ``iter_documents`` over many WARC shards.

    Parse, HTTP decode, and HTML→text extraction all run in ``workers``
    processes; the parent only unpickles extracted
    :class:`~repro.core.pipeline.Document` chunks. ``workers=0`` is the
    serial fallback (identical output, one process). ``ordered=True``
    reproduces the exact serial document order; the default streams
    documents as shards finish.
    """
    paths = [p for p in paths]
    if workers is not None and workers <= 0:
        from repro.core.pipeline import iter_documents

        for p in paths:
            yield from iter_documents(p, min_length=min_length,
                                      status_ok_only=status_ok_only)
        return
    fn = functools.partial(_extract_documents, min_length=min_length,
                           status_ok_only=status_ok_only)
    with ParallelWarcPool(fn, workers=workers, chunk_size=chunk_size,
                          mp_context=mp_context) as pool:
        yield from pool.iter_results(paths, ordered=ordered)


def map_shards(fn: Callable, items: Iterable, *,
               workers: int | None = None,
               mp_context: str | None = None) -> list:
    """Apply ``fn`` (module-level, one picklable result) per shard.

    Returns results in ``items`` order — the map half of map-reduce
    analytics over shard collections.
    """
    items = [it for it in items]
    if workers is not None and workers <= 0 or len(items) <= 1:
        return [fn(it) for it in items]
    with ParallelWarcPool(functools.partial(_call_one, fn), workers=workers,
                          chunk_size=1, mp_context=mp_context) as pool:
        return list(pool.iter_results(items, ordered=True))
