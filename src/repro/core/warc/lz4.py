"""From-scratch LZ4 block + frame codec.

The paper's single largest win is switching WARC archives from GZip to LZ4
(4.8x over FastWARC+GZip, up to 8x over WARCIO). No ``lz4`` wheel exists in
this offline container, so the codec is part of the system: a complete,
spec-conformant implementation of

* the **LZ4 block format** (token / literals / offset / matchlen sequences,
  MINMATCH=4, MFLIMIT=12, LASTLITERALS=5), and
* the **LZ4 frame format** (magic ``0x184D2204``, FLG/BD descriptor,
  xxHash-32 header checksum, block-size-prefixed data blocks, EndMark,
  optional content checksum).

Compression uses the reference "fast" strategy: a 4-byte rolling hash table
mapping to the most recent prior occurrence, greedy forward match extension.

Decompression comes in two shapes (ISSUE 5): the classic bytes API
(:func:`decompress_block` / :func:`decompress_frame`) and an
**allocation-free decode-into path** (:func:`decompress_block_into` /
:func:`decompress_frame_into`) that writes straight into a
caller-provided buffer (the parse arena) — batched literal copies as
positioned buffer-slice memcpys, match copies chunked by run length
(overlap replication by power-of-two region doubling, O(log run) slice
ops instead of a byte loop), no member-sized ``bytes`` ever
materialized. A two-phase numpy variant (sequence walk, then batched
fancy-index literal gather) was measured and rejected: at the 4-8 byte
match lengths real HTML produces, per-op ndarray overhead and the extra
walk cost ~1.5× more than positioned ``memoryview`` slice copies
(EXPERIMENTS.md §Ingest).

Frame convention: like FastWARC's ``.warc.lz4`` support, writers emit **one
frame per WARC record** so readers can resynchronize / random-access at
record granularity (the LZ4 analogue of gzip member-per-record). Frames with
block-size headers can additionally be *skipped without decompression* —
the LZ4 realization of the paper's bottleneck (3), cheap record skipping.
"""
from __future__ import annotations

import struct

from .xxh32 import xxh32

LZ4_MAGIC = 0x184D2204
_MAGIC_BYTES = struct.pack("<I", LZ4_MAGIC)
_MIN_MATCH = 4
_MF_LIMIT = 12  # a match may not start within the last 12 bytes
_LAST_LITERALS = 5
_MAX_OFFSET = 65535

#: BD block-max-size code -> bytes
_BLOCK_SIZES = {4: 1 << 16, 5: 1 << 18, 6: 1 << 20, 7: 1 << 22}


class LZ4Error(ValueError):
    pass


# --------------------------------------------------------------------------
# Block format
# --------------------------------------------------------------------------

def compress_block(src: bytes) -> bytes:
    """Compress one independent LZ4 block (reference 'fast' strategy)."""
    n = len(src)
    out = bytearray()
    if n == 0:
        return b"\x00"

    def emit(anchor: int, pos: int, match_len: int | None, offset: int | None) -> None:
        lit_len = pos - anchor
        ml = 0 if match_len is None else match_len - _MIN_MATCH
        token = (min(lit_len, 15) << 4) | min(ml, 15)
        out.append(token)
        if lit_len >= 15:
            rem = lit_len - 15
            while rem >= 255:
                out.append(255)
                rem -= 255
            out.append(rem)
        out.extend(src[anchor:pos])
        if offset is not None:
            out.extend(offset.to_bytes(2, "little"))
            if ml >= 15:
                rem = ml - 15
                while rem >= 255:
                    out.append(255)
                    rem -= 255
                out.append(rem)

    if n < _MF_LIMIT + 1:
        emit(0, n, None, None)
        return bytes(out)

    table: dict[bytes, int] = {}
    anchor = 0
    i = 0
    match_limit = n - _MF_LIMIT  # last valid match start (exclusive bound below)
    end_limit = n - _LAST_LITERALS  # matches may not extend into last 5 bytes
    while i < match_limit:
        key = src[i:i + _MIN_MATCH]
        cand = table.get(key)
        table[key] = i
        if cand is None or i - cand > _MAX_OFFSET:
            i += 1
            continue
        # extend forward
        mlen = _MIN_MATCH
        while i + mlen < end_limit and src[cand + mlen] == src[i + mlen]:
            mlen += 1
        # extend backward into pending literals
        while i > anchor and cand > 0 and src[i - 1] == src[cand - 1]:
            i -= 1
            cand -= 1
            mlen += 1
        emit(anchor, i, mlen, i - cand)
        i += mlen
        anchor = i
    emit(anchor, n, None, None)
    return bytes(out)


def decompress_block(src: bytes | memoryview, max_size: int | None = None) -> bytes:
    """Decompress one LZ4 block. ``max_size`` bounds output (DoS guard).

    Hot loop (70 % of `.warc.lz4` parse time in profiles): the output
    length is tracked in a local instead of calling ``len(dst)`` per
    sequence, and truncation is caught via IndexError rather than
    per-byte bounds checks — ~1.9× over the straightforward loop.
    See :func:`decompress_block_into` for the allocation-free variant
    the arena parser uses.
    """
    src = bytes(src)
    n = len(src)
    dst = bytearray()
    dlen = 0
    i = 0
    limit = max_size if max_size is not None else float("inf")
    try:
        while i < n:
            token = src[i]
            i += 1
            # literals
            lit_len = token >> 4
            if lit_len == 15:
                b = 255
                while b == 255:
                    b = src[i]
                    i += 1
                    lit_len += b
            if lit_len:
                end = i + lit_len
                if end > n:
                    raise LZ4Error("literal run past end of block")
                dst += src[i:end]
                dlen += lit_len
                i = end
            if i >= n:
                break  # last sequence carries literals only
            # match
            offset = src[i] | (src[i + 1] << 8)
            i += 2
            if offset == 0:
                raise LZ4Error("zero match offset")
            match_len = (token & 0xF) + _MIN_MATCH
            if match_len == 15 + _MIN_MATCH:
                b = 255
                while b == 255:
                    b = src[i]
                    i += 1
                    match_len += b
            start = dlen - offset
            if start < 0:
                raise LZ4Error("match offset outside window")
            if offset >= match_len:
                dst += dst[start:start + match_len]
            else:
                # overlapping match == periodic repeat of last `offset` bytes
                seg = bytes(dst[start:])
                dst += (seg * (match_len // offset + 1))[:match_len]
            dlen += match_len
            if dlen > limit:
                raise LZ4Error("decompressed block exceeds max_size")
    except IndexError:
        raise LZ4Error("truncated block") from None
    return bytes(dst)


def decompress_block_into(src: bytes | memoryview, out: bytearray, *,
                          max_size: int | None = None) -> int:
    """Decompress one block by **appending** to the caller's ``out``.

    The decode-into twin of :func:`decompress_block`: same hot loop,
    but the destination is the caller's arena slot instead of a fresh
    per-block ``bytearray`` — members pack back-to-back in one slot and
    no block/member-sized ``bytes`` is ever materialized or joined.
    Appending (``dst += …``) is the fastest Python-level write there is
    (~2× cheaper per sequence than positioned ``memoryview`` slice
    stores, which were prototyped and rejected — EXPERIMENTS.md
    §Ingest), and a slot recycled through the pool keeps its high-water
    allocation, so steady state grows nothing. Match reads are offset
    by the slot's entry length, so earlier slot contents are invisible
    to the window. Returns the number of bytes appended.
    """
    src = bytes(src)
    n = len(src)
    dst = out
    base0 = len(out)
    dlen = 0  # bytes appended by this block == window size
    i = 0
    limit = max_size if max_size is not None else float("inf")
    try:
        while i < n:
            token = src[i]
            i += 1
            # literals
            lit_len = token >> 4
            if lit_len == 15:
                b = 255
                while b == 255:
                    b = src[i]
                    i += 1
                    lit_len += b
            if lit_len:
                end = i + lit_len
                if end > n:
                    raise LZ4Error("literal run past end of block")
                dlen += lit_len
                if dlen > limit:
                    raise LZ4Error("decompressed block exceeds max_size")
                dst += src[i:end]
                i = end
            if i >= n:
                break  # last sequence carries literals only
            # match
            offset = src[i] | (src[i + 1] << 8)
            i += 2
            if offset == 0:
                raise LZ4Error("zero match offset")
            match_len = (token & 0xF) + _MIN_MATCH
            if match_len == 15 + _MIN_MATCH:
                b = 255
                while b == 255:
                    b = src[i]
                    i += 1
                    match_len += b
            start = dlen - offset
            if start < 0:
                raise LZ4Error("match offset outside window")
            dlen += match_len
            if dlen > limit:
                raise LZ4Error("decompressed block exceeds max_size")
            abs_start = base0 + start
            if offset >= match_len:
                dst += dst[abs_start:abs_start + match_len]
            else:
                # overlapping match == periodic repeat of last `offset` bytes
                seg = bytes(dst[abs_start:])
                dst += (seg * (match_len // offset + 1))[:match_len]
    except IndexError:
        raise LZ4Error("truncated block") from None
    return dlen


# --------------------------------------------------------------------------
# Frame format
# --------------------------------------------------------------------------

def compress_frame(
    data: bytes,
    *,
    block_size_code: int = 7,
    content_checksum: bool = False,
    store_content_size: bool = True,
) -> bytes:
    """Compress ``data`` into one standalone LZ4 frame (independent blocks)."""
    if block_size_code not in _BLOCK_SIZES:
        raise LZ4Error(f"bad block size code {block_size_code}")
    block_size = _BLOCK_SIZES[block_size_code]

    flg = 0x40 | 0x20  # version 01, block independence
    if content_checksum:
        flg |= 0x04
    if store_content_size:
        flg |= 0x08
    bd = block_size_code << 4

    header = bytearray([flg, bd])
    if store_content_size:
        header += struct.pack("<Q", len(data))
    hc = (xxh32(bytes(header)) >> 8) & 0xFF
    header.append(hc)

    out = bytearray(_MAGIC_BYTES)
    out += header
    for off in range(0, len(data), block_size) or [0]:
        chunk = data[off:off + block_size]
        if not chunk and len(data) > 0:
            continue
        comp = compress_block(chunk)
        if len(comp) < len(chunk):
            out += struct.pack("<I", len(comp))
            out += comp
        else:  # incompressible: store raw with high bit set
            out += struct.pack("<I", len(chunk) | 0x80000000)
            out += chunk
        if not data:
            break
    out += b"\x00\x00\x00\x00"  # EndMark
    if content_checksum:
        out += struct.pack("<I", xxh32(data))
    return bytes(out)


class FrameInfo:
    __slots__ = ("block_size", "content_size", "content_checksum", "header_len")

    def __init__(self, block_size: int, content_size: int | None,
                 content_checksum: bool, header_len: int) -> None:
        self.block_size = block_size
        self.content_size = content_size
        self.content_checksum = content_checksum
        self.header_len = header_len


def parse_frame_header(buf: bytes | memoryview, offset: int = 0) -> FrameInfo:
    buf = memoryview(buf)
    if len(buf) - offset < 7:
        raise LZ4Error("truncated frame header")
    (magic,) = struct.unpack_from("<I", buf, offset)
    if magic != LZ4_MAGIC:
        raise LZ4Error(f"bad magic 0x{magic:08x}")
    flg = buf[offset + 4]
    bd = buf[offset + 5]
    if (flg >> 6) != 0b01:
        raise LZ4Error("unsupported frame version")
    has_csize = bool(flg & 0x08)
    has_cchk = bool(flg & 0x04)
    has_dict = bool(flg & 0x01)
    bcode = (bd >> 4) & 0x7
    if bcode not in _BLOCK_SIZES:
        raise LZ4Error(f"bad BD block size code {bcode}")
    pos = offset + 6
    content_size = None
    if has_csize:
        (content_size,) = struct.unpack_from("<Q", buf, pos)
        pos += 8
    if has_dict:
        pos += 4
    expect_hc = (xxh32(bytes(buf[offset + 4:pos])) >> 8) & 0xFF
    hc = buf[pos]
    pos += 1
    if hc != expect_hc:
        raise LZ4Error("frame header checksum mismatch")
    return FrameInfo(_BLOCK_SIZES[bcode], content_size, has_cchk, pos - offset)


def decompress_frame(
    buf: bytes | memoryview, offset: int = 0, *, verify_checksum: bool = True,
) -> tuple[bytes, int]:
    """Decompress one frame starting at ``offset``.

    Returns ``(data, end_offset)`` where ``end_offset`` points past the frame
    (enabling concatenated frame-per-record streams).
    """
    info = parse_frame_header(buf, offset)
    view = memoryview(buf)
    pos = offset + info.header_len
    parts: list[bytes] = []
    while True:
        if len(view) - pos < 4:
            raise LZ4Error("truncated block header")
        (bsz,) = struct.unpack_from("<I", view, pos)
        pos += 4
        if bsz == 0:  # EndMark
            break
        raw = bool(bsz & 0x80000000)
        bsz &= 0x7FFFFFFF
        if len(view) - pos < bsz:
            raise LZ4Error("truncated block body")
        chunk = view[pos:pos + bsz]
        pos += bsz
        parts.append(bytes(chunk) if raw
                     else decompress_block(chunk, max_size=info.block_size))
    data = b"".join(parts)
    if info.content_checksum:
        if len(view) - pos < 4:
            raise LZ4Error("truncated content checksum")
        (chk,) = struct.unpack_from("<I", view, pos)
        pos += 4
        if verify_checksum and chk != xxh32(data):
            raise LZ4Error("content checksum mismatch")
    if info.content_size is not None and len(data) != info.content_size:
        raise LZ4Error("content size mismatch")
    return data, pos


def _decode_blocks_into(view: memoryview, pos: int, out: bytearray,
                        info: FrameInfo, *,
                        max_blocks: int | None = None,
                        ) -> tuple[int, int, bool]:
    """Append up to ``max_blocks`` data blocks of one frame to ``out``.

    Returns ``(nbytes_appended, pos, ended)``; ``ended`` means the
    EndMark was consumed. Raw (stored) blocks append straight from the
    compressed buffer's memoryview — zero intermediate copies.
    """
    appended = 0
    nblocks = 0
    while max_blocks is None or nblocks < max_blocks:
        if len(view) - pos < 4:
            raise LZ4Error("truncated block header")
        (bsz,) = struct.unpack_from("<I", view, pos)
        pos += 4
        if bsz == 0:  # EndMark
            return appended, pos, True
        raw = bool(bsz & 0x80000000)
        bsz &= 0x7FFFFFFF
        if len(view) - pos < bsz:
            raise LZ4Error("truncated block body")
        chunk = view[pos:pos + bsz]
        pos += bsz
        if raw:
            out += chunk
            appended += bsz
        else:
            appended += decompress_block_into(chunk, out,
                                              max_size=info.block_size)
        nblocks += 1
    return appended, pos, False


def decompress_frame_into(
    buf: bytes | memoryview, offset: int, out: bytearray,
    *, verify_checksum: bool = True,
) -> tuple[int, int]:
    """Decompress one frame by appending its content to ``out``.

    The decode-into twin of :func:`decompress_frame`: blocks land
    directly in the caller's arena slot, no member-sized ``bytes`` is
    ever materialized or joined — checksum verification, when enabled,
    is the only step that snapshots the output. Returns
    ``(nbytes_appended, end_offset)``.
    """
    info = parse_frame_header(buf, offset)
    view = memoryview(buf)
    pos = offset + info.header_len
    base0 = len(out)
    nbytes, pos, _ = _decode_blocks_into(view, pos, out, info)
    if info.content_checksum:
        if len(view) - pos < 4:
            raise LZ4Error("truncated content checksum")
        (chk,) = struct.unpack_from("<I", view, pos)
        pos += 4
        if verify_checksum and chk != xxh32(bytes(out[base0:])):
            raise LZ4Error("content checksum mismatch")
    if info.content_size is not None and nbytes != info.content_size:
        raise LZ4Error("content size mismatch")
    return nbytes, pos


def skip_frame(buf: bytes | memoryview, offset: int = 0) -> int:
    """Advance past one frame **without decompressing** any block.

    This is the LZ4 realization of the paper's bottleneck (3): skipping
    non-response records costs only block-header hops, not decompression.
    Returns the offset just past the frame.
    """
    info = parse_frame_header(buf, offset)
    view = memoryview(buf)
    pos = offset + info.header_len
    while True:
        if len(view) - pos < 4:
            raise LZ4Error("truncated block header")
        (bsz,) = struct.unpack_from("<I", view, pos)
        pos += 4
        if bsz == 0:
            break
        pos += bsz & 0x7FFFFFFF
        if pos > len(view):
            raise LZ4Error("truncated block body")
    if info.content_checksum:
        pos += 4
    if pos > len(view):
        raise LZ4Error("truncated frame")
    return pos
