"""WARC record model: record types, case-insensitive header maps, records.

Mirrors the data model of ISO 28500 (WARC/1.1) as implemented by FastWARC
(Bevendorff et al., 2021): a record is a version line, a block of
``Name: value`` headers, and a content block of ``Content-Length`` bytes,
followed by two CRLFs.

Two header-map implementations are provided:

* :class:`WarcHeaderMap` — the *optimized* representation used by the
  FastWARC-style parser: stores raw ``bytes`` pairs, decodes lazily on
  access, preserves order, O(1) case-insensitive lookup via a side index.
* The baseline (WARCIO-style) parser in ``warcio_ref.py`` deliberately
  uses eager ``str`` decoding and per-line regex splitting instead — that
  difference is one of the paper's three measured bottlenecks.
"""
from __future__ import annotations

import enum
from typing import Iterator


class WarcRecordType(enum.IntFlag):
    """WARC-Type values as a bit mask (so iterators can filter cheaply)."""

    warcinfo = 2
    response = 4
    resource = 8
    request = 16
    metadata = 32
    revisit = 64
    conversion = 128
    continuation = 256
    unknown = 512
    any_type = 2 | 4 | 8 | 16 | 32 | 64 | 128 | 256 | 512
    no_type = 0


#: raw ``WARC-Type`` value -> enum member (bytes keys: the hot path never decodes)
_RECORD_TYPE_BY_NAME: dict[bytes, WarcRecordType] = {
    b"warcinfo": WarcRecordType.warcinfo,
    b"response": WarcRecordType.response,
    b"resource": WarcRecordType.resource,
    b"request": WarcRecordType.request,
    b"metadata": WarcRecordType.metadata,
    b"revisit": WarcRecordType.revisit,
    b"conversion": WarcRecordType.conversion,
    b"continuation": WarcRecordType.continuation,
}

#: same map to plain ints — ``IntFlag.__and__`` showed up in profiles at
#: ~10 % of parse time; the hot path masks with ints and materializes the
#: enum member only for records that are actually yielded.
RECORD_TYPE_VALUES: dict[bytes, int] = {
    k: int(v) for k, v in _RECORD_TYPE_BY_NAME.items()
}
RECORD_TYPE_FROM_VALUE: dict[int, WarcRecordType] = {
    int(v): v for v in WarcRecordType if v.name not in ("any_type", "no_type")
}
UNKNOWN_TYPE_VALUE = int(WarcRecordType.unknown)
HTTP_TYPE_MASK = int(WarcRecordType.response | WarcRecordType.request)


def record_type_from_bytes(value: bytes) -> WarcRecordType:
    return _RECORD_TYPE_BY_NAME.get(value.strip().lower(), WarcRecordType.unknown)


def parse_content_length(raw: bytes | None) -> int | None:
    """Strict ``Content-Length`` validation: non-negative decimal or bust.

    The hot parse paths historically coerced a missing/garbled length to
    ``0`` and kept going — fine for well-formed archives, catastrophic
    for damaged ones (a wrong length desynchronizes the framing scan and
    every subsequent "record" is garbage). The tolerant paths use this
    instead and treat ``None`` as a resync trigger.
    """
    if raw is None:
        return None
    raw = raw.strip()
    if not raw or not raw.isdigit():  # isdigit() rejects b"-1", b"1e3", b""
        return None
    try:
        return int(raw)
    except ValueError:  # pragma: no cover - isdigit makes this unreachable
        return None


def scan_header_field(block: bytes, needle: bytes) -> bytes | None:
    """Grab one ``Name:``-prefixed field value from a raw header block
    without parsing the block. The backbone of both the record-type
    pre-filter and lazy header access: for skipped records this is the only
    work ever done on their headers. ``needle`` must include the colon."""
    i = block.find(needle)
    while i > 0 and block[i - 1] != 0x0A:  # must start a line
        i = block.find(needle, i + 1)
    if i < 0:
        return None
    end = block.find(b"\r\n", i)
    if end < 0:
        end = len(block)
    return block[i + len(needle):end].strip()


def scan_header_field_in(buf, needle: bytes, start: int, end: int) -> bytes | None:
    """:func:`scan_header_field` over a region ``[start, end)`` of a larger
    buffer (``bytes`` or ``bytearray``), without slicing the region out.

    The zero-copy twin used by the arena parse paths (the pooled record
    buffer and the member-decode slots): skipped records get their
    type/length sniffed straight off the arena — only the (tiny) field
    value is ever materialized. ``needle`` must include the colon.
    """
    i = buf.find(needle, start, end)
    while i > start and buf[i - 1] != 0x0A:  # must start a line
        i = buf.find(needle, i + 1, end)
    if i < 0:
        return None
    vend = buf.find(b"\r\n", i, end)
    if vend < 0:
        vend = end
    return bytes(buf[i + len(needle):vend]).strip()


class WarcHeaderMap:
    """Ordered, case-insensitive multi-map over raw header bytes.

    Values stay ``bytes`` until accessed (lazy decode — one of the
    FastWARC-vs-WARCIO differences this system reproduces).
    """

    __slots__ = ("_pairs", "_index", "status_line")

    def __init__(self, status_line: bytes = b"WARC/1.1") -> None:
        self.status_line = status_line
        self._pairs: list[tuple[bytes, bytes]] = []
        self._index: dict[bytes, int] | None = None

    # -- construction ------------------------------------------------------
    def append(self, name: bytes, value: bytes) -> None:
        self._pairs.append((name, value))
        self._index = None

    def append_continuation(self, value: bytes) -> None:
        """RFC 822 folded header continuation line."""
        if not self._pairs:  # malformed; treat as headerless value
            self._pairs.append((b"", value))
            return
        name, prev = self._pairs[-1]
        self._pairs[-1] = (name, prev + b" " + value)
        self._index = None

    def set(self, name: bytes | str, value: bytes | str) -> None:
        if isinstance(name, str):
            name = name.encode("latin-1")
        if isinstance(value, str):
            value = value.encode("latin-1")
        key = name.lower()
        for i, (n, _) in enumerate(self._pairs):
            if n.lower() == key:
                self._pairs[i] = (name, value)
                self._index = None
                return
        self.append(name, value)

    # -- lookup ------------------------------------------------------------
    def _build_index(self) -> dict[bytes, int]:
        index: dict[bytes, int] = {}
        for i, (name, _) in enumerate(self._pairs):
            index.setdefault(name.lower(), i)
        self._index = index
        return index

    def get_bytes(self, name: bytes, default: bytes | None = None) -> bytes | None:
        index = self._index or self._build_index()
        i = index.get(name.lower())
        return self._pairs[i][1] if i is not None else default

    def get(self, name: str, default: str | None = None) -> str | None:
        raw = self.get_bytes(name.encode("latin-1"))
        return raw.decode("latin-1", "replace") if raw is not None else default

    def __getitem__(self, name: str) -> str:
        value = self.get(name)
        if value is None:
            raise KeyError(name)
        return value

    def __contains__(self, name: str) -> bool:
        return self.get(name) is not None

    def __iter__(self) -> Iterator[tuple[str, str]]:
        for name, value in self._pairs:
            yield name.decode("latin-1", "replace"), value.decode("latin-1", "replace")

    def items_bytes(self) -> list[tuple[bytes, bytes]]:
        return list(self._pairs)

    def __len__(self) -> int:
        return len(self._pairs)

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"WarcHeaderMap({self.status_line!r}, {len(self._pairs)} headers)"


class HttpHeaderMap(WarcHeaderMap):
    """HTTP status line + headers; same storage, different status semantics."""

    @property
    def status_code(self) -> int | None:
        parts = self.status_line.split(None, 2)
        if len(parts) >= 2 and parts[1].isdigit():
            return int(parts[1])
        return None

    @property
    def reason(self) -> str:
        parts = self.status_line.split(None, 2)
        return parts[2].decode("latin-1", "replace") if len(parts) == 3 else ""


class WarcRecord:
    """A parsed WARC record.

    Headers are **lazy**: the record carries the raw header block and the
    :class:`WarcHeaderMap` is built on first ``.headers`` access. Iterating
    an archive without touching headers therefore costs no header parsing
    at all — the same work-avoidance insight the paper applies to HTTP
    parsing, pushed one level up (profiled: header-map construction was the
    single hottest phase of the Python hot loop).

    ``content`` may be a zero-copy ``memoryview`` into the parser's
    pooled arena (``http_headers`` is populated only when HTTP parsing is
    enabled — lazy HTTP parsing is bottleneck (2) of the paper). Borrowed
    views pin their arena: holding many un-detached records costs arena
    memory, never correctness. :meth:`detach` copies the record out and
    releases the pin; :meth:`content_view` / :meth:`payload_view` are the
    **borrow-only** zero-copy accessors.
    """

    __slots__ = (
        "_header_block",
        "_headers",
        "record_type",
        "content_length",
        "_content",
        "_stats",
        "http_headers",
        "http_content_offset",
        "stream_offset",
        "verified_block_digest",
        "verified_payload_digest",
    )

    def __init__(
        self,
        headers: "WarcHeaderMap | bytes",
        record_type: WarcRecordType,
        content: bytes | memoryview = b"",
        stream_offset: int = -1,
        stats=None,
    ) -> None:
        if isinstance(headers, WarcHeaderMap):
            self._headers: WarcHeaderMap | None = headers
            self._header_block = b""
        else:
            self._headers = None
            self._header_block = headers
        self.record_type = record_type
        self._content = content
        self.content_length = len(content)
        self._stats = stats  # CopyStats ledger shared with the iterator
        self.http_headers: HttpHeaderMap | None = None
        self.http_content_offset = -1
        self.stream_offset = stream_offset
        self.verified_block_digest: bool | None = None
        self.verified_payload_digest: bool | None = None

    @property
    def headers(self) -> "WarcHeaderMap":
        if self._headers is None:
            from .fastwarc import parse_header_block  # local: no cycle at import
            self._headers = parse_header_block(self._header_block)
        return self._headers

    # -- convenience accessors ----------------------------------------------
    @property
    def record_id(self) -> str | None:
        return self.headers.get("WARC-Record-ID")

    @property
    def record_date(self) -> str | None:
        return self.headers.get("WARC-Date")

    @property
    def target_uri(self) -> str | None:
        return self.headers.get("WARC-Target-URI")

    @property
    def content(self) -> bytes:
        """Owning ``bytes`` of the content block (copies a borrowed view
        on first access — counted against the parse ledger)."""
        if isinstance(self._content, memoryview):
            if self._stats is not None:
                self._stats.count_copy(len(self._content))
            self._content = self._content.tobytes()
        return self._content

    def content_view(self) -> memoryview:
        """**Borrow-only** zero-copy view of the record block.

        The view aliases the parser's arena; it pins that arena while
        referenced but must not be stored past the record's own lifetime
        — call :meth:`detach` (or read :attr:`content`) for an owning
        copy that outlives the iterator.
        """
        if isinstance(self._content, memoryview):
            return self._content
        return memoryview(self._content)

    def detach(self) -> "WarcRecord":
        """Copy this record out of the parse arena (returns ``self``).

        After ``detach()`` the record owns its content and raw header
        block outright: it survives arena recycling, pickling, and the
        iterator's teardown. The one copy it costs is counted in the
        iterator's :class:`~repro.core.warc.streams.CopyStats`.
        """
        self.content  # noqa: B018 - property materializes the borrow
        if isinstance(self._header_block, memoryview):
            if self._stats is not None:
                self._stats.count_copy(len(self._header_block))
            self._header_block = bytes(self._header_block)
        return self

    @property
    def is_detached(self) -> bool:
        return not (isinstance(self._content, memoryview)
                    or isinstance(self._header_block, memoryview))

    @property
    def http_payload(self) -> bytes:
        """Owning body after the HTTP header block (requires HTTP parsing)."""
        if self.http_content_offset < 0:
            return self.content
        return self.content[self.http_content_offset:]

    def payload_view(self) -> memoryview:
        """Borrow-only zero-copy view of the HTTP body (or whole block).

        Same lifetime contract as :meth:`content_view`.
        """
        view = self.content_view()
        if self.http_content_offset < 0:
            return view
        return view[self.http_content_offset:]

    def header_bytes(self, needle: bytes) -> bytes | None:
        """Single-field access without building the header map (when lazy).

        ``needle`` is the raw header name *with* trailing colon, e.g.
        ``b"WARC-Target-URI:"``.
        """
        if self._headers is not None:
            return self._headers.get_bytes(needle.rstrip(b":"))
        return scan_header_field(self._header_block, needle)

    @property
    def is_http(self) -> bool:
        ctype = self.header_bytes(b"Content-Type:") or b""
        return ctype.startswith(b"application/http")

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (
            f"WarcRecord({self.record_type.name}, id={self.record_id}, "
            f"len={self.content_length})"
        )


CRLF = b"\r\n"
HEADER_TERMINATOR = b"\r\n\r\n"
WARC_MAGIC = b"WARC/"
