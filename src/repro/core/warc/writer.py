"""WARC writer: serialize records with per-record compression members.

Writes the member-per-record layout all WARC tooling expects (gzip member,
LZ4 frame, or zstd frame per record) so readers can random-access and skip
at record granularity. Also home of the **recompression** tool from the
paper's conclusion: "recompressing GZip WARCs with LZ4 is certainly an
option to be considered".
"""
from __future__ import annotations

import io
import uuid
import zlib
from datetime import datetime, timezone
from typing import BinaryIO

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover
    _zstd = None

from . import lz4 as _lz4
from .checksum import block_digest
from .record import CRLF, WarcHeaderMap, WarcRecord, WarcRecordType

_WARC_VERSION = b"WARC/1.1"


def _utcnow() -> str:
    return datetime.now(timezone.utc).strftime("%Y-%m-%dT%H:%M:%SZ")


def serialize_record(
    record_type: str,
    content: bytes,
    headers: dict[str, str] | None = None,
    *,
    digests: bool = False,
) -> bytes:
    """Serialize one record to uncompressed WARC bytes."""
    h = WarcHeaderMap(_WARC_VERSION)
    h.append(b"WARC-Type", record_type.encode("ascii"))
    headers = headers or {}
    if "WARC-Record-ID" not in headers:
        h.append(b"WARC-Record-ID", f"<urn:uuid:{uuid.uuid4()}>".encode("ascii"))
    if "WARC-Date" not in headers:
        h.append(b"WARC-Date", _utcnow().encode("ascii"))
    for name, value in headers.items():
        h.set(name, value)
    if digests:
        h.set("WARC-Block-Digest", block_digest(content, "sha1"))
    h.set("Content-Length", str(len(content)))
    out = bytearray(h.status_line + CRLF)
    for name, value in h.items_bytes():
        out += name + b": " + value + CRLF
    out += CRLF
    out += content
    out += CRLF + CRLF
    return bytes(out)


class WarcWriter:
    """Streaming writer with selectable per-record compression."""

    def __init__(self, sink: BinaryIO, compression: str = "none",
                 *, lz4_content_checksum: bool = False) -> None:
        if compression not in ("none", "gzip", "lz4", "zstd"):
            raise ValueError(f"unknown compression {compression!r}")
        if compression == "zstd" and _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard not available")
        self._sink = sink
        self.compression = compression
        self._lz4_chk = lz4_content_checksum
        self._zctx = _zstd.ZstdCompressor(level=1) if compression == "zstd" else None
        self.records_written = 0
        self.bytes_written = 0

    def write_serialized(self, raw: bytes) -> None:
        if self.compression == "gzip":
            co = zlib.compressobj(6, zlib.DEFLATED, 31)
            out = co.compress(raw) + co.flush()
        elif self.compression == "lz4":
            out = _lz4.compress_frame(raw, content_checksum=self._lz4_chk)
        elif self.compression == "zstd":
            out = self._zctx.compress(raw)
        else:
            out = raw
        self._sink.write(out)
        self.records_written += 1
        self.bytes_written += len(out)

    def write_record(self, record_type: str, content: bytes,
                     headers: dict[str, str] | None = None,
                     *, digests: bool = False) -> None:
        self.write_serialized(
            serialize_record(record_type, content, headers, digests=digests))

    def write_warcinfo(self, fields: dict[str, str] | None = None) -> None:
        body = b"".join(
            f"{k}: {v}\r\n".encode("utf-8")
            for k, v in (fields or {"software": "repro-fastwarc/0.1"}).items())
        self.write_record("warcinfo", body,
                          {"Content-Type": "application/warc-fields"})


def reserialize(record: WarcRecord) -> bytes:
    """Re-serialize a parsed record verbatim (headers preserved in order)."""
    out = bytearray(record.headers.status_line + CRLF)
    for name, value in record.headers.items_bytes():
        out += name + b": " + value + CRLF
    out += CRLF
    out += record.content
    out += CRLF + CRLF
    return bytes(out)


def recompress(src_path: str, dst_path: str, compression: str = "lz4") -> dict:
    """GZip→LZ4 (or →zstd) recompression — the paper's concluding advice.

    Returns size/ratio statistics so callers can check the paper's claimed
    30–40 % LZ4 storage overhead versus GZip.
    """
    from .fastwarc import FastWARCIterator  # late import: avoid cycle

    in_size = 0
    with open(src_path, "rb") as f:
        f.seek(0, io.SEEK_END)
        in_size = f.tell()
    with open(src_path, "rb") as src, open(dst_path, "wb") as dst:
        writer = WarcWriter(dst, compression)
        for record in FastWARCIterator(src, parse_http=False,
                                       record_types=WarcRecordType.any_type):
            writer.write_serialized(reserialize(record))
    return {
        "records": writer.records_written,
        "input_bytes": in_size,
        "output_bytes": writer.bytes_written,
        "size_ratio": writer.bytes_written / max(in_size, 1),
    }
