"""Pure-Python xxHash-32 (needed for LZ4 frame header/content checksums).

The LZ4 frame format (lz4.github.io/lz4/lz4_Frame_format.md) mandates
xxHash-32 for its header checksum and optional content checksum. No lz4 or
xxhash wheel is available offline, so the hash is implemented here and
round-trip verified against published test vectors in the test suite.
"""
from __future__ import annotations

import struct

_P1 = 2654435761
_P2 = 2246822519
_P3 = 3266489917
_P4 = 668265263
_P5 = 374761393
_M32 = 0xFFFFFFFF


def _rotl(x: int, r: int) -> int:
    return ((x << r) | (x >> (32 - r))) & _M32


def _round(acc: int, lane: int) -> int:
    acc = (acc + lane * _P2) & _M32
    return (_rotl(acc, 13) * _P1) & _M32


def xxh32(data: bytes | bytearray | memoryview, seed: int = 0) -> int:
    """xxHash-32 of ``data`` with ``seed``; returns an unsigned 32-bit int."""
    data = bytes(data)
    n = len(data)
    i = 0
    if n >= 16:
        v1 = (seed + _P1 + _P2) & _M32
        v2 = (seed + _P2) & _M32
        v3 = seed & _M32
        v4 = (seed - _P1) & _M32
        limit = n - 16
        unpack = struct.unpack_from
        while i <= limit:
            l1, l2, l3, l4 = unpack("<IIII", data, i)
            v1 = _round(v1, l1)
            v2 = _round(v2, l2)
            v3 = _round(v3, l3)
            v4 = _round(v4, l4)
            i += 16
        h = (_rotl(v1, 1) + _rotl(v2, 7) + _rotl(v3, 12) + _rotl(v4, 18)) & _M32
    else:
        h = (seed + _P5) & _M32
    h = (h + n) & _M32
    while i + 4 <= n:
        (lane,) = struct.unpack_from("<I", data, i)
        h = (h + lane * _P3) & _M32
        h = (_rotl(h, 17) * _P4) & _M32
        i += 4
    while i < n:
        h = (h + data[i] * _P5) & _M32
        h = (_rotl(h, 11) * _P1) & _M32
        i += 1
    h ^= h >> 15
    h = (h * _P2) & _M32
    h ^= h >> 13
    h = (h * _P3) & _M32
    h ^= h >> 16
    return h
