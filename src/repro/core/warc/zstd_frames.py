"""zstd frame-boundary walker: compressed-domain random access for zstd.

zstd frames do not carry their *compressed* length in the frame header,
which is why :class:`~repro.core.warc.streams.ZstdStream` historically
decompressed a whole shard before the first random access. But the
compressed length **is** recoverable without any decompression: a frame
is ``header · block · block · … · [checksum]`` and every 3-byte block
header states its block's size, so a pure header/block walk yields every
frame's ``(compressed offset, compressed length, content size)`` at
C-of-one-pass cost (a few bytes touched per block, no entropy decode).

``repro.index`` runs this walk at CDX build time and stores, per record,
the compressed offset of the frame containing it plus that frame's
decompressed base — :class:`~repro.index.cdx.RandomAccessReader` then
seeks straight to the containing frame and decompresses only from there
(RFC 8878 guarantees frames are independent), instead of inflating the
shard from byte 0.

Implements the RFC 8878 framing grammar: data frames (magic
``0xFD2FB528``) and skippable frames (``0x184D2A5?``); reserved block
types and truncated structures raise ``ValueError`` rather than
guessing.
"""
from __future__ import annotations

import io
import struct
from dataclasses import dataclass

import numpy as np

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - exercised on zstd-less installs
    _zstd = None

__all__ = ["ZstdFrameInfo", "frame_table", "walk_frames"]

_DATA_MAGIC = 0xFD2FB528
_SKIP_MAGIC_LO = 0x184D2A50  # ..5F: skippable frame magic range
_FCS_FIELD_SIZE = (0, 2, 4, 8)   # indexed by Frame_Content_Size_flag
_DID_FIELD_SIZE = (0, 1, 2, 4)   # indexed by Dictionary_ID_flag


@dataclass
class ZstdFrameInfo:
    """One frame of a concatenated-zstd stream (compressed domain)."""

    comp_off: int            # absolute offset of the frame's magic
    comp_len: int            # full frame span, header through checksum
    content_size: int | None  # decompressed size, when the header states it
    skippable: bool = False  # skippable frames hold no stream content


def _walk_data_frame(blob, pos: int) -> tuple[int, int | None]:
    """Parse one data frame from ``pos`` (past magic is computed here);
    returns ``(end_offset, content_size_or_None)``."""
    start = pos
    pos += 4  # magic
    if pos >= len(blob):
        raise ValueError(f"truncated zstd frame header at {start}")
    fhd = blob[pos]
    pos += 1
    fcs_flag = fhd >> 6
    single_segment = (fhd >> 5) & 1
    has_checksum = (fhd >> 2) & 1
    if not single_segment:
        pos += 1  # Window_Descriptor
    pos += _DID_FIELD_SIZE[fhd & 3]
    fcs_size = _FCS_FIELD_SIZE[fcs_flag] or (1 if single_segment else 0)
    if pos + fcs_size > len(blob):
        raise ValueError(f"truncated zstd frame header at {start}")
    content_size: int | None = None
    if fcs_size:
        content_size = int.from_bytes(blob[pos:pos + fcs_size], "little")
        if fcs_size == 2:  # 2-byte field stores value - 256 (RFC 8878)
            content_size += 256
        pos += fcs_size
    while True:  # block walk: 3-byte headers state every block's span
        if pos + 3 > len(blob):
            raise ValueError(f"truncated zstd block header at {pos}")
        header = int.from_bytes(blob[pos:pos + 3], "little")
        pos += 3
        last, btype, bsize = header & 1, (header >> 1) & 3, header >> 3
        if btype == 3:
            raise ValueError(f"reserved zstd block type at {pos - 3}")
        pos += 1 if btype == 1 else bsize  # RLE stores one byte
        if last:
            break
    if has_checksum:
        pos += 4
    if pos > len(blob):
        raise ValueError(f"truncated zstd frame at {start}")
    return pos, content_size


def walk_frames(blob: bytes) -> list[ZstdFrameInfo]:
    """Frame boundaries of a concatenated-zstd blob — no decompression."""
    frames: list[ZstdFrameInfo] = []
    pos = 0
    n = len(blob)
    while pos < n:
        if pos + 4 > n:
            raise ValueError(f"trailing garbage at {pos}")
        magic = int.from_bytes(blob[pos:pos + 4], "little")
        if magic & 0xFFFFFFF0 == _SKIP_MAGIC_LO:
            if pos + 8 > n:
                raise ValueError(f"truncated skippable frame at {pos}")
            (size,) = struct.unpack_from("<I", blob, pos + 4)
            end = pos + 8 + size
            if end > n:
                raise ValueError(f"truncated skippable frame at {pos}")
            frames.append(ZstdFrameInfo(pos, end - pos, 0, skippable=True))
        elif magic == _DATA_MAGIC:
            end, content_size = _walk_data_frame(blob, pos)
            frames.append(ZstdFrameInfo(pos, end - pos, content_size))
        else:
            raise ValueError(f"bad zstd frame magic at {pos}: {magic:#x}")
        pos = end
    return frames


def _measure(blob, frame: ZstdFrameInfo) -> int:
    """Decompressed size of one frame whose header omits it."""
    if _zstd is None:  # pragma: no cover - needs a zstd-less install
        raise RuntimeError(
            "zstandard needed to size a frame without Frame_Content_Size")
    reader = _zstd.ZstdDecompressor().stream_reader(
        io.BytesIO(bytes(blob[frame.comp_off:frame.comp_off + frame.comp_len])))
    total = 0
    while True:
        chunk = reader.read(1 << 20)
        if not chunk:
            return total
        total += len(chunk)


def frame_table(blob: bytes) -> tuple[np.ndarray, np.ndarray]:
    """``(comp_offs, decomp_bases)`` of the *data* frames of a blob.

    ``decomp_bases[i]`` is the decompressed-stream offset where data
    frame ``i`` begins — ``searchsorted`` against it maps any record's
    decompressed offset to its containing frame. Headers lacking
    ``Frame_Content_Size`` fall back to decompressing that one frame to
    measure it (our writer always stores the size, so the common path
    never decompresses anything).
    """
    comp_offs: list[int] = []
    sizes: list[int] = []
    for frame in walk_frames(blob):
        if frame.skippable:
            continue
        comp_offs.append(frame.comp_off)
        sizes.append(frame.content_size if frame.content_size is not None
                     else _measure(blob, frame))
    bases = np.zeros(len(sizes), np.uint64)
    if len(sizes) > 1:
        bases[1:] = np.cumsum(np.asarray(sizes[:-1], np.uint64))
    return np.asarray(comp_offs, np.uint64), bases
