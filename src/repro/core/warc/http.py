"""HTTP message parsing for ``application/http`` WARC payloads.

Two implementations, matching the benchmark axes of the paper's Table 1
("+HTTP" rows):

* :func:`parse_http_fast` — FastWARC-style: one ``find(b"\\r\\n\\r\\n")``
  to bound the header block, one ``split(b"\\r\\n")``, lazy byte values.
* :func:`parse_http_baseline` — WARCIO-style: per-line ``readline()``-shaped
  iteration with eager ``str`` decode and regex-ish splitting.
"""
from __future__ import annotations

import re

from .record import HttpHeaderMap, HEADER_TERMINATOR, CRLF

_BASELINE_SPLIT = re.compile(r":\s*")

# Adversarial payloads can pack tens of thousands of tiny "a:b\r\n" lines
# into the 64 KiB header window; cap how many we ever materialize so a
# hostile record costs O(cap) header-map appends, not O(window).
_MAX_HEADER_LINES = 512


def parse_http_fast(payload: bytes | memoryview) -> tuple[HttpHeaderMap | None, int]:
    """Parse HTTP headers from ``payload``.

    Returns ``(headers, body_offset)``; ``headers`` is ``None`` when the
    payload does not look like an HTTP message. Values stay raw bytes.
    """
    # headers are nearly always < 4 KiB: copy the small window first and
    # only escalate to 64 KiB when the terminator isn't found in it
    if isinstance(payload, memoryview):
        view = bytes(payload[:4096])
        end = view.find(HEADER_TERMINATOR)
        if end < 0 and len(payload) > 4096:
            view = bytes(payload[:64 * 1024])
            end = view.find(HEADER_TERMINATOR)
    else:
        view = payload
        end = view.find(HEADER_TERMINATOR, 0, 64 * 1024)
    if end < 0:
        nl = view.find(b"\n\n", 0, 64 * 1024)  # tolerate LF-only messages
        if nl < 0:
            return None, 0
        head, body_off, sep = view[:nl], nl + 2, b"\n"
    else:
        head, body_off, sep = view[:end], end + 4, CRLF
    lines = head.split(sep)
    if not lines or not (lines[0].startswith(b"HTTP/") or b" HTTP/" in lines[0]):
        return None, 0
    headers = HttpHeaderMap(lines[0])
    for line in lines[1:_MAX_HEADER_LINES + 1]:
        if not line:
            continue
        if line[0] in (0x20, 0x09):  # folded continuation
            headers.append_continuation(line.strip())
            continue
        colon = line.find(b":")
        if colon < 0:
            continue
        headers.append(line[:colon].strip(), line[colon + 1:].strip())
    return headers, body_off


def parse_http_baseline(payload: bytes) -> tuple[HttpHeaderMap | None, int]:
    """WARCIO-faithful variant: eager decode, per-line regex split.

    Part of the measured baseline; deliberately mirrors
    ``warcio.statusandheaders.StatusAndHeadersParser``.
    """
    # simulate readline-oriented consumption over the payload
    off = 0
    n = len(payload)
    i = payload.find(b"\n", off)
    if i < 0:
        return None, 0
    status_line = payload[off:i].rstrip(b"\r")
    text = status_line.decode("latin-1", "replace")  # eager decode (baseline)
    if not (text.startswith("HTTP/") or " HTTP/" in text):
        return None, 0
    headers = HttpHeaderMap(status_line)
    off = i + 1
    while off < n:
        i = payload.find(b"\n", off)
        if i < 0:
            i = n - 1
        line = payload[off:i].rstrip(b"\r")
        off = i + 1
        if not line:
            break
        decoded = line.decode("latin-1", "replace")  # eager decode per line
        if decoded[0] in (" ", "\t"):
            headers.append_continuation(decoded.strip().encode("latin-1"))
            continue
        parts = _BASELINE_SPLIT.split(decoded, maxsplit=1)
        if len(parts) != 2:
            continue
        headers.append(parts[0].encode("latin-1"), parts[1].encode("latin-1"))
    return headers, off
