"""Record digest computation/verification (Table 1 "+Checksum" rows).

WARC records carry ``WARC-Block-Digest`` / ``WARC-Payload-Digest`` headers
of the form ``sha1:<base32>`` (also ``md5:``/``sha256:`` in the wild, and
``crc32:``/``adler32:`` as cheap in-pipeline checks). SHA-1/MD5/SHA-256 run
through hashlib's C core on the host; CRC-32 through ``zlib.crc32``.

Adler-32 additionally has a TPU-side Pallas kernel
(:mod:`repro.kernels.adler32`) — see DESIGN.md §4: CRC's bit-feedback loop
does not transfer to the TPU vector unit, Adler's two running sums do.
"""
from __future__ import annotations

import base64
import hashlib
import zlib

_HASHLIB_ALGOS = {"sha1", "md5", "sha256"}


def block_digest(data: bytes | memoryview, algo: str = "sha1") -> str:
    """Digest in WARC header notation, e.g. ``sha1:3I42H3S6...``."""
    algo = algo.lower()
    if algo in _HASHLIB_ALGOS:
        raw = hashlib.new(algo, data).digest()
        return f"{algo}:{base64.b32encode(raw).decode('ascii')}"
    if algo == "crc32":
        return f"crc32:{zlib.crc32(data) & 0xFFFFFFFF:08x}"
    if algo == "adler32":
        return f"adler32:{zlib.adler32(data) & 0xFFFFFFFF:08x}"
    raise ValueError(f"unsupported digest algorithm: {algo}")


def verify_digest(data: bytes | memoryview, header_value: str) -> bool:
    """Check ``data`` against a ``algo:value`` WARC digest header."""
    algo, _, expected = header_value.partition(":")
    algo = algo.strip().lower()
    expected = expected.strip()
    if algo in _HASHLIB_ALGOS:
        raw = hashlib.new(algo, data).digest()
        if base64.b32encode(raw).decode("ascii") == expected.upper():
            return True
        # tolerate hex notation, which some writers emit instead of base32
        try:
            return bytes.fromhex(expected) == raw
        except ValueError:
            return False
    if algo in ("crc32", "adler32"):
        try:
            want = int(expected, 16)
        except ValueError:  # malformed digest value: mismatch, not a crash
            return False
        got = zlib.crc32(data) if algo == "crc32" else zlib.adler32(data)
        return (got & 0xFFFFFFFF) == want
    return False


def verify_digests_bulk(datas, header_values, *, use_kernel: bool = True,
                        interpret: bool = True) -> list[bool]:
    """Verify many ``algo:value`` digest headers at once.

    The batched path exists for the Adler-32 entries: instead of one
    device dispatch per record, every adler32-digested payload in the
    batch is checksummed by a single ``(B, nblocks)``-gridded Pallas call
    (:func:`repro.kernels.adler32.adler32_batch`) and compared host-side.
    All other algorithms fall back to :func:`verify_digest` per item.
    ``use_kernel=False`` keeps everything on zlib (e.g. when JAX is
    unavailable in a worker process).
    """
    datas = list(datas)
    header_values = list(header_values)
    if len(datas) != len(header_values):
        raise ValueError("datas and header_values must have equal length")
    results: list[bool] = [False] * len(datas)
    adler_idx: list[int] = []
    adler_expected: list[int] = []
    for i, (data, header) in enumerate(zip(datas, header_values)):
        algo, _, expected = header.partition(":")
        if use_kernel and algo.strip().lower() == "adler32":
            try:
                adler_expected.append(int(expected.strip(), 16))
                adler_idx.append(i)
                continue
            except ValueError:
                results[i] = False
                continue
        results[i] = verify_digest(data, header)
    if adler_idx:
        from repro.kernels.adler32 import adler32_batch

        got = adler32_batch([datas[i] for i in adler_idx],
                            interpret=interpret)
        for j, i in enumerate(adler_idx):
            results[i] = int(got[j]) == adler_expected[j]
    return results


def adler32_reference(data: bytes) -> int:
    """Pure-Python Adler-32 (oracle for the Pallas kernel tests)."""
    MOD = 65521
    s1, s2 = 1, 0
    for b in data:
        s1 = (s1 + b) % MOD
        s2 = (s2 + s1) % MOD
    return (s2 << 16) | s1
