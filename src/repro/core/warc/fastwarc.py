"""FastWARC-style optimized WARC parser.

Implements the paper's three fixes:

1. **Stream decompression** — member-granular single-C-call gzip decode
   (:class:`GZipStream`), LZ4 frames with lazy first-block decode, zstd
   bulk C-speed streaming.
2. **Record parsing** — bulk buffer scans: one ``find(b"\\r\\n\\r\\n")`` to
   bound the header block, one ``split(b"\\r\\n")`` to cut headers, raw
   ``bytes`` values decoded lazily; record content exposed as a zero-copy
   ``memoryview``; HTTP parsing deferred until requested.
3. **Cheap skipping** — a record-type pre-filter string-scans the raw
   header block *before* any header-map construction; skipped records cost
   a ``Content-Length`` seek (uncompressed/zstd), a frame hop (LZ4), or a
   member decode only (gzip — boundaries are unknowable without inflate).

The public API mirrors FastWARC's ``ArchiveIterator``. Hot-path style note:
this file deliberately trades a little elegance (int masks instead of
IntFlag math, pre-bound locals) for measured wins — see EXPERIMENTS.md
§Paper for the profile-driven iteration log.
"""
from __future__ import annotations

import io
import os
from typing import BinaryIO, Callable, Iterator

from repro import obs
from repro.obs import trace

from .checksum import verify_digest
from .errors import ErrorLedger, RecordReadError
from .http import parse_http_fast
from .record import (
    CRLF,
    HEADER_TERMINATOR,
    HTTP_TYPE_MASK,
    RECORD_TYPE_FROM_VALUE,
    RECORD_TYPE_VALUES,
    UNKNOWN_TYPE_VALUE,
    WARC_MAGIC,
    WarcHeaderMap,
    WarcRecord,
    WarcRecordType,
    parse_content_length,
)
from .record import scan_header_field_in as _scan_field_in
from .streams import (
    _ARENA_BYTES,
    CopyStats,
    GZipStream,
    LZ4Stream,
    MemberArena,
    ProcessReadaheadDecoder,
    ReadaheadDecoder,
    RecordBuffer,
    ZstdStream,
    detect_compression,
    next_member_tolerant,
)

_READ_BLOCK = 1 << 20
_COMPACT_THRESHOLD = 8 << 20
_TYPE_NEEDLE = b"WARC-Type:"
_CLEN_NEEDLE = b"Content-Length:"


from .record import scan_header_field as _scan_header_field  # hot-path alias


def parse_header_block(block: bytes | memoryview) -> WarcHeaderMap:
    """One-pass split of a raw WARC header block into a lazy header map."""
    if isinstance(block, memoryview):
        block = bytes(block)
    lines = block.split(CRLF)
    headers = WarcHeaderMap(lines[0])
    pairs = headers._pairs  # direct fill: append() indirection profiled hot
    for line in lines[1:]:
        if not line:
            continue
        c0 = line[0]
        if c0 == 0x20 or c0 == 0x09:  # folded continuation
            if pairs:
                name, prev = pairs[-1]
                pairs[-1] = (name, prev + b" " + line.strip())
            continue
        colon = line.find(b":")
        if colon < 0:
            continue
        value = line[colon + 1:]
        # single leading space is the overwhelmingly common layout
        pairs.append((line[:colon],
                      value[1:] if value[:1] == b" " else value.strip()))
    return headers


class _TolerantReadGuard:
    """Wrap a decompressing reader so a mid-stream decode error becomes
    EOF plus an ``ErrorLedger`` entry instead of an exception.

    Used for tolerant zstd parsing: unlike gzip/LZ4 there are no member
    boundaries to resync on, so a damaged stream loses its tail — the
    ledger records where (decompressed-domain offset; the skipped length
    is unknowable without a decodable stream, recorded as 0).
    """

    def __init__(self, raw, report) -> None:
        self._raw = raw
        self._report = report
        self._produced = 0
        self._dead = False

    def _fail(self, exc: BaseException) -> None:
        self._dead = True
        self._report(self._produced, "bad_zstd_stream", 0, repr(exc))

    def read(self, n: int = -1) -> bytes:
        if self._dead:
            return b""
        try:
            data = self._raw.read(n)
        except Exception as exc:  # noqa: BLE001 - tolerant by contract
            self._fail(exc)
            return b""
        self._produced += len(data)
        return data

    def readinto(self, buf) -> int:
        if self._dead:
            return 0
        try:
            n = self._raw.readinto(buf)
        except Exception as exc:  # noqa: BLE001 - tolerant by contract
            self._fail(exc)
            return 0
        self._produced += n
        return n


class FastWARCIterator:
    """Iterate WARC records with filtering, lazy HTTP, optional digests.

    Parameters
    ----------
    source:
        file object, path, or bytes of a (possibly compressed) WARC file.
    record_types:
        bit mask of :class:`WarcRecordType` to yield; everything else is
        skipped via the cheap pre-filter path.
    parse_http:
        parse HTTP headers of ``application/http`` payloads on yield.
    verify_digests:
        verify ``WARC-Block-Digest`` / ``WARC-Payload-Digest``.
    func_filter:
        optional predicate applied after header parse, before HTTP parse.
    zero_copy:
        parse through the pooled arenas (default): uncompressed/zstd
        streams go through :class:`~repro.core.warc.streams.RecordBuffer`,
        gzip/LZ4 members are decoded **directly into**
        :class:`~repro.core.warc.streams.MemberArena` slots
        (``next_member_into`` — no per-record member ``bytes``) —
        record content is a borrowed ``memoryview``, see
        :meth:`WarcRecord.detach`. ``False`` selects the PR 1-era
        bytes-slicing / member-``bytes`` loops (kept as the
        instrumented "old path" the ingest benchmark measures against).
    arena_bytes:
        initial arena size for the zero-copy path (default 1 MiB; grows
        geometrically past oversized records); also the readahead
        decoder's slot-packing watermark. Exposed for memory tuning and
        for tests that force arena recycling.
    readahead:
        overlap member decode with record parsing: a decoder thread
        inflates gzip/LZ4 members into arena slots ahead of the parser
        through a bounded slot ring
        (:class:`~repro.core.warc.streams.ReadaheadDecoder`). Default
        ``None`` enables it wherever it cannot lose work: gzip always
        (members must be inflated to find their boundaries anyway), LZ4
        only when no type filter is active (the filtered LZ4 path keeps
        the lazy first-block sniff + frame-hop skip, which readahead's
        decode-everything would defeat; pass ``readahead=True`` to
        force it regardless). Only the zero-copy member paths ever
        spawn the thread; ``close()`` joins it.
    readahead_depth:
        slot-batches the decoder may run ahead of the parser (ring
        bound; default 3 — double buffering plus one slot of slack
        against scheduler jitter on busy hosts).
    tolerant:
        recover from malformed input instead of raising: bad
        ``Content-Length``, garbage headers, truncated payloads and
        corrupt gzip/LZ4 members trigger a *resync scan* to the next
        record/member magic; each damaged byte range is quarantined
        into ``self.error_ledger`` (offset, shard, error class, bytes
        skipped) and parsing continues. Good records keep full
        zero-copy semantics (requires ``zero_copy=True``; the legacy
        loops stay strict baselines). Strict mode behavior is
        bit-for-bit unchanged.
    error_ledger:
        optional shared :class:`~repro.core.warc.errors.ErrorLedger` to
        append into (the tolerant index build aggregates one ledger
        across shards); default: a fresh per-iterator ledger.

    Every Python-level byte copy either path makes is tallied in
    ``self.copy_stats`` (:class:`~repro.core.warc.streams.CopyStats`);
    member decode is split between its ``member_bytes_copied`` (legacy
    member materialization) and ``decode_into_arena`` (arena-path
    decompressor output) counters.
    """

    def __init__(
        self,
        source: BinaryIO | bytes | str,
        *,
        record_types: WarcRecordType = WarcRecordType.any_type,
        parse_http: bool = True,
        verify_digests: bool = False,
        func_filter: Callable[[WarcRecord], bool] | None = None,
        zero_copy: bool = True,
        arena_bytes: int | None = None,
        readahead: bool | None = None,
        readahead_depth: int = 3,
        tolerant: bool = False,
        error_ledger: ErrorLedger | None = None,
    ) -> None:
        if tolerant and not zero_copy:
            # the legacy loops are kept as the *measured baseline* —
            # teaching them resync would change what they measure
            raise ValueError("tolerant=True requires zero_copy=True")
        self.tolerant = tolerant
        self.error_ledger = error_ledger if error_ledger is not None \
            else ErrorLedger()
        self._shard = source if isinstance(source, str) else None
        self._slot_damaged = False  # set by _record_from_slot on bad members
        self._owned_file: BinaryIO | None = None
        # path / bytes sources can be re-opened by a readahead decoder
        # *process* (fork ships bytes for free); file objects cannot
        self._source_spec: str | bytes | None = None
        if isinstance(source, str):
            self._source_spec = source
            source = open(source, "rb")
            self._owned_file = source
        elif isinstance(source, (bytes, bytearray, memoryview)):
            self._source_spec = bytes(source)
            source = io.BytesIO(self._source_spec)
        self._raw = source
        self.record_types = record_types
        self._types_mask = int(record_types)
        self._filter_active = self._types_mask != int(WarcRecordType.any_type)
        self.parse_http = parse_http
        self.verify_digests = verify_digests
        self.func_filter = func_filter
        self.zero_copy = zero_copy
        self.arena_bytes = arena_bytes  # None: streams._ARENA_BYTES default
        self.readahead = readahead
        self.readahead_depth = readahead_depth
        self._decoder: ReadaheadDecoder | ProcessReadaheadDecoder | None = None
        self.copy_stats = CopyStats()
        self.records_skipped = 0
        self.records_yielded = 0
        self._obs_published = False
        # an externally-shared ledger (tolerant index build) predates this
        # iterator: publish only the entries added past this watermark
        self._ledger_base = len(self.error_ledger.entries())

        head = source.read(8)
        source.seek(-len(head), io.SEEK_CUR)
        self._kind = detect_compression(head)
        self._stream = None
        if self._kind == "gzip":
            # legacy path keeps PR 4 semantics bit-for-bit (zlib always
            # verified member CRCs internally); the zero-copy decode path
            # is FastWARC-style raw-deflate — redundant per-member CRC
            # off by default, end-to-end integrity via verify_digests
            self._stream = GZipStream(source,
                                      verify_checksums=not zero_copy)
        elif self._kind == "lz4":
            self._stream = LZ4Stream(source)
        elif self._kind == "zstd":
            # bulk C decode + in-buffer splitting (see ZstdStream docstring);
            # the arena path readintos straight out of the decompressor
            self._raw = ZstdStream(source)
            if tolerant:
                # zstd has no member boundaries to resync on: a damaged
                # stream truncates at the error point, ledgered as a tail
                self._raw = _TolerantReadGuard(self._raw, self._ledger)

    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[WarcRecord]:
        if self.closed:
            return  # exhausted path-owned source: empty, like re-reading EOF
        try:
            if self._stream is None:
                if self.zero_copy:
                    yield from self._iter_uncompressed_arena()
                else:
                    yield from self._iter_uncompressed_legacy()
            elif self.zero_copy:
                yield from self._iter_members_arena()
            elif isinstance(self._stream, LZ4Stream):
                yield from self._iter_lz4()
            else:
                yield from self._iter_members()
        finally:
            # files *we* opened (str paths) are released on exhaustion or
            # generator teardown — callers iterating many shards per epoch
            # must not accumulate fds (WarcTokenLoader does exactly that)
            self._stop_decoder()
            self._publish_obs()
            if self._owned_file is not None:
                self.close()

    # -- lifecycle -------------------------------------------------------
    @property
    def closed(self) -> bool:
        f = self._owned_file
        return f is not None and f.closed

    def _stop_decoder(self) -> None:
        decoder = self._decoder
        if decoder is not None:
            self._decoder = None
            decoder.close()

    def close(self) -> None:
        """Release everything this iterator owns: join the readahead
        decoder thread (and free its ring slots) if one is running, and
        close the underlying file if this iterator opened it."""
        self._stop_decoder()
        self._publish_obs()
        if self._owned_file is not None and not self._owned_file.closed:
            self._owned_file.close()

    def __enter__(self) -> "FastWARCIterator":
        return self

    def __exit__(self, *exc_info) -> None:
        self.close()

    # -- observability ----------------------------------------------------
    def _publish_obs(self) -> None:
        """Fold this iterator's terminal counters into the process-default
        registry (``ingest.*``): CopyStats, records yielded/skipped, and
        the ledger entries this iterator added. Idempotent — the first of
        exhaustion/close wins, so double-close never double-counts."""
        if self._obs_published:
            return
        self._obs_published = True
        reg = obs.registry()
        reg.fold_counters(self.copy_stats.as_dict(), prefix="ingest.")
        reg.fold_counters({
            "records": self.records_yielded,
            "records_skipped": self.records_skipped,
            "shards": 1,
            "ledger_entries":
                len(self.error_ledger.entries()) - self._ledger_base,
        }, prefix="ingest.")

    # -- fault accounting -------------------------------------------------
    def _ledger(self, offset: int, error_class: str, bytes_skipped: int,
                message: str = "") -> None:
        self.error_ledger.record(self._shard, offset, error_class,
                                 bytes_skipped, message)

    @staticmethod
    def _find_magic_anchored(rb: RecordBuffer, pos: int) -> int:
        """Next ``WARC/`` magic at a line start (tolerant resync target).

        Record payloads may legitimately contain ``WARC/`` (warcinfo
        bodies quote it); anchoring to a preceding LF keeps the resync
        scan from latching onto payload text mid-damaged-region.
        """
        nxt = rb.find(WARC_MAGIC, pos)
        while nxt > 0:
            if rb.startswith(b"\n", nxt - 1):
                return nxt
            nxt = rb.find(WARC_MAGIC, nxt + 1)
        return nxt

    # -- shared record assembly -----------------------------------------
    def _type_value(self, header_block: bytes) -> int:
        raw = _scan_header_field(header_block, _TYPE_NEEDLE)
        if raw is None:
            return UNKNOWN_TYPE_VALUE
        return RECORD_TYPE_VALUES.get(raw.lower(), UNKNOWN_TYPE_VALUE)

    def _finalize(self, header_block: bytes, type_value: int,
                  content, offset: int) -> WarcRecord | None:
        """Assemble a record from its raw header block (headers stay lazy)."""
        rtype = RECORD_TYPE_FROM_VALUE[type_value]
        record = WarcRecord(header_block, rtype, content, offset,
                            stats=self.copy_stats)
        if self.func_filter is not None and not self.func_filter(record):
            self.records_skipped += 1
            return None
        if self.verify_digests:
            bd = _scan_header_field(header_block, b"WARC-Block-Digest:")
            if bd is not None:
                record.verified_block_digest = verify_digest(
                    record.content_view(), bd.decode("latin-1"))
        if self.parse_http and (type_value & HTTP_TYPE_MASK) and record.is_http:
            http, body_off = parse_http_fast(record.content_view())
            record.http_headers = http
            record.http_content_offset = body_off if http is not None else -1
            if self.verify_digests and record.http_headers is not None:
                pd = _scan_header_field(header_block, b"WARC-Payload-Digest:")
                if pd is not None:
                    record.verified_payload_digest = verify_digest(
                        record.payload_view(), pd.decode("latin-1"))
        self.records_yielded += 1
        return record

    # -- uncompressed / zstd: pooled-arena zero-copy splitting (default) --
    def _iter_uncompressed_arena(self) -> Iterator[WarcRecord]:
        # Absolute-offset parse over a RecordBuffer: fills land in a
        # reusable bytearray arena via readinto, record content is a
        # borrowed memoryview into it, and the only copies left are the
        # yielded records' (small) header blocks plus the arena-roll
        # tail — all tallied in self.copy_stats (DESIGN.md §9).
        # tracing attributes fill time via a reader proxy wrapped ONLY when
        # enabled — the disabled hot loop keeps its direct readinto path
        raw = trace.timed_reader(self._raw) if trace.enabled() else self._raw
        if self.arena_bytes is not None:
            rb = RecordBuffer(raw, stats=self.copy_stats,
                              arena_bytes=self.arena_bytes)
        else:
            rb = RecordBuffer(raw, stats=self.copy_stats)
        types_mask = self._types_mask
        filter_active = self._filter_active
        tolerant = self.tolerant
        magic_len = len(WARC_MAGIC)
        pos = 0  # absolute stream offset of the next unconsumed byte
        # tolerant bookkeeping: [damage_start, <next good magic>) is one
        # quarantined range of class damage_class when set
        damage_start: int | None = None
        damage_class = "garbage"
        while True:
            rb.discard(pos)
            if not rb.ensure(pos, magic_len):
                if tolerant and damage_start is not None \
                        and rb.end_abs > damage_start:
                    self._ledger(damage_start, damage_class,
                                 rb.end_abs - damage_start)
                return
            if not rb.startswith(WARC_MAGIC, pos):
                if tolerant and damage_start is None:
                    damage_start = pos
                nxt = self._find_magic_anchored(rb, pos) if tolerant \
                    else rb.find(WARC_MAGIC, pos)
                if nxt < 0:
                    if rb.eof:
                        if tolerant and damage_start is not None:
                            self._ledger(damage_start, damage_class,
                                         rb.end_abs - damage_start)
                        return
                    # garbage: keep only a magic-straddle tail, read on
                    pos = max(pos, rb.end_abs - magic_len + 1)
                    rb.discard(pos)
                    rb.ensure(pos, rb.end_abs - pos + 1)
                    continue
                pos = nxt
                rb.discard(pos)
            if tolerant and damage_start is not None:
                if pos > damage_start:
                    self._ledger(damage_start, damage_class,
                                 pos - damage_start)
                damage_start = None
                damage_class = "garbage"
            hdr_end = rb.find(HEADER_TERMINATOR, pos)
            while hdr_end < 0:
                if rb.eof:
                    if tolerant:
                        self._ledger(pos, "truncated_tail",
                                     rb.end_abs - pos)
                    return
                rb.ensure(pos, rb.end_abs - pos + _READ_BLOCK)
                hdr_end = rb.find(HEADER_TERMINATOR, pos)
            clen_raw = rb.scan_field(_CLEN_NEEDLE, pos, hdr_end)
            if tolerant:
                clen_opt = parse_content_length(clen_raw)
                if clen_opt is None:
                    # untrustworthy framing: quarantine from this record's
                    # magic and resync to the next one
                    damage_start = pos
                    damage_class = "bad_content_length"
                    pos += magic_len
                    continue
                clen = clen_opt
            else:
                clen = int(clen_raw) if clen_raw and clen_raw.isdigit() else 0
            body_start = hdr_end + 4
            record_end = body_start + clen + 4
            if tolerant:
                if not rb.ensure(pos, record_end - pos):
                    # EOF inside the claimed body. The whole tail is
                    # buffered now (ensure grew the arena to EOF), so a
                    # *bogus-but-numeric* length mid-file can still be
                    # resynced past instead of eating the rest of the
                    # shard; only a tail with no further record start is
                    # a true truncation.
                    nxt = self._find_magic_anchored(rb, pos + magic_len)
                    if nxt < 0:
                        self._ledger(pos, "truncated_tail",
                                     rb.end_abs - pos)
                        return
                    self._ledger(pos, "bad_content_length", nxt - pos)
                    pos = nxt
                    continue
                if not rb.startswith(HEADER_TERMINATOR, body_start + clen):
                    # Content-Length does not land on a record terminator:
                    # the framing is lies, resync rather than desync
                    damage_start = pos
                    damage_class = "bad_content_length"
                    pos += magic_len
                    continue

            type_raw = rb.scan_field(_TYPE_NEEDLE, pos, hdr_end)
            type_value = (UNKNOWN_TYPE_VALUE if type_raw is None else
                          RECORD_TYPE_VALUES.get(type_raw.lower(),
                                                 UNKNOWN_TYPE_VALUE))
            if filter_active and not (type_value & types_mask):
                # bottleneck (3): skipped records never leave the arena —
                # not even their header block is sliced out
                self.records_skipped += 1
                pos = record_end if rb.ensure(pos, record_end - pos) \
                    else rb.end_abs
                continue
            if not rb.ensure(pos, record_end - pos):
                return  # truncated final record (strict: silent stop)
            header_block = rb.take_bytes(pos, hdr_end)
            content = rb.view(body_start, body_start + clen)
            record = self._finalize(header_block, type_value, content, pos)
            pos = record_end
            if record is not None:
                yield record

    # -- uncompressed / zstd: PR 1-era bytes-slicing loop (measured "old
    # path"; selected with zero_copy=False) ------------------------------
    def _iter_uncompressed_legacy(self) -> Iterator[WarcRecord]:
        # `buf` is immutable bytes: appends REBIND (never resize), so
        # zero-copy memoryviews handed to callers stay valid on the old
        # object; rebasing happens only at record boundaries.
        raw_read = self._raw.read
        stats = self.copy_stats
        types_mask = self._types_mask
        filter_active = self._filter_active
        buf = b""
        pos = 0       # buffer-relative cursor
        base = 0      # absolute stream offset of buf[0]
        eof = False

        def fill(need: int) -> bool:
            """Ensure ``len(buf) - pos >= need``; never moves ``pos``."""
            nonlocal buf, eof
            if len(buf) - pos >= need:
                return True
            parts = [buf]
            have = len(buf) - pos
            while have < need and not eof:
                chunk = raw_read(_READ_BLOCK)
                if not chunk:
                    eof = True
                    break
                parts.append(chunk)
                have += len(chunk)
            if len(parts) > 1:
                buf = b"".join(parts)
                stats.count_copy(len(buf))  # the join re-copies everything
            return len(buf) - pos >= need

        while True:
            if pos > _COMPACT_THRESHOLD:  # record boundary: safe to rebase
                buf = buf[pos:]
                stats.count_copy(len(buf))
                base += pos  # keep reported offsets absolute past the rebase
                pos = 0
            if not fill(len(WARC_MAGIC)):
                return
            if not buf.startswith(WARC_MAGIC, pos):
                nxt = buf.find(WARC_MAGIC, pos)
                if nxt < 0:
                    if eof:
                        return
                    fill(len(buf) - pos + _READ_BLOCK)
                    continue
                pos = nxt
            hdr_end = buf.find(HEADER_TERMINATOR, pos)
            while hdr_end < 0:
                if eof:
                    return
                fill(len(buf) - pos + _READ_BLOCK)
                hdr_end = buf.find(HEADER_TERMINATOR, pos)
            header_block = buf[pos:hdr_end]  # one small copy, reused thrice
            stats.count_copy(len(header_block))
            clen_raw = _scan_header_field(header_block, _CLEN_NEEDLE)
            clen = int(clen_raw) if clen_raw and clen_raw.isdigit() else 0
            body_start = hdr_end + 4
            record_end = body_start - pos + clen + 4

            type_value = self._type_value(header_block)
            if filter_active and not (type_value & types_mask):
                # bottleneck (3): seek past the body, parse nothing
                self.records_skipped += 1
                if fill(record_end):
                    pos += record_end
                else:
                    pos = len(buf)
                continue
            if not fill(record_end):
                return  # truncated final record
            content = memoryview(buf)[body_start:body_start + clen]
            record = self._finalize(header_block, type_value, content,
                                    base + pos)
            pos += record_end
            if record is not None:
                yield record

    # -- gzip: member == record (legacy member-``bytes`` path) ------------
    def _iter_members(self) -> Iterator[WarcRecord]:
        stream = self._stream
        count_member = self.copy_stats.count_member_copy
        while True:
            offset = stream.tell_compressed()
            data = stream.next_member()
            if data is None:
                return
            count_member(len(data))  # per-record member bytes materialized
            record = self._record_from_member(data, offset)
            if record is not None:
                yield record

    # -- lz4: lazy first-block sniff + frame hop skip (legacy) ------------
    def _iter_lz4(self) -> Iterator[WarcRecord]:
        stream = self._stream
        filter_active = self._filter_active
        while True:
            offset = stream.tell_compressed()
            lazy = stream.begin_member()
            if lazy is None:
                return
            if filter_active:
                hdr_end = lazy.prefix.find(HEADER_TERMINATOR)
                sniff = lazy.prefix[:hdr_end] if hdr_end >= 0 else lazy.prefix
                if not (self._type_value(sniff) & self._types_mask):
                    self.records_skipped += 1
                    lazy.skip()
                    continue
            data = lazy.read_all()
            self.copy_stats.count_member_copy(len(data))
            record = self._record_from_member(data, offset)
            if record is not None:
                yield record

    # -- gzip/lz4: decode-into-arena members (zero-copy default) ----------
    def _resolve_readahead(self, is_lz4: bool) -> bool:
        if self.readahead is not None:
            return self.readahead
        # auto: on wherever it cannot lose work — gzip members must be
        # inflated to find their boundaries anyway; filtered LZ4 keeps
        # the lazy sniff + frame-hop skip instead
        return not (is_lz4 and self._filter_active)

    def _iter_members_arena(self) -> Iterator[WarcRecord]:
        stream = self._stream
        arena = MemberArena(stats=self.copy_stats)
        is_lz4 = isinstance(stream, LZ4Stream)
        if self._resolve_readahead(is_lz4):
            yield from self._iter_members_readahead(stream, arena)
        elif is_lz4 and self._filter_active:
            yield from self._iter_lz4_arena_lazy(stream, arena)
        elif self.tolerant:
            stats = self.copy_stats
            traced = trace.enabled()  # once per iterator, not per member
            while True:
                slot = arena.acquire()
                if traced:
                    with trace.span("ingest.decode_member"):
                        item = next_member_tolerant(stream, slot, stats,
                                                    self._ledger)
                else:
                    item = next_member_tolerant(stream, slot, stats,
                                                self._ledger)
                if item is None:
                    arena.release(slot)
                    return
                n, offset = item
                record = self._record_from_slot(slot, 0, n, offset)
                if record is None and self._slot_damaged:
                    self._ledger(offset, "bad_member",
                                 stream.tell_compressed() - offset,
                                 "member decoded but contains no record")
                arena.release(slot)
                if record is not None:
                    yield record
        else:
            stats = self.copy_stats
            traced = trace.enabled()
            while True:
                offset = stream.tell_compressed()
                slot = arena.acquire()
                if traced:
                    with trace.span("ingest.decode_member"):
                        n = stream.next_member_into(slot, stats)
                else:
                    n = stream.next_member_into(slot, stats)
                if n is None:
                    arena.release(slot)
                    return
                record = self._record_from_slot(slot, 0, n, offset)
                arena.release(slot)
                if record is not None:
                    yield record

    def _iter_members_readahead(self, stream,
                                arena: MemberArena) -> Iterator[WarcRecord]:
        # a decoder stage inflates members into slot batches ahead of this
        # parse loop (bounded ring). Preferred implementation is a child
        # *process* (true CPU overlap — the GIL serializes a decoder
        # thread against a hot parse loop, see ProcessReadaheadDecoder);
        # in-memory/file-object sources without a fork context use the
        # decoder thread. Lifecycle contract either way: the stage dies
        # with this generator (finally) and with close().
        stats = self.copy_stats
        tolerant = self.tolerant
        watermark = self.arena_bytes if self.arena_bytes else _ARENA_BYTES
        decoder = None
        if self._source_spec is not None:
            try:
                decoder = ProcessReadaheadDecoder(
                    self._source_spec, arena, depth=self.readahead_depth,
                    watermark=watermark, tolerant=tolerant,
                    on_ledger=self._ledger)
            except (RuntimeError, OSError):
                decoder = None  # no fork / constrained /dev/shm: thread
        if decoder is None:
            if tolerant:
                def decode_member(slot: bytearray):
                    return next_member_tolerant(stream, slot, stats,
                                                self._ledger)
            else:
                def decode_member(slot: bytearray):
                    offset = stream.tell_compressed()
                    n = stream.next_member_into(slot, stats)
                    return None if n is None else (n, offset)

            decoder = ReadaheadDecoder(decode_member, arena,
                                       depth=self.readahead_depth,
                                       watermark=watermark)
        self._decoder = decoder
        get = decoder.get
        release = decoder.release
        record_from_slot = self._record_from_slot
        traced = trace.enabled()  # once per iterator; spans are per batch
        try:
            while True:
                if traced:
                    with trace.span("ingest.decode_wait"):
                        item = get()
                else:
                    item = get()
                if item is None:
                    return
                _, slot, members = item
                if traced:
                    # parse the whole batch inside the span, yield after —
                    # consumer time must not pollute ingest.parse_batch
                    batch = []
                    with trace.span("ingest.parse_batch"):
                        for start, nbytes, offset in members:
                            record = record_from_slot(slot, start, nbytes,
                                                      offset)
                            if record is None:
                                if tolerant and self._slot_damaged:
                                    self._ledger(
                                        offset, "bad_member", 0,
                                        "member decoded but contains "
                                        "no record")
                                continue
                            batch.append(record)
                    yield from batch
                else:
                    for start, nbytes, offset in members:
                        record = record_from_slot(slot, start, nbytes, offset)
                        if record is None:
                            if tolerant and self._slot_damaged:
                                self._ledger(
                                    offset, "bad_member", 0,
                                    "member decoded but contains no record")
                            continue
                        yield record
                release(slot)
        finally:
            self._stop_decoder()

    def _iter_lz4_arena_lazy(self, stream,
                             arena: MemberArena) -> Iterator[WarcRecord]:
        # filtered LZ4: first block decodes into the slot for the type
        # sniff; skipped frames roll the prefix back off the slot and hop
        # block headers only — cheap skipping *and* arena decode
        types_mask = self._types_mask
        stats = self.copy_stats
        tolerant = self.tolerant
        while True:
            offset = stream.tell_compressed()
            slot = arena.acquire()
            try:
                member = stream.begin_member_into(slot)
                if member is None:
                    arena.release(slot)
                    return
                hdr_end = slot.find(HEADER_TERMINATOR, 0, member.prefix_len)
                sniff_end = hdr_end if hdr_end >= 0 else member.prefix_len
                type_raw = _scan_field_in(slot, _TYPE_NEEDLE, 0, sniff_end)
                type_value = (UNKNOWN_TYPE_VALUE if type_raw is None else
                              RECORD_TYPE_VALUES.get(type_raw.lower(),
                                                     UNKNOWN_TYPE_VALUE))
                if not (type_value & types_mask):
                    self.records_skipped += 1
                    member.skip()
                    arena.release(slot)
                    continue
                n = member.finish(stats)
            except Exception as exc:  # noqa: BLE001 - tolerant by contract
                if not tolerant:
                    arena.release(slot)
                    raise
                from .errors import classify_member_error

                del slot[:]  # partial first-block decode: roll it off
                arena.release(slot)
                skipped = stream.resync(offset)
                if skipped is None:
                    self._ledger(offset, "truncated_tail",
                                 stream.tell_compressed() - offset,
                                 repr(exc))
                    return
                self._ledger(offset, classify_member_error(exc), skipped,
                             repr(exc))
                continue
            record = self._record_from_slot(slot, 0, n, offset)
            if record is None and tolerant and self._slot_damaged:
                self._ledger(offset, "bad_member",
                             stream.tell_compressed() - offset,
                             "member decoded but contains no record")
            arena.release(slot)
            if record is not None:
                yield record

    def _record_from_slot(self, slot: bytearray, at: int, nbytes: int,
                          offset: int) -> WarcRecord | None:
        """Parse one decoded member in place: type/length sniffed off the
        slot, header block copied out (small, counted), content borrowed
        as a ``memoryview`` of the slot — the member-path twin of the
        :class:`RecordBuffer` parse (DESIGN.md §9)."""
        self._slot_damaged = False
        end = at + nbytes
        start = slot.find(WARC_MAGIC, at, end)
        if start < 0:
            self._slot_damaged = True  # decoded fine, but no record in it
            return None
        hdr_end = slot.find(HEADER_TERMINATOR, start, end)
        if hdr_end < 0:
            self._slot_damaged = True
            return None
        type_raw = _scan_field_in(slot, _TYPE_NEEDLE, start, hdr_end)
        type_value = (UNKNOWN_TYPE_VALUE if type_raw is None else
                      RECORD_TYPE_VALUES.get(type_raw.lower(),
                                             UNKNOWN_TYPE_VALUE))
        if self._filter_active and not (type_value & self._types_mask):
            self.records_skipped += 1
            return None
        clen_raw = _scan_field_in(slot, _CLEN_NEEDLE, start, hdr_end)
        clen = int(clen_raw) if clen_raw and clen_raw.isdigit() else 0
        header_block = bytes(memoryview(slot)[start:hdr_end])
        self.copy_stats.count_copy(len(header_block))
        body_start = hdr_end + 4
        content = memoryview(slot)[body_start:min(body_start + clen, end)]
        return self._finalize(header_block, type_value, content, offset)

    def read_one(self) -> WarcRecord | None:
        """Parse and return the next record only (random-access support).

        Used by :class:`repro.index.cdx.RandomAccessReader`: position the
        underlying file at a record boundary (a CDX offset), construct the
        iterator, call ``read_one()`` — exactly one member is decompressed
        and one record parsed; the rest of the archive is never touched.
        """
        # random-access reads are serving-side: the caller counts them
        # (gateway.records_fetched); a throwaway iterator publishing
        # ingest.shards/records per fetch would drown the real sweep
        # counters in the merged snapshot
        self._obs_published = True
        return next(iter(self), None)

    def _record_from_member(self, data: bytes, offset: int) -> WarcRecord | None:
        if not data.startswith(WARC_MAGIC):
            start = data.find(WARC_MAGIC)
            if start < 0:
                return None
            data = data[start:]
            self.copy_stats.count_copy(len(data))
        hdr_end = data.find(HEADER_TERMINATOR)
        if hdr_end < 0:
            return None
        header_block = data[:hdr_end]
        self.copy_stats.count_copy(len(header_block))
        type_value = self._type_value(header_block)
        if self._filter_active and not (type_value & self._types_mask):
            self.records_skipped += 1
            return None
        clen_raw = _scan_header_field(header_block, _CLEN_NEEDLE)
        clen = int(clen_raw) if clen_raw and clen_raw.isdigit() else 0
        body_start = hdr_end + 4
        content = memoryview(data)[body_start:body_start + clen]
        return self._finalize(header_block, type_value, content, offset)


def read_record_at(source, offset: int, *,
                   parse_http: bool = True,
                   verify_digests: bool = False,
                   shard: str | None = None) -> WarcRecord:
    """Parse exactly one record at absolute ``offset`` in ``source``.

    ``source`` is a seekable file object over the *addressable* stream —
    the compressed file for gzip/LZ4 members, the raw file for
    uncompressed WARCs (zstd has no cheap member boundaries — callers
    decompress first; see ``streams.ZstdStream``) — or a filesystem
    path, opened and closed around the read. This is the paper's
    "constant-time random access" claim made executable: cost is one
    seek + one member decode + one record parse, independent of archive
    size. The returned record's ``stream_offset`` is rebased to the
    absolute ``offset``.

    An offset that addresses no record raises
    :class:`~repro.core.warc.errors.RecordReadError` carrying the offset
    and shard — never a bare ``zlib.error`` / ``struct.error`` /
    ``LZ4Error`` from the decode internals, and never a silent ``None``:
    whether the bytes there fail to decode (corrupted member) or decode
    to nothing (stale index, truncated shard), the caller asked for a
    record that does not exist.
    """
    if isinstance(source, (str, os.PathLike)):
        if shard is None:
            shard = os.fspath(source)
        with open(source, "rb") as f:
            return read_record_at(f, offset, parse_http=parse_http,
                                  verify_digests=verify_digests, shard=shard)
    try:
        source.seek(offset)
        # readahead off: one member is parsed and the iterator abandoned —
        # spinning a decoder thread per random-access read would be pure
        # cost
        it = FastWARCIterator(source, parse_http=parse_http,
                              verify_digests=verify_digests,
                              readahead=False)
        record = it.read_one()
    except (OSError, RecordReadError):
        raise
    except Exception as exc:
        raise RecordReadError(
            f"damaged record: {exc!r}", offset=offset, shard=shard) from exc
    if record is None:
        # e.g. a mid-member gzip offset: the member scan sees no magic
        # and reports a clean end-of-stream rather than an error
        raise RecordReadError("offset addresses no record "
                              "(stale index or truncated shard)",
                              offset=offset, shard=shard)
    # content may be a zero-copy borrow of the iterator's arena;
    # detach so the record outlives the abandoned iterator
    record.detach()
    record.stream_offset = offset
    return record
