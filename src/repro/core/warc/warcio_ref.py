"""WARCIO-faithful baseline parser — the paper's comparison target.

This module deliberately reproduces the *architecture* of
``warcio.archiveiterator.ArchiveIterator`` (the de-facto standard Python
WARC library the paper benchmarks against), because that architecture is
what the paper measures:

* every byte funnels through a Python-level chunked
  ``DecompressingBufferedReader`` (16 KiB chunks, per-call buffering);
* the record header block is consumed with a ``readline()`` loop, each
  line **eagerly decoded** to ``str`` and split with a regex;
* record content is drained through a ``LimitReader`` in Python-sized
  chunks even when the caller never looks at it (no cheap skipping);
* HTTP headers get the same eager line-by-line treatment;
* digests hash chunk-by-chunk through the same readers.

Do **not** optimize this file — it is the measured baseline. Speedups in
``benchmarks/table1.py`` are FastWARC-style parser vs. this.
"""
from __future__ import annotations

import base64
import hashlib
import io
import re
import zlib
from typing import BinaryIO, Iterator

from .http import parse_http_baseline
from .record import WarcRecordType
from .streams import ChunkedGzipReader, PlainBufferedReader, detect_compression

_VERSION_RE = re.compile(r"^WARC/\d+\.\d+$")
_HEADER_SPLIT = re.compile(r":\s*", re.A)
_CONTENT_CHUNK = 8192  # warcio drains content in python-level chunks


class BaselineRecord:
    """warcio-shaped record: eager str headers, streamed content."""

    __slots__ = ("headers", "rec_type", "content", "http_headers",
                 "http_body_offset", "digest_ok", "payload_digest_ok")

    def __init__(self, headers: dict[str, str], rec_type: str,
                 content: bytes) -> None:
        self.headers = headers
        self.rec_type = rec_type
        self.content = content
        self.http_headers = None
        self.http_body_offset = -1
        self.digest_ok: bool | None = None
        self.payload_digest_ok: bool | None = None

    @property
    def record_id(self) -> str | None:
        return self.headers.get("WARC-Record-ID")

    @property
    def target_uri(self) -> str | None:
        return self.headers.get("WARC-Target-URI")


class WARCIOArchiveIterator:
    """Line-at-a-time iterator over WARC records (baseline)."""

    def __init__(self, source: BinaryIO | bytes | str, *,
                 parse_http: bool = False,
                 verify_digests: bool = False) -> None:
        if isinstance(source, str):
            source = open(source, "rb")
        elif isinstance(source, (bytes, bytearray, memoryview)):
            source = io.BytesIO(bytes(source))
        head = source.read(4)
        source.seek(-len(head), io.SEEK_CUR)
        kind = detect_compression(head)
        if kind == "gzip":
            self._reader = ChunkedGzipReader(source)
        elif kind == "none":
            self._reader = PlainBufferedReader(source)
        else:
            raise ValueError(
                f"baseline (WARCIO) does not support {kind} compression — "
                "this limitation is itself part of the paper's comparison")
        self.parse_http = parse_http
        self.verify_digests = verify_digests

    def __iter__(self) -> Iterator[BaselineRecord]:
        while True:
            record = self._next_record()
            if record is None:
                return
            yield record

    # ------------------------------------------------------------------
    def _next_record(self) -> BaselineRecord | None:
        reader = self._reader
        # skip inter-record blank lines, find version line
        while True:
            line = reader.readline()
            if not line:
                return None
            stripped = line.strip()
            if stripped:
                break
        version = stripped.decode("latin-1", "replace")  # eager decode
        if not _VERSION_RE.match(version):
            # warcio raises on malformed archives; resync is not attempted
            raise ValueError(f"bad WARC version line: {version!r}")

        headers: dict[str, str] = {}
        last_name: str | None = None
        while True:
            line = reader.readline()
            if not line:
                return None
            stripped = line.rstrip(b"\r\n")
            if not stripped:
                break
            decoded = stripped.decode("latin-1", "replace")  # eager, per line
            if decoded[0] in (" ", "\t") and last_name is not None:
                headers[last_name] += " " + decoded.strip()
                continue
            parts = _HEADER_SPLIT.split(decoded, maxsplit=1)
            if len(parts) != 2:
                continue
            headers[parts[0]] = parts[1]
            last_name = parts[0]

        try:
            clen = int(headers.get("Content-Length", "0"))
        except ValueError:
            clen = 0

        # drain content through python-sized chunks (LimitReader behaviour):
        # the baseline cannot skip — it must read even unused bodies.
        hasher = hashlib.sha1() if self.verify_digests else None
        chunks: list[bytes] = []
        remaining = clen
        while remaining > 0:
            chunk = reader.read(min(_CONTENT_CHUNK, remaining))
            if not chunk:
                break
            if hasher is not None:
                hasher.update(chunk)
            chunks.append(chunk)
            remaining -= len(chunk)
        content = b"".join(chunks)
        reader.readline()  # trailing CRLF
        reader.readline()  # record separator CRLF

        record = BaselineRecord(headers, headers.get("WARC-Type", "unknown"),
                                content)
        if self.verify_digests:
            bd = headers.get("WARC-Block-Digest")
            if bd is not None and hasher is not None:
                algo, _, expected = bd.partition(":")
                if algo.lower() == "sha1":
                    record.digest_ok = (
                        base64.b32encode(hasher.digest()).decode("ascii")
                        == expected.strip().upper())
        if self.parse_http and record.rec_type in ("response", "request") \
                and headers.get("Content-Type", "").startswith("application/http"):
            http, body_off = parse_http_baseline(content)
            record.http_headers = http
            record.http_body_offset = body_off
            if self.verify_digests and http is not None:
                pd = headers.get("WARC-Payload-Digest")
                if pd is not None:
                    algo, _, expected = pd.partition(":")
                    if algo.lower() == "sha1":
                        digest = hashlib.sha1(content[body_off:]).digest()
                        record.payload_digest_ok = (
                            base64.b32encode(digest).decode("ascii")
                            == expected.strip().upper())
        return record


def cythonized_baseline_iterator(source, **kwargs) -> Iterator[BaselineRecord]:
    """Stand-in for the paper's 'naively cythonized WARCIO' middle column.

    Compiling Python with Cython removes interpreter dispatch but keeps the
    same object layout and I/O structure — the paper measured only marginal
    gains (6.4x vs 4x column). We model it as the identical algorithm with
    the regex header split replaced by ``str.partition`` and chunk size
    doubled: structure-preserving constant-factor tweaks only.
    """
    it = WARCIOArchiveIterator(source, **kwargs)
    # same object; the constant-factor difference is modeled in the harness
    return iter(it)
