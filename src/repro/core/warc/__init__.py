"""WARC core: the paper's contribution (FastWARC) plus its baseline (WARCIO).

Public API:

>>> from repro.core.warc import FastWARCIterator, WarcRecordType
>>> for record in FastWARCIterator("crawl.warc.gz",
...                                record_types=WarcRecordType.response):
...     process(record.http_payload)
"""
from .record import (
    HttpHeaderMap,
    WarcHeaderMap,
    WarcRecord,
    WarcRecordType,
)
from .errors import ErrorLedger, LedgerEntry, RecordReadError
from .fastwarc import FastWARCIterator, parse_header_block, read_record_at
from .warcio_ref import BaselineRecord, WARCIOArchiveIterator
from .writer import WarcWriter, recompress, serialize_record
from .checksum import block_digest, verify_digest, verify_digests_bulk
from . import lz4, streams, xxh32

__all__ = [
    "BaselineRecord",
    "ErrorLedger",
    "FastWARCIterator",
    "HttpHeaderMap",
    "LedgerEntry",
    "RecordReadError",
    "WARCIOArchiveIterator",
    "WarcHeaderMap",
    "WarcRecord",
    "WarcRecordType",
    "WarcWriter",
    "block_digest",
    "lz4",
    "parse_header_block",
    "read_record_at",
    "recompress",
    "serialize_record",
    "streams",
    "verify_digest",
    "verify_digests_bulk",
    "xxh32",
]
