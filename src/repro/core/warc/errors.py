"""Error taxonomy for fault-tolerant archive processing.

Production crawl data is riddled with malformed records, truncated
members, and mid-job process failures (the WARC-DL and Common Crawl
longitudinal papers both call this out as the dominant operational
cost). The tolerant paths never silently drop bytes: every damaged or
skipped byte range is accounted for in an :class:`ErrorLedger` entry so
a shard job can report exactly *what* it could not parse and *where*.

Error classes (the ``error_class`` field of :class:`LedgerEntry`):

``garbage``             bytes between records that match no ``WARC/`` magic
``bad_content_length``  header's Content-Length does not land on a record
                        terminator (or is missing/non-numeric)
``truncated_tail``      EOF inside the final record / member
``bad_gzip_member``     gzip member failed to decode (header or deflate)
``bad_lz4_frame``       LZ4 frame failed to parse or decode
``bad_member``          decoded member does not contain a parseable record
``bad_zstd_stream``     zstd stream failed mid-decode (rest of shard lost)
``shard_quarantined``   a supervised worker died twice on this shard
"""
from __future__ import annotations

import struct
import threading
import zlib
from dataclasses import dataclass

__all__ = [
    "LedgerEntry",
    "ErrorLedger",
    "RecordReadError",
    "classify_member_error",
]


@dataclass(frozen=True)
class LedgerEntry:
    """One quarantined byte range (picklable: crosses process boundaries).

    ``offset`` is in the *addressing domain* of the stream that produced
    it: compressed-file offsets for gzip/LZ4 member archives (the same
    domain CDX offsets live in), decompressed offsets for uncompressed
    and zstd streams.
    """

    shard: str | None
    offset: int
    error_class: str
    bytes_skipped: int
    message: str = ""

    @property
    def end(self) -> int:
        return self.offset + self.bytes_skipped

    def covers(self, start: int, stop: int) -> bool:
        """Does this entry's range overlap ``[start, stop)``?"""
        return self.offset < stop and start < self.end


class ErrorLedger:
    """Append-only, thread-safe ledger of damaged byte ranges.

    Shared between an iterator and its readahead decoder thread (and
    merged across processes by the tolerant index build), so appends are
    lock-guarded; reads take a snapshot.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._entries: list[LedgerEntry] = []

    def record(self, shard: str | None, offset: int, error_class: str,
               bytes_skipped: int, message: str = "") -> LedgerEntry:
        entry = LedgerEntry(shard, offset, error_class, bytes_skipped, message)
        with self._lock:
            self._entries.append(entry)
        return entry

    def extend(self, entries) -> None:
        with self._lock:
            self._entries.extend(entries)

    def merge(self, other: "ErrorLedger") -> None:
        self.extend(other.entries())

    def entries(self) -> list[LedgerEntry]:
        with self._lock:
            return list(self._entries)

    def counts(self) -> dict[str, int]:
        out: dict[str, int] = {}
        for e in self.entries():
            out[e.error_class] = out.get(e.error_class, 0) + 1
        return out

    @property
    def bytes_skipped(self) -> int:
        return sum(e.bytes_skipped for e in self.entries())

    def covers(self, start: int, stop: int, shard: str | None = None) -> bool:
        """Is ``[start, stop)`` fully inside quarantined ranges of ``shard``?

        Damaged ranges from one fault are contiguous per entry, so this
        checks any-overlap entry containment (good enough for the fault
        harness, which damages record-aligned spans).
        """
        for e in self.entries():
            if shard is not None and e.shard is not None and e.shard != shard:
                continue
            if e.offset <= start and stop <= e.end:
                return True
        return False

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __iter__(self):
        return iter(self.entries())

    def __bool__(self) -> bool:
        return len(self) > 0

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return f"ErrorLedger({self.counts()}, bytes={self.bytes_skipped})"


class RecordReadError(RuntimeError):
    """A random-access record read (CDX offset -> record) failed.

    Raised by :func:`repro.core.warc.fastwarc.read_record_at` and
    :class:`repro.index.cdx.RandomAccessReader` instead of leaking bare
    ``zlib.error`` / ``struct.error`` / ``LZ4Error`` out of the decode
    internals — the serving gateway maps it to a clean per-request
    error, not a scheduler-wedging 500-equivalent.
    """

    def __init__(self, message: str, *, offset: int = -1,
                 shard: str | None = None) -> None:
        super().__init__(message)
        self.offset = offset
        self.shard = shard

    def __str__(self) -> str:
        base = super().__str__()
        where = f"offset {self.offset}"
        if self.shard is not None:
            where += f" of {self.shard}"
        return f"{base} ({where})"


def classify_member_error(exc: BaseException) -> str:
    """Map a decode exception to a ledger error class."""
    from .lz4 import LZ4Error  # local: record/errors must not import lz4 eagerly

    if isinstance(exc, zlib.error):
        return "bad_gzip_member"
    if isinstance(exc, LZ4Error):
        return "bad_lz4_frame"
    if isinstance(exc, (struct.error, IndexError)):
        return "truncated_tail"
    return "bad_member"
