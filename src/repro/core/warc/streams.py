"""Stream layer: compression-aware byte streams for WARC processing.

The paper's bottleneck (1) is *stream decompression speed*. WARCIO funnels
every read through a Python-level chunked ``DecompressingBufferedReader``;
FastWARC talks to zlib directly and adds LZ4. Both designs are implemented
here so the benchmark harness measures the real difference:

* :class:`ChunkedGzipReader` — WARCIO-faithful: fixed 16 KiB chunk loop,
  per-``read()`` Python buffering, member-boundary handling via
  ``unused_data`` re-feeding. Used only by the baseline parser.
* :class:`GZipStream` — FastWARC-style: decompresses whole gzip members in
  single C calls (``decompressobj(wbits=31)``), exposing *member
  boundaries* so the record iterator can resynchronize and so non-target
  records are skipped at member granularity.
* :class:`LZ4Stream` — frame-per-record streams over the from-scratch codec
  in :mod:`repro.core.warc.lz4`; supports frame *skipping* without
  decompression (block-header hops).
* :class:`ZstdStream` — beyond-paper codec (the real FastWARC later grew
  zstd support too); used to validate the paper's "fast codec beats gzip"
  claim with a C-speed decompressor, since our LZ4 hot loop is Python.
"""
from __future__ import annotations

import io
import zlib
from typing import BinaryIO, Iterator

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - zstandard ships in the image
    _zstd = None

from . import lz4 as _lz4

GZIP_MAGIC = b"\x1f\x8b"
ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
LZ4_MAGIC_BYTES = b"\x04\x22\x4d\x18"

_CHUNK = 16 * 1024  # WARCIO's default read chunk
_READ_BLOCK = 1 << 20  # FastWARC-style bulk read


def detect_compression(head: bytes) -> str:
    if head.startswith(GZIP_MAGIC):
        return "gzip"
    if head.startswith(LZ4_MAGIC_BYTES):
        return "lz4"
    if head.startswith(ZSTD_MAGIC):
        return "zstd"
    return "none"


# --------------------------------------------------------------------------
# Member-oriented decompressed-payload iterators (FastWARC-style fast path)
# --------------------------------------------------------------------------

class MemberStream:
    """Iterator over per-record compression members/frames.

    ``next_member()`` returns the decompressed bytes of the next member, or
    ``None`` at EOF. ``skip_member()`` advances without (fully) materializing
    where the format allows it.
    """

    def next_member(self) -> bytes | None:
        raise NotImplementedError

    def skip_member(self) -> bool:
        data = self.next_member()
        return data is not None

    def tell_compressed(self) -> int:
        raise NotImplementedError


class GZipStream(MemberStream):
    """Concatenated-gzip-member reader with C-call member decode.

    Feeds the decompressor bounded ``memoryview`` slices so the
    ``unused_data`` tail copy stays O(feed) per member instead of
    O(remaining buffer) — the latter is quadratic over a file and was the
    first profiling finding of our own hillclimb (EXPERIMENTS.md §Paper).
    """

    _FEED = 16 * 1024

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0
        self._abs = 0  # compressed offset of _buf[0]
        self._eof = False

    def _fill(self) -> bool:
        chunk = self._raw.read(_READ_BLOCK)
        if not chunk:
            self._eof = True
            return False
        if self._off:
            self._abs += self._off
            self._buf = self._buf[self._off:] + chunk
            self._off = 0
        else:
            self._buf += chunk  # bytes: rebind, never resize
        return True

    def next_member(self) -> bytes | None:
        if self._off >= len(self._buf) and not self._fill():
            return None
        d = zlib.decompressobj(31)
        parts: list[bytes] = []
        feed_size = self._FEED
        view = memoryview(self._buf)
        while True:
            if self._off >= len(self._buf):
                if not self._fill():
                    if parts:
                        raise zlib.error("truncated gzip member")
                    return None
                view = memoryview(self._buf)
            feed = view[self._off:self._off + feed_size]
            out = d.decompress(feed)
            if out:
                parts.append(out)
            if d.eof:
                self._off += len(feed) - len(d.unused_data)
                return parts[0] if len(parts) == 1 else b"".join(parts)
            self._off += len(feed)
            feed_size = _READ_BLOCK  # big member: switch to large feeds

    def tell_compressed(self) -> int:
        return self._abs + self._off


class LZ4Stream(MemberStream):
    """Frame-per-record LZ4 reader; ``skip_member`` hops block headers only."""

    def __init__(self, raw: BinaryIO, *, verify_checksums: bool = False) -> None:
        self._buf = raw.read()  # frame skipping needs random access
        self._pos = 0
        self._verify = verify_checksums

    def next_member(self) -> bytes | None:
        if self._pos >= len(self._buf):
            return None
        data, self._pos = _lz4.decompress_frame(
            self._buf, self._pos, verify_checksum=self._verify)
        return data

    def skip_member(self) -> bool:
        if self._pos >= len(self._buf):
            return False
        self._pos = _lz4.skip_frame(self._buf, self._pos)
        return True

    def peek_member_content_size(self) -> int | None:
        """Content size from the frame header, if stored (free skip decision)."""
        if self._pos >= len(self._buf):
            return None
        return _lz4.parse_frame_header(self._buf, self._pos).content_size

    def begin_member(self) -> "_LazyLZ4Member | None":
        """Start reading the next frame lazily: only the first block is
        decompressed up front (enough to sniff the WARC header block); the
        caller then either ``read_all()`` or ``skip()`` — skipping costs
        block-header hops only. This is bottleneck (3) of the paper realized
        for a compressed stream."""
        if self._pos >= len(self._buf):
            return None
        return _LazyLZ4Member(self, self._pos)

    def tell_compressed(self) -> int:
        return self._pos


class _LazyLZ4Member:
    __slots__ = ("_stream", "_start", "_info", "_first_end", "_ended", "prefix")

    def __init__(self, stream: "LZ4Stream", start: int) -> None:
        self._stream = stream
        self._start = start
        buf = stream._buf
        self._info = _lz4.parse_frame_header(buf, start)
        pos = start + self._info.header_len
        import struct as _struct
        (bsz,) = _struct.unpack_from("<I", buf, pos)
        pos += 4
        if bsz == 0:  # empty frame: EndMark immediately
            self.prefix = b""
            self._first_end = pos
            self._ended = True
            return
        self._ended = False
        raw = bool(bsz & 0x80000000)
        bsz &= 0x7FFFFFFF
        chunk = memoryview(buf)[pos:pos + bsz]
        self.prefix = bytes(chunk) if raw else _lz4.decompress_block(
            chunk, max_size=self._info.block_size)
        self._first_end = pos + bsz

    def read_all(self) -> bytes:
        """Decompress the remaining blocks and advance the stream."""
        import struct as _struct
        buf = self._stream._buf
        parts = [self.prefix]
        pos = self._first_end
        if not self._ended:
            while True:
                (bsz,) = _struct.unpack_from("<I", buf, pos)
                pos += 4
                if bsz == 0:
                    break
                raw = bool(bsz & 0x80000000)
                bsz &= 0x7FFFFFFF
                chunk = memoryview(buf)[pos:pos + bsz]
                parts.append(bytes(chunk) if raw else _lz4.decompress_block(
                    chunk, max_size=self._info.block_size))
                pos += bsz
        if self._info.content_checksum:
            pos += 4
        self._stream._pos = pos
        return b"".join(parts) if len(parts) > 1 else self.prefix

    def skip(self) -> None:
        """Advance past the frame without decompressing remaining blocks."""
        self._stream._pos = _lz4.skip_frame(self._stream._buf, self._start)


class ZstdStream:
    """Bulk zstd reader: one C-speed streaming pass across all frames.

    zstd frames do not expose their compressed length without a block walk,
    so per-member random access buys nothing over gzip; the fast parser
    instead decompresses the stream lazily (``read()``) and does in-buffer
    record splitting, which also preserves Content-Length skipping on the
    decompressed bytes. (Read-path counterpart of ``WarcWriter('zstd')``.)
    """

    def __init__(self, raw: BinaryIO) -> None:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard not available")
        self._reader = _zstd.ZstdDecompressor().stream_reader(
            raw, read_across_frames=True)

    def read(self, n: int = -1) -> bytes:
        return self._reader.read(n if n >= 0 else -1)

    def readinto(self, buf) -> int:
        """Decompress directly into ``buf`` (zero-copy arena fills)."""
        return self._reader.readinto(buf)


class ForwardWindow:
    """Seekable facade over a forward-only reader, at an offset origin.

    Wraps a streaming decompressor (e.g. :class:`ZstdStream` opened at a
    frame boundary) so :func:`repro.core.warc.read_record_at` can use it
    like a file positioned in the *decompressed* stream: position ``base``
    corresponds to the wrapped reader's byte 0, forward seeks discard,
    and a small pushback tail absorbs the parser's short look-behind
    (the 8-byte compression sniff). Backward seeks past the tail raise —
    the record parser never does that.
    """

    _KEEP = 64  # pushback capacity; the parser rewinds ≤ 8 bytes

    def __init__(self, reader, base: int = 0) -> None:
        self._r = reader
        self._pos = base
        self._origin = base
        self._pending = b""   # pushed-back bytes, next to be read
        self._tail = b""      # most recent _KEEP bytes handed out

    def read(self, n: int = -1) -> bytes:
        parts: list[bytes] = []
        if self._pending:
            take = self._pending if n < 0 else self._pending[:n]
            self._pending = self._pending[len(take):]
            parts.append(take)
        need = -1 if n < 0 else n - sum(len(p) for p in parts)
        while need != 0:
            chunk = self._r.read(_READ_BLOCK if need < 0 else need)
            if not chunk:
                break
            parts.append(chunk)
            if need > 0:
                need -= len(chunk)
        out = b"".join(parts)
        self._pos += len(out)
        self._tail = (self._tail + out)[-self._KEEP:]
        return out

    def seek(self, offset: int, whence: int = io.SEEK_SET) -> int:
        if whence == io.SEEK_CUR:
            target = self._pos + offset
        elif whence == io.SEEK_SET:
            target = offset
        else:  # SEEK_END needs the stream length, which is unknowable here
            raise ValueError(f"unsupported whence {whence}")
        if target < self._origin:
            raise ValueError(f"seek before window origin {self._origin}")
        delta = target - self._pos
        if delta < 0:
            if -delta > len(self._tail):
                raise ValueError("seek beyond the pushback tail")
            self._pending = self._tail[delta:] + self._pending
            self._tail = self._tail[:delta]
            self._pos = target
        elif delta > 0:
            while self._pos < target:
                if not self.read(min(target - self._pos, _READ_BLOCK)):
                    break  # short stream: behave like file seek past EOF
        return self._pos

    def tell(self) -> int:
        return self._pos


class UncompressedMemberStream(MemberStream):
    """Degenerate member stream: one member == the whole file.

    The fast parser does its own in-buffer record splitting for the
    uncompressed case, so this exists only for API uniformity.
    """

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._done = False
        self._pos = 0

    def next_member(self) -> bytes | None:
        if self._done:
            return None
        self._done = True
        data = self._raw.read()
        self._pos = len(data)
        return data

    def tell_compressed(self) -> int:
        return self._pos


def open_member_stream(raw: BinaryIO) -> tuple[MemberStream | None, str]:
    """Sniff compression and return the matching member stream.

    zstd returns ``(None, "zstd")`` — it has no cheap member boundaries;
    callers should wrap the source in :class:`ZstdStream` for bulk reads.
    """
    head = raw.read(8)
    if not raw.seekable():  # pragma: no cover - all our sources are seekable
        raise ValueError("non-seekable source")
    raw.seek(-len(head), io.SEEK_CUR)
    kind = detect_compression(head)
    if kind == "gzip":
        return GZipStream(raw), kind
    if kind == "lz4":
        return LZ4Stream(raw), kind
    return None, kind


# --------------------------------------------------------------------------
# WARCIO-faithful chunked decompressing reader (baseline parser only)
# --------------------------------------------------------------------------

class ChunkedGzipReader:
    """Python-chunked gzip reader modeled on WARCIO's
    ``DecompressingBufferedReader``: 16 KiB compressed chunks, incremental
    decompress on every ``read``/``readline``, member restart on EOF of a
    member. This *is* the measured baseline behaviour, do not optimize."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._decomp = zlib.decompressobj(31)
        self._buf = b""
        self._off = 0
        self._comp_tail = b""
        self._eof = False

    def _fill(self) -> None:
        while not self._eof and self._off >= len(self._buf):
            comp = self._comp_tail or self._raw.read(_CHUNK)
            self._comp_tail = b""
            if not comp:
                self._eof = True
                return
            out = self._decomp.decompress(comp)
            if self._decomp.eof:
                self._comp_tail = self._decomp.unused_data
                self._decomp = zlib.decompressobj(31)
            if out:
                self._buf = out
                self._off = 0
                return

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            parts = [self._buf[self._off:]]
            self._off = len(self._buf)
            while True:
                self._fill()
                if self._off >= len(self._buf):
                    break
                parts.append(self._buf[self._off:])
                self._off = len(self._buf)
            return b"".join(parts)
        parts = []
        need = n
        while need > 0:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


class PlainBufferedReader:
    """Uncompressed counterpart of :class:`ChunkedGzipReader` (baseline)."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0

    def _fill(self) -> None:
        if self._off >= len(self._buf):
            self._buf = self._raw.read(_CHUNK)
            self._off = 0

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            rest = self._buf[self._off:] + self._raw.read()
            self._buf = b""
            self._off = 0
            return rest
        parts = []
        need = n
        while need > 0:
            self._fill()
            if self._off >= len(self._buf):
                break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            self._fill()
            if self._off >= len(self._buf):
                break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


# --------------------------------------------------------------------------
# Zero-copy pooled parse arena (FastWARC-style buffered reader, DESIGN.md §9)
# --------------------------------------------------------------------------

class CopyStats:
    """Byte-copy / allocation ledger for the ingest hot path.

    Every Python-level copy of payload bytes (buffer joins, compaction,
    header-block slices, ``detach()``/``content`` materialization) and
    every arena allocation is counted here, so the ingest benchmark can
    *prove* — not eyeball — that the zero-copy path stopped copying.
    Decompressor output is deliberately not counted: producing those
    bytes is the work itself, not overhead.
    """

    __slots__ = ("copies", "bytes_copied", "allocs", "bytes_allocated",
                 "arena_reuses")

    def __init__(self) -> None:
        self.copies = 0
        self.bytes_copied = 0
        self.allocs = 0
        self.bytes_allocated = 0
        self.arena_reuses = 0

    def count_copy(self, nbytes: int) -> None:
        self.copies += 1
        self.bytes_copied += nbytes

    def count_alloc(self, nbytes: int) -> None:
        self.allocs += 1
        self.bytes_allocated += nbytes

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__slots__}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"CopyStats(copies={self.copies}, "
                f"bytes_copied={self.bytes_copied}, allocs={self.allocs}, "
                f"reuses={self.arena_reuses})")


_ARENA_BYTES = 1 << 20   # default arena size; grows geometrically per record
_ARENA_POOL_MAX = 4      # retired arenas kept for recycling


class RecordBuffer:
    """Pooled-arena buffered reader: the zero-copy parse surface.

    The parser addresses the stream by **absolute offset**; this class
    maps those offsets onto a reusable ``bytearray`` arena filled with
    ``readinto`` (no intermediate ``bytes`` objects where the source
    supports it). Record content is handed out as :meth:`view`
    ``memoryview`` slices — no per-record ``bytes`` slicing.

    Lifetime contract: a view pins its arena. Retired arenas go to a
    small pool and are recycled **only when no outstanding view
    references them** (checked via the arena's refcount), so borrowed
    views are never silently clobbered — consumers that drop records as
    they stream get steady-state zero allocation, consumers that hold
    records trade memory (fresh arenas) for safety. ``WarcRecord.detach``
    copies a record out and releases its pin.
    """

    def __init__(self, raw, *, arena_bytes: int = _ARENA_BYTES,
                 stats: CopyStats | None = None) -> None:
        self._raw = raw
        self._readinto = getattr(raw, "readinto", None)
        self._arena_bytes = max(arena_bytes, 4096)
        self.stats = stats if stats is not None else CopyStats()
        self._buf = bytearray(self._arena_bytes)
        self.stats.count_alloc(self._arena_bytes)
        self._pool: list[bytearray] = []
        self._start = 0   # discard watermark (buffer-relative)
        self._end = 0     # fill watermark (buffer-relative)
        self._base = 0    # absolute stream offset of _buf[0]
        self.eof = False

    # -- addressing ------------------------------------------------------
    @property
    def end_abs(self) -> int:
        """Absolute offset one past the last buffered byte."""
        return self._base + self._end

    def ensure(self, pos: int, need: int) -> bool:
        """Make ``[pos, pos + need)`` addressable; never moves ``pos``."""
        while True:
            if self._base + self._end - pos >= need:
                return True
            if self.eof:
                return False
            if self._end >= len(self._buf) or \
                    pos - self._base + need > len(self._buf):
                self._roll(pos, need)
            self._fill_tail()

    def find(self, needle: bytes, pos: int, end: int | None = None) -> int:
        """Absolute offset of ``needle`` in the buffered region, or -1."""
        rel_end = self._end if end is None else min(end - self._base,
                                                   self._end)
        i = self._buf.find(needle, max(pos - self._base, 0), rel_end)
        return -1 if i < 0 else self._base + i

    def startswith(self, needle: bytes, pos: int) -> bool:
        return self._buf.startswith(needle, pos - self._base)

    def view(self, a: int, b: int) -> memoryview:
        """Zero-copy borrow of ``[a, b)``; pins the arena (see class doc)."""
        return memoryview(self._buf)[a - self._base:b - self._base]

    def take_bytes(self, a: int, b: int) -> bytes:
        """Owning ``bytes`` copy of ``[a, b)`` (counted)."""
        out = bytes(memoryview(self._buf)[a - self._base:b - self._base])
        self.stats.count_copy(len(out))
        return out

    def discard(self, pos: int) -> None:
        """Mark everything below absolute ``pos`` consumed (reusable)."""
        rel = pos - self._base
        if rel > self._start:
            self._start = rel

    def scan_field(self, needle: bytes, a: int, b: int) -> bytes | None:
        """Line-anchored ``Name:``-field scan inside ``[a, b)``, in-arena.

        The zero-copy twin of :func:`repro.core.warc.record.scan_header_field`:
        skipped records get their type/length sniffed straight off the
        arena — no header block is ever sliced out for them. Only the
        (tiny) field value is materialized.
        """
        buf = self._buf
        rs, re_ = a - self._base, b - self._base
        i = buf.find(needle, rs, re_)
        while i > rs and buf[i - 1] != 0x0A:  # must start a line
            i = buf.find(needle, i + 1, re_)
        if i < 0:
            return None
        end = buf.find(b"\r\n", i, re_)
        if end < 0:
            end = re_
        return bytes(memoryview(buf)[i + len(needle):end]).strip()

    # -- internals -------------------------------------------------------
    def _take_arena(self, capacity: int) -> bytearray:
        """Recycle a retired arena iff nothing references it anymore."""
        import sys

        for i in range(len(self._pool)):
            cand = self._pool[i]
            # refs: pool list + `cand` local + getrefcount argument == 3;
            # any outstanding memoryview/ndarray raises the count
            if len(cand) >= capacity and sys.getrefcount(cand) <= 3:
                self.stats.arena_reuses += 1
                return self._pool.pop(i)
        cap = self._arena_bytes
        while cap < capacity:
            cap *= 2
        self.stats.count_alloc(cap)
        return bytearray(cap)

    def _roll(self, pos: int, need: int) -> None:
        """Move the live tail onto a fresh/recycled arena.

        The only copy on the whole parse path: the bytes of the record
        currently straddling the arena edge (amortized: a fraction of one
        record per arena, not per record). Growth is geometric — at most
        a doubling per roll, never ``need`` upfront: a hostile or corrupt
        ``Content-Length`` (terabyte ``need``) must not allocate anything
        the stream hasn't backed with bytes; ``ensure`` keeps rolling as
        real data arrives and surfaces EOF as a truncated record instead.
        """
        live_start = min(self._start, pos - self._base)
        live = self._end - live_start
        cap_limit = max(2 * len(self._buf), self._arena_bytes)
        new = self._take_arena(max(min(live + need, cap_limit), live + 1))
        if live:
            new[:live] = memoryview(self._buf)[live_start:self._end]
            self.stats.count_copy(live)
        old = self._buf
        self._buf = new
        self._base += live_start
        self._end = live
        self._start = 0
        if len(self._pool) >= _ARENA_POOL_MAX:
            self._pool.pop(0)  # dropped; freed once its views die
        self._pool.append(old)

    def _fill_tail(self) -> None:
        space = len(self._buf) - self._end
        if space <= 0:
            return
        if self._readinto is not None:
            n = self._readinto(memoryview(self._buf)[self._end:])
            if not n:
                self.eof = True
            else:
                self._end += n
            return
        chunk = self._raw.read(space)
        if not chunk:
            self.eof = True
            return
        self._buf[self._end:self._end + len(chunk)] = chunk
        self.stats.count_copy(len(chunk))  # copy-in: source lacks readinto
        self._end += len(chunk)


def iter_members(path_or_buf, kind: str | None = None) -> Iterator[bytes]:
    """Convenience: yield decompressed members of a WARC file."""
    raw = open(path_or_buf, "rb") if isinstance(path_or_buf, str) else io.BytesIO(path_or_buf)
    try:
        stream, detected = open_member_stream(raw)
        if stream is None:
            data = ZstdStream(raw).read() if detected == "zstd" else raw.read()
            if data:
                yield data
            return
        while True:
            member = stream.next_member()
            if member is None:
                return
            yield member
    finally:
        if isinstance(path_or_buf, str):
            raw.close()
