"""Stream layer: compression-aware byte streams for WARC processing.

The paper's bottleneck (1) is *stream decompression speed*. WARCIO funnels
every read through a Python-level chunked ``DecompressingBufferedReader``;
FastWARC talks to zlib directly and adds LZ4. Both designs are implemented
here so the benchmark harness measures the real difference:

* :class:`ChunkedGzipReader` — WARCIO-faithful: fixed 16 KiB chunk loop,
  per-``read()`` Python buffering, member-boundary handling via
  ``unused_data`` re-feeding. Used only by the baseline parser.
* :class:`GZipStream` — FastWARC-style: decompresses whole gzip members in
  single C calls (``decompressobj(wbits=31)``), exposing *member
  boundaries* so the record iterator can resynchronize and so non-target
  records are skipped at member granularity.
* :class:`LZ4Stream` — frame-per-record streams over the from-scratch codec
  in :mod:`repro.core.warc.lz4`; supports frame *skipping* without
  decompression (block-header hops).
* :class:`ZstdStream` — beyond-paper codec (the real FastWARC later grew
  zstd support too); used to validate the paper's "fast codec beats gzip"
  claim with a C-speed decompressor, since our LZ4 hot loop is Python.
"""
from __future__ import annotations

import io
import zlib
from typing import BinaryIO, Iterator

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - zstandard ships in the image
    _zstd = None

from . import lz4 as _lz4

GZIP_MAGIC = b"\x1f\x8b"
ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
LZ4_MAGIC_BYTES = b"\x04\x22\x4d\x18"

_CHUNK = 16 * 1024  # WARCIO's default read chunk
_READ_BLOCK = 1 << 20  # FastWARC-style bulk read


def detect_compression(head: bytes) -> str:
    if head.startswith(GZIP_MAGIC):
        return "gzip"
    if head.startswith(LZ4_MAGIC_BYTES):
        return "lz4"
    if head.startswith(ZSTD_MAGIC):
        return "zstd"
    return "none"


# --------------------------------------------------------------------------
# Member-oriented decompressed-payload iterators (FastWARC-style fast path)
# --------------------------------------------------------------------------

class MemberStream:
    """Iterator over per-record compression members/frames.

    ``next_member()`` returns the decompressed bytes of the next member, or
    ``None`` at EOF. ``skip_member()`` advances without (fully) materializing
    where the format allows it.
    """

    def next_member(self) -> bytes | None:
        raise NotImplementedError

    def skip_member(self) -> bool:
        data = self.next_member()
        return data is not None

    def tell_compressed(self) -> int:
        raise NotImplementedError


class GZipStream(MemberStream):
    """Concatenated-gzip-member reader with C-call member decode.

    Feeds the decompressor bounded ``memoryview`` slices so the
    ``unused_data`` tail copy stays O(feed) per member instead of
    O(remaining buffer) — the latter is quadratic over a file and was the
    first profiling finding of our own hillclimb (EXPERIMENTS.md §Paper).
    """

    _FEED = 16 * 1024

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0
        self._abs = 0  # compressed offset of _buf[0]
        self._eof = False

    def _fill(self) -> bool:
        chunk = self._raw.read(_READ_BLOCK)
        if not chunk:
            self._eof = True
            return False
        if self._off:
            self._abs += self._off
            self._buf = self._buf[self._off:] + chunk
            self._off = 0
        else:
            self._buf += chunk  # bytes: rebind, never resize
        return True

    def next_member(self) -> bytes | None:
        if self._off >= len(self._buf) and not self._fill():
            return None
        d = zlib.decompressobj(31)
        parts: list[bytes] = []
        feed_size = self._FEED
        view = memoryview(self._buf)
        while True:
            if self._off >= len(self._buf):
                if not self._fill():
                    if parts:
                        raise zlib.error("truncated gzip member")
                    return None
                view = memoryview(self._buf)
            feed = view[self._off:self._off + feed_size]
            out = d.decompress(feed)
            if out:
                parts.append(out)
            if d.eof:
                self._off += len(feed) - len(d.unused_data)
                return parts[0] if len(parts) == 1 else b"".join(parts)
            self._off += len(feed)
            feed_size = _READ_BLOCK  # big member: switch to large feeds

    def tell_compressed(self) -> int:
        return self._abs + self._off


class LZ4Stream(MemberStream):
    """Frame-per-record LZ4 reader; ``skip_member`` hops block headers only."""

    def __init__(self, raw: BinaryIO, *, verify_checksums: bool = False) -> None:
        self._buf = raw.read()  # frame skipping needs random access
        self._pos = 0
        self._verify = verify_checksums

    def next_member(self) -> bytes | None:
        if self._pos >= len(self._buf):
            return None
        data, self._pos = _lz4.decompress_frame(
            self._buf, self._pos, verify_checksum=self._verify)
        return data

    def skip_member(self) -> bool:
        if self._pos >= len(self._buf):
            return False
        self._pos = _lz4.skip_frame(self._buf, self._pos)
        return True

    def peek_member_content_size(self) -> int | None:
        """Content size from the frame header, if stored (free skip decision)."""
        if self._pos >= len(self._buf):
            return None
        return _lz4.parse_frame_header(self._buf, self._pos).content_size

    def begin_member(self) -> "_LazyLZ4Member | None":
        """Start reading the next frame lazily: only the first block is
        decompressed up front (enough to sniff the WARC header block); the
        caller then either ``read_all()`` or ``skip()`` — skipping costs
        block-header hops only. This is bottleneck (3) of the paper realized
        for a compressed stream."""
        if self._pos >= len(self._buf):
            return None
        return _LazyLZ4Member(self, self._pos)

    def tell_compressed(self) -> int:
        return self._pos


class _LazyLZ4Member:
    __slots__ = ("_stream", "_start", "_info", "_first_end", "_ended", "prefix")

    def __init__(self, stream: "LZ4Stream", start: int) -> None:
        self._stream = stream
        self._start = start
        buf = stream._buf
        self._info = _lz4.parse_frame_header(buf, start)
        pos = start + self._info.header_len
        import struct as _struct
        (bsz,) = _struct.unpack_from("<I", buf, pos)
        pos += 4
        if bsz == 0:  # empty frame: EndMark immediately
            self.prefix = b""
            self._first_end = pos
            self._ended = True
            return
        self._ended = False
        raw = bool(bsz & 0x80000000)
        bsz &= 0x7FFFFFFF
        chunk = memoryview(buf)[pos:pos + bsz]
        self.prefix = bytes(chunk) if raw else _lz4.decompress_block(
            chunk, max_size=self._info.block_size)
        self._first_end = pos + bsz

    def read_all(self) -> bytes:
        """Decompress the remaining blocks and advance the stream."""
        import struct as _struct
        buf = self._stream._buf
        parts = [self.prefix]
        pos = self._first_end
        if not self._ended:
            while True:
                (bsz,) = _struct.unpack_from("<I", buf, pos)
                pos += 4
                if bsz == 0:
                    break
                raw = bool(bsz & 0x80000000)
                bsz &= 0x7FFFFFFF
                chunk = memoryview(buf)[pos:pos + bsz]
                parts.append(bytes(chunk) if raw else _lz4.decompress_block(
                    chunk, max_size=self._info.block_size))
                pos += bsz
        if self._info.content_checksum:
            pos += 4
        self._stream._pos = pos
        return b"".join(parts) if len(parts) > 1 else self.prefix

    def skip(self) -> None:
        """Advance past the frame without decompressing remaining blocks."""
        self._stream._pos = _lz4.skip_frame(self._stream._buf, self._start)


class ZstdStream:
    """Bulk zstd reader: one C-speed streaming pass across all frames.

    zstd frames do not expose their compressed length without a block walk,
    so per-member random access buys nothing over gzip; the fast parser
    instead decompresses the stream lazily (``read()``) and does in-buffer
    record splitting, which also preserves Content-Length skipping on the
    decompressed bytes. (Read-path counterpart of ``WarcWriter('zstd')``.)
    """

    def __init__(self, raw: BinaryIO) -> None:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard not available")
        self._reader = _zstd.ZstdDecompressor().stream_reader(
            raw, read_across_frames=True)

    def read(self, n: int = -1) -> bytes:
        return self._reader.read(n if n >= 0 else -1)


class ForwardWindow:
    """Seekable facade over a forward-only reader, at an offset origin.

    Wraps a streaming decompressor (e.g. :class:`ZstdStream` opened at a
    frame boundary) so :func:`repro.core.warc.read_record_at` can use it
    like a file positioned in the *decompressed* stream: position ``base``
    corresponds to the wrapped reader's byte 0, forward seeks discard,
    and a small pushback tail absorbs the parser's short look-behind
    (the 8-byte compression sniff). Backward seeks past the tail raise —
    the record parser never does that.
    """

    _KEEP = 64  # pushback capacity; the parser rewinds ≤ 8 bytes

    def __init__(self, reader, base: int = 0) -> None:
        self._r = reader
        self._pos = base
        self._origin = base
        self._pending = b""   # pushed-back bytes, next to be read
        self._tail = b""      # most recent _KEEP bytes handed out

    def read(self, n: int = -1) -> bytes:
        parts: list[bytes] = []
        if self._pending:
            take = self._pending if n < 0 else self._pending[:n]
            self._pending = self._pending[len(take):]
            parts.append(take)
        need = -1 if n < 0 else n - sum(len(p) for p in parts)
        while need != 0:
            chunk = self._r.read(_READ_BLOCK if need < 0 else need)
            if not chunk:
                break
            parts.append(chunk)
            if need > 0:
                need -= len(chunk)
        out = b"".join(parts)
        self._pos += len(out)
        self._tail = (self._tail + out)[-self._KEEP:]
        return out

    def seek(self, offset: int, whence: int = io.SEEK_SET) -> int:
        if whence == io.SEEK_CUR:
            target = self._pos + offset
        elif whence == io.SEEK_SET:
            target = offset
        else:  # SEEK_END needs the stream length, which is unknowable here
            raise ValueError(f"unsupported whence {whence}")
        if target < self._origin:
            raise ValueError(f"seek before window origin {self._origin}")
        delta = target - self._pos
        if delta < 0:
            if -delta > len(self._tail):
                raise ValueError("seek beyond the pushback tail")
            self._pending = self._tail[delta:] + self._pending
            self._tail = self._tail[:delta]
            self._pos = target
        elif delta > 0:
            while self._pos < target:
                if not self.read(min(target - self._pos, _READ_BLOCK)):
                    break  # short stream: behave like file seek past EOF
        return self._pos

    def tell(self) -> int:
        return self._pos


class UncompressedMemberStream(MemberStream):
    """Degenerate member stream: one member == the whole file.

    The fast parser does its own in-buffer record splitting for the
    uncompressed case, so this exists only for API uniformity.
    """

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._done = False
        self._pos = 0

    def next_member(self) -> bytes | None:
        if self._done:
            return None
        self._done = True
        data = self._raw.read()
        self._pos = len(data)
        return data

    def tell_compressed(self) -> int:
        return self._pos


def open_member_stream(raw: BinaryIO) -> tuple[MemberStream | None, str]:
    """Sniff compression and return the matching member stream.

    zstd returns ``(None, "zstd")`` — it has no cheap member boundaries;
    callers should wrap the source in :class:`ZstdStream` for bulk reads.
    """
    head = raw.read(8)
    if not raw.seekable():  # pragma: no cover - all our sources are seekable
        raise ValueError("non-seekable source")
    raw.seek(-len(head), io.SEEK_CUR)
    kind = detect_compression(head)
    if kind == "gzip":
        return GZipStream(raw), kind
    if kind == "lz4":
        return LZ4Stream(raw), kind
    return None, kind


# --------------------------------------------------------------------------
# WARCIO-faithful chunked decompressing reader (baseline parser only)
# --------------------------------------------------------------------------

class ChunkedGzipReader:
    """Python-chunked gzip reader modeled on WARCIO's
    ``DecompressingBufferedReader``: 16 KiB compressed chunks, incremental
    decompress on every ``read``/``readline``, member restart on EOF of a
    member. This *is* the measured baseline behaviour, do not optimize."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._decomp = zlib.decompressobj(31)
        self._buf = b""
        self._off = 0
        self._comp_tail = b""
        self._eof = False

    def _fill(self) -> None:
        while not self._eof and self._off >= len(self._buf):
            comp = self._comp_tail or self._raw.read(_CHUNK)
            self._comp_tail = b""
            if not comp:
                self._eof = True
                return
            out = self._decomp.decompress(comp)
            if self._decomp.eof:
                self._comp_tail = self._decomp.unused_data
                self._decomp = zlib.decompressobj(31)
            if out:
                self._buf = out
                self._off = 0
                return

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            parts = [self._buf[self._off:]]
            self._off = len(self._buf)
            while True:
                self._fill()
                if self._off >= len(self._buf):
                    break
                parts.append(self._buf[self._off:])
                self._off = len(self._buf)
            return b"".join(parts)
        parts = []
        need = n
        while need > 0:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


class PlainBufferedReader:
    """Uncompressed counterpart of :class:`ChunkedGzipReader` (baseline)."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0

    def _fill(self) -> None:
        if self._off >= len(self._buf):
            self._buf = self._raw.read(_CHUNK)
            self._off = 0

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            rest = self._buf[self._off:] + self._raw.read()
            self._buf = b""
            self._off = 0
            return rest
        parts = []
        need = n
        while need > 0:
            self._fill()
            if self._off >= len(self._buf):
                break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            self._fill()
            if self._off >= len(self._buf):
                break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


def iter_members(path_or_buf, kind: str | None = None) -> Iterator[bytes]:
    """Convenience: yield decompressed members of a WARC file."""
    raw = open(path_or_buf, "rb") if isinstance(path_or_buf, str) else io.BytesIO(path_or_buf)
    try:
        stream, detected = open_member_stream(raw)
        if stream is None:
            data = ZstdStream(raw).read() if detected == "zstd" else raw.read()
            if data:
                yield data
            return
        while True:
            member = stream.next_member()
            if member is None:
                return
            yield member
    finally:
        if isinstance(path_or_buf, str):
            raw.close()
