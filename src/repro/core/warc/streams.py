"""Stream layer: compression-aware byte streams for WARC processing.

The paper's bottleneck (1) is *stream decompression speed*. WARCIO funnels
every read through a Python-level chunked ``DecompressingBufferedReader``;
FastWARC talks to zlib directly and adds LZ4. Both designs are implemented
here so the benchmark harness measures the real difference:

* :class:`ChunkedGzipReader` — WARCIO-faithful: fixed 16 KiB chunk loop,
  per-``read()`` Python buffering, member-boundary handling via
  ``unused_data`` re-feeding. Used only by the baseline parser.
* :class:`GZipStream` — FastWARC-style: decompresses whole gzip members in
  single C calls (``decompressobj(wbits=31)``), exposing *member
  boundaries* so the record iterator can resynchronize and so non-target
  records are skipped at member granularity.
* :class:`LZ4Stream` — frame-per-record streams over the from-scratch codec
  in :mod:`repro.core.warc.lz4`; supports frame *skipping* without
  decompression (block-header hops).
* :class:`ZstdStream` — beyond-paper codec (the real FastWARC later grew
  zstd support too); used to validate the paper's "fast codec beats gzip"
  claim with a C-speed decompressor, since our LZ4 hot loop is Python.

Decode-into-arena layer (ISSUE 5, DESIGN.md §9): every member stream
additionally exposes ``next_member_into(slot)`` — the member's
decompressed bytes are *appended* to a pooled :class:`MemberArena`
``bytearray`` slot instead of materializing per-record ``bytes`` — and
:class:`ReadaheadDecoder` runs that decode on its own thread, packing
members into slots and posting them through a bounded ring so member
inflate overlaps record parsing. (zstd needs no member API: it has no
cheap member boundaries, so the zstd path already streams through
``ZstdStream.readinto`` into the :class:`RecordBuffer` arena.)
"""
from __future__ import annotations

import io
import os
import pickle
import queue
import select
import struct
import sys
import threading
import time
import zlib
from typing import BinaryIO, Iterator

try:
    import zstandard as _zstd
except ImportError:  # pragma: no cover - zstandard ships in the image
    _zstd = None

try:
    from multiprocessing import shared_memory as _shm_mod
except ImportError:  # pragma: no cover - py>=3.8 everywhere we run
    _shm_mod = None

from repro.obs import trace
from repro.obs.registry import ObsSnapshot
from repro.obs.shmstats import (STATS_SLOT_BYTES, StatsSlotReader,
                                StatsSlotWriter)

from . import lz4 as _lz4
from .record import scan_header_field_in

GZIP_MAGIC = b"\x1f\x8b"
GZIP_MEMBER_MAGIC = b"\x1f\x8b\x08"  # magic + CM=deflate: the resync needle
ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"
LZ4_MAGIC_BYTES = b"\x04\x22\x4d\x18"

_CHUNK = 16 * 1024  # WARCIO's default read chunk
_READ_BLOCK = 1 << 20  # FastWARC-style bulk read
_DECODE_CHUNK = 256 * 1024  # max zlib output temporary on the into-path


def detect_compression(head: bytes) -> str:
    if head.startswith(GZIP_MAGIC):
        return "gzip"
    if head.startswith(LZ4_MAGIC_BYTES):
        return "lz4"
    if head.startswith(ZSTD_MAGIC):
        return "zstd"
    return "none"


# --------------------------------------------------------------------------
# Member-oriented decompressed-payload iterators (FastWARC-style fast path)
# --------------------------------------------------------------------------

class MemberStream:
    """Iterator over per-record compression members/frames.

    ``next_member()`` returns the decompressed bytes of the next member, or
    ``None`` at EOF. ``skip_member()`` advances without (fully) materializing
    where the format allows it. ``next_member_into()`` is the streaming
    decode-into API (ISSUE 5): the member's decompressed bytes are
    *appended* to a caller-provided ``bytearray`` (an arena slot), so no
    member-sized ``bytes`` object is ever allocated — consecutive members
    pack back-to-back in one slot.
    """

    def next_member(self) -> bytes | None:
        raise NotImplementedError

    def next_member_into(self, out: bytearray,
                         stats: "CopyStats | None" = None) -> int | None:
        """Append the next member's decompressed bytes to ``out``; returns
        the byte count, or ``None`` at EOF.

        Base implementation materializes via :meth:`next_member` and
        copies (counted); subclasses override with true decode-into.
        """
        data = self.next_member()
        if data is None:
            return None
        out += data
        if stats is not None:
            stats.count_copy(len(data))
        return len(data)

    def skip_member(self) -> bool:
        data = self.next_member()
        return data is not None

    def tell_compressed(self) -> int:
        raise NotImplementedError


class GZipStream(MemberStream):
    """Concatenated-gzip-member reader with C-call member decode.

    Feeds the decompressor bounded ``memoryview`` slices so the
    ``unused_data`` tail copy stays O(feed) per member instead of
    O(remaining buffer) — the latter is quadratic over a file and was the
    first profiling finding of our own hillclimb (EXPERIMENTS.md §Paper).

    Member headers are parsed by hand and the deflate stream inflated
    raw (``wbits=-15``) — the real FastWARC's design: per-member CRC32
    verification is **opt-in** (``verify_checksums``, default off like
    :class:`LZ4Stream`'s frame checksums; end-to-end integrity belongs
    to ``verify_digests``). Skipping the redundant CRC saves ~16 % of
    member decode time at Common-Crawl-ish member sizes. The PR 4-era
    legacy parse path always verified (zlib did it internally), so the
    ``zero_copy=False`` iterator keeps ``verify_checksums=True``.
    """

    # first feed per member: covers p99 of Common-Crawl-ish compressed
    # members in one C call while keeping the per-member unused_data
    # tail copy ~half the 16 KiB it used to be (measured ~0.7 µs/member)
    _FEED = 8 * 1024

    def __init__(self, raw: BinaryIO, *,
                 verify_checksums: bool = False) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0
        self._abs = 0  # compressed offset of _buf[0]
        self._eof = False
        self._verify = verify_checksums

    def _fill(self) -> bool:
        chunk = self._raw.read(_READ_BLOCK)
        if not chunk:
            self._eof = True
            return False
        if self._off:
            self._abs += self._off
            self._buf = self._buf[self._off:] + chunk
            self._off = 0
        else:
            self._buf += chunk  # bytes: rebind, never resize
        return True

    def _ensure(self, need: int) -> bool:
        """At least ``need`` bytes buffered past the cursor."""
        while len(self._buf) - self._off < need:
            if not self._fill():
                return False
        return True

    def _skip_member_header(self) -> bool | None:
        """Advance the cursor past one gzip member header.

        ``None`` at clean EOF (cursor on end-of-stream); raises
        ``zlib.error`` on malformed or truncated headers. Handles the
        full RFC 1952 layout: FEXTRA, FNAME, FCOMMENT, FHCRC.
        """
        if not self._ensure(1):
            return None
        if not self._ensure(10):
            raise zlib.error("truncated gzip member header")
        buf, off = self._buf, self._off
        if buf[off] != 0x1F or buf[off + 1] != 0x8B:
            raise zlib.error("bad gzip member magic")
        if buf[off + 2] != 8:
            raise zlib.error("unsupported gzip compression method")
        flg = buf[off + 3]
        self._off = off + 10
        if flg & 0x04:  # FEXTRA: 2-byte little-endian length + payload
            if not self._ensure(2):
                raise zlib.error("truncated gzip member header")
            buf = self._buf
            xlen = buf[self._off] | (buf[self._off + 1] << 8)
            if not self._ensure(2 + xlen):
                raise zlib.error("truncated gzip member header")
            self._off += 2 + xlen
        for bit in (0x08, 0x10):  # FNAME / FCOMMENT: zero-terminated
            if flg & bit:
                while True:
                    i = self._buf.find(b"\x00", self._off)
                    if i >= 0:
                        self._off = i + 1
                        break
                    self._off = len(self._buf)
                    if not self._fill():
                        raise zlib.error("truncated gzip member header")
        if flg & 0x02:  # FHCRC
            if not self._ensure(2):
                raise zlib.error("truncated gzip member header")
            self._off += 2
        return True

    def _decode_member_body(self, sink_append) -> int:
        """Inflate one member's raw-deflate body + consume the trailer.

        ``sink_append`` receives ``_DECODE_CHUNK``-bounded output chunks
        (a list's ``append`` for the bytes API, a slot's ``extend`` for
        decode-into). Returns the decompressed byte count.
        """
        d = zlib.decompressobj(-15)
        crc = 0
        written = 0
        feed_size = self._FEED
        while True:
            if self._off >= len(self._buf) and not self._fill():
                raise zlib.error("truncated gzip member")
            view = memoryview(self._buf)
            feed = view[self._off:self._off + feed_size]
            chunk = d.decompress(feed, _DECODE_CHUNK)
            while True:
                if chunk:
                    sink_append(chunk)
                    written += len(chunk)
                    if self._verify:
                        crc = zlib.crc32(chunk, crc)
                if d.eof or not d.unconsumed_tail:
                    break
                chunk = d.decompress(d.unconsumed_tail, _DECODE_CHUNK)
            if d.eof:
                self._off += len(feed) - len(d.unused_data)
                break
            self._off += len(feed)
            feed_size = _READ_BLOCK  # big member: switch to large feeds
        if not self._ensure(8):  # trailer: CRC32 + ISIZE (mod 2^32)
            raise zlib.error("truncated gzip member")
        if self._verify:
            buf, off = self._buf, self._off
            stored_crc = int.from_bytes(buf[off:off + 4], "little")
            stored_isize = int.from_bytes(buf[off + 4:off + 8], "little")
            if stored_crc != crc or stored_isize != written & 0xFFFFFFFF:
                raise zlib.error("gzip member checksum mismatch")
        self._off += 8
        return written

    def next_member(self) -> bytes | None:
        if self._skip_member_header() is None:
            return None
        parts: list[bytes] = []
        self._decode_member_body(parts.append)
        return parts[0] if len(parts) == 1 else b"".join(parts)

    def next_member_into(self, out: bytearray,
                         stats: "CopyStats | None" = None) -> int | None:
        """Decode the next gzip member by appending it to ``out``.

        zlib exposes no decompress-into API, so "into" here means
        ``max_length``-bounded chunks appended straight onto the
        caller's arena slot: the member-sized join and ``bytes`` object
        of :meth:`next_member` are gone, temporaries are capped at
        ``_DECODE_CHUNK``. Appended bytes are tallied in the ledger's
        ``decode_into_arena`` counter.
        """
        if self._skip_member_header() is None:
            return None
        written = self._decode_member_body(out.extend)
        if stats is not None:
            stats.count_decode_into(written)
        return written

    def tell_compressed(self) -> int:
        return self._abs + self._off

    def resync(self, start_abs: int) -> int | None:
        """Seek forward from a damaged member to the next plausible one.

        Scans for the next gzip member magic (``1f 8b 08``) strictly
        after ``start_abs``, leaves the cursor on it, and returns the
        number of bytes skipped from ``start_abs``; ``None`` when EOF
        arrives first (cursor parked at end-of-stream). Bytes before the
        current buffer window are gone (already compacted), so a decode
        error detected deep inside a member can at worst resync to a
        *later* member — the skipped range is still accounted exactly.
        """
        pos = max(start_abs + 1 - self._abs, 0)
        while True:
            i = self._buf.find(GZIP_MEMBER_MAGIC, pos)
            if i >= 0:
                self._off = i
                return self._abs + i - start_abs
            # keep a straddle tail shorter than the needle, read more
            keep = max(len(self._buf) - (len(GZIP_MEMBER_MAGIC) - 1), pos, 0)
            self._off = min(keep, len(self._buf))
            if not self._fill():
                self._off = len(self._buf)
                return None
            pos = 0  # _fill compacted the buffer down to the kept tail


class LZ4Stream(MemberStream):
    """Frame-per-record LZ4 reader; ``skip_member`` hops block headers only."""

    def __init__(self, raw: BinaryIO, *, verify_checksums: bool = False) -> None:
        self._buf = raw.read()  # frame skipping needs random access
        self._pos = 0
        self._verify = verify_checksums

    def next_member(self) -> bytes | None:
        if self._pos >= len(self._buf):
            return None
        data, self._pos = _lz4.decompress_frame(
            self._buf, self._pos, verify_checksum=self._verify)
        return data

    def next_member_into(self, out: bytearray,
                         stats: "CopyStats | None" = None) -> int | None:
        """Decode the next frame by appending it to ``out`` — true
        decode-into: blocks land straight in the caller's arena slot
        (:func:`repro.core.warc.lz4.decompress_frame_into`), nothing
        member- or block-sized is materialized or joined."""
        if self._pos >= len(self._buf):
            return None
        n, self._pos = _lz4.decompress_frame_into(
            self._buf, self._pos, out, verify_checksum=self._verify)
        if stats is not None:
            stats.count_decode_into(n)
        return n

    def skip_member(self) -> bool:
        if self._pos >= len(self._buf):
            return False
        self._pos = _lz4.skip_frame(self._buf, self._pos)
        return True

    def peek_member_content_size(self) -> int | None:
        """Content size from the frame header, if stored (free skip decision)."""
        if self._pos >= len(self._buf):
            return None
        return _lz4.parse_frame_header(self._buf, self._pos).content_size

    def begin_member(self) -> "_LazyLZ4Member | None":
        """Start reading the next frame lazily: only the first block is
        decompressed up front (enough to sniff the WARC header block); the
        caller then either ``read_all()`` or ``skip()`` — skipping costs
        block-header hops only. This is bottleneck (3) of the paper realized
        for a compressed stream."""
        if self._pos >= len(self._buf):
            return None
        return _LazyLZ4Member(self, self._pos)

    def begin_member_into(self, out: bytearray) -> "_LazyLZ4MemberInto | None":
        """Decode-into twin of :meth:`begin_member`: the first block is
        appended to the caller's arena slot for the type sniff; the
        caller then either ``finish()``es the member in place or
        ``skip()``s — rolling the prefix back off the slot and hopping
        the remaining block headers without decompression."""
        if self._pos >= len(self._buf):
            return None
        return _LazyLZ4MemberInto(self, self._pos, out)

    def tell_compressed(self) -> int:
        return self._pos

    def resync(self, start_abs: int) -> int | None:
        """Seek forward to the next *valid* frame header after ``start_abs``.

        Candidate magics are validated with :func:`lz4.parse_frame_header`
        (version bits + block-size code + header checksum), so false
        positives inside damaged compressed data are skipped over.
        Returns bytes skipped from ``start_abs``; ``None`` at EOF.
        """
        i = self._buf.find(LZ4_MAGIC_BYTES, start_abs + 1)
        while i >= 0:
            try:
                _lz4.parse_frame_header(self._buf, i)
            except _lz4.LZ4Error:
                i = self._buf.find(LZ4_MAGIC_BYTES, i + 1)
                continue
            self._pos = i
            return i - start_abs
        self._pos = len(self._buf)
        return None


class _LazyLZ4Member:
    __slots__ = ("_stream", "_start", "_info", "_first_end", "_ended", "prefix")

    def __init__(self, stream: "LZ4Stream", start: int) -> None:
        self._stream = stream
        self._start = start
        buf = stream._buf
        self._info = _lz4.parse_frame_header(buf, start)
        pos = start + self._info.header_len
        import struct as _struct
        (bsz,) = _struct.unpack_from("<I", buf, pos)
        pos += 4
        if bsz == 0:  # empty frame: EndMark immediately
            self.prefix = b""
            self._first_end = pos
            self._ended = True
            return
        self._ended = False
        raw = bool(bsz & 0x80000000)
        bsz &= 0x7FFFFFFF
        chunk = memoryview(buf)[pos:pos + bsz]
        self.prefix = bytes(chunk) if raw else _lz4.decompress_block(
            chunk, max_size=self._info.block_size)
        self._first_end = pos + bsz

    def read_all(self) -> bytes:
        """Decompress the remaining blocks and advance the stream."""
        import struct as _struct
        buf = self._stream._buf
        parts = [self.prefix]
        pos = self._first_end
        if not self._ended:
            while True:
                (bsz,) = _struct.unpack_from("<I", buf, pos)
                pos += 4
                if bsz == 0:
                    break
                raw = bool(bsz & 0x80000000)
                bsz &= 0x7FFFFFFF
                chunk = memoryview(buf)[pos:pos + bsz]
                parts.append(bytes(chunk) if raw else _lz4.decompress_block(
                    chunk, max_size=self._info.block_size))
                pos += bsz
        if self._info.content_checksum:
            pos += 4
        self._stream._pos = pos
        return b"".join(parts) if len(parts) > 1 else self.prefix

    def skip(self) -> None:
        """Advance past the frame without decompressing remaining blocks."""
        self._stream._pos = _lz4.skip_frame(self._stream._buf, self._start)


class _LazyLZ4MemberInto:
    """Into-arena twin of :class:`_LazyLZ4Member`.

    The first block is *appended* to the caller's slot (enough to sniff
    the WARC header); ``finish()`` appends the remaining blocks in
    place, ``skip()`` rolls the appended prefix back off the slot and
    hops the rest of the frame without decompressing. ``prefix_len``
    bytes starting at the slot length observed at construction hold the
    sniffable prefix.
    """

    __slots__ = ("_stream", "_start", "_info", "_pos", "_out", "_base",
                 "prefix_len", "_ended")

    def __init__(self, stream: "LZ4Stream", start: int,
                 out: bytearray) -> None:
        self._stream = stream
        self._start = start
        self._out = out
        self._base = len(out)
        buf = stream._buf
        self._info = _lz4.parse_frame_header(buf, start)
        n, pos, ended = _lz4._decode_blocks_into(
            memoryview(buf), start + self._info.header_len, out,
            self._info, max_blocks=1)
        self.prefix_len = n
        self._pos = pos
        self._ended = ended

    def finish(self, stats: "CopyStats | None" = None) -> int:
        """Append the remaining blocks and advance the stream past the
        frame; returns the member's total byte count."""
        n = self.prefix_len
        pos = self._pos
        if not self._ended:
            buf = self._stream._buf
            more, pos, _ = _lz4._decode_blocks_into(
                memoryview(buf), pos, self._out, self._info)
            n += more
        if self._info.content_checksum:
            pos += 4
        self._stream._pos = pos
        if stats is not None:
            stats.count_decode_into(n)
        return n

    def skip(self) -> None:
        """Roll the appended prefix back and hop past the frame without
        decompressing the remaining blocks."""
        del self._out[self._base:]
        self._stream._pos = _lz4.skip_frame(self._stream._buf, self._start)


class ZstdStream:
    """Bulk zstd reader: one C-speed streaming pass across all frames.

    zstd frames do not expose their compressed length without a block walk,
    so per-member random access buys nothing over gzip; the fast parser
    instead decompresses the stream lazily (``read()``) and does in-buffer
    record splitting, which also preserves Content-Length skipping on the
    decompressed bytes. (Read-path counterpart of ``WarcWriter('zstd')``.)
    """

    def __init__(self, raw: BinaryIO) -> None:
        if _zstd is None:  # pragma: no cover
            raise RuntimeError("zstandard not available")
        self._reader = _zstd.ZstdDecompressor().stream_reader(
            raw, read_across_frames=True)

    def read(self, n: int = -1) -> bytes:
        return self._reader.read(n if n >= 0 else -1)

    def readinto(self, buf) -> int:
        """Decompress directly into ``buf`` (zero-copy arena fills)."""
        return self._reader.readinto(buf)


class ForwardWindow:
    """Seekable facade over a forward-only reader, at an offset origin.

    Wraps a streaming decompressor (e.g. :class:`ZstdStream` opened at a
    frame boundary) so :func:`repro.core.warc.read_record_at` can use it
    like a file positioned in the *decompressed* stream: position ``base``
    corresponds to the wrapped reader's byte 0, forward seeks discard,
    and a small pushback tail absorbs the parser's short look-behind
    (the 8-byte compression sniff). Backward seeks past the tail raise —
    the record parser never does that.
    """

    _KEEP = 64  # pushback capacity; the parser rewinds ≤ 8 bytes

    def __init__(self, reader, base: int = 0) -> None:
        self._r = reader
        self._pos = base
        self._origin = base
        self._pending = b""   # pushed-back bytes, next to be read
        self._tail = b""      # most recent _KEEP bytes handed out

    def read(self, n: int = -1) -> bytes:
        parts: list[bytes] = []
        if self._pending:
            take = self._pending if n < 0 else self._pending[:n]
            self._pending = self._pending[len(take):]
            parts.append(take)
        need = -1 if n < 0 else n - sum(len(p) for p in parts)
        while need != 0:
            chunk = self._r.read(_READ_BLOCK if need < 0 else need)
            if not chunk:
                break
            parts.append(chunk)
            if need > 0:
                need -= len(chunk)
        out = b"".join(parts)
        self._pos += len(out)
        self._tail = (self._tail + out)[-self._KEEP:]
        return out

    def seek(self, offset: int, whence: int = io.SEEK_SET) -> int:
        if whence == io.SEEK_CUR:
            target = self._pos + offset
        elif whence == io.SEEK_SET:
            target = offset
        else:  # SEEK_END needs the stream length, which is unknowable here
            raise ValueError(f"unsupported whence {whence}")
        if target < self._origin:
            raise ValueError(f"seek before window origin {self._origin}")
        delta = target - self._pos
        if delta < 0:
            if -delta > len(self._tail):
                raise ValueError("seek beyond the pushback tail")
            self._pending = self._tail[delta:] + self._pending
            self._tail = self._tail[:delta]
            self._pos = target
        elif delta > 0:
            while self._pos < target:
                if not self.read(min(target - self._pos, _READ_BLOCK)):
                    break  # short stream: behave like file seek past EOF
        return self._pos

    def tell(self) -> int:
        return self._pos


class UncompressedMemberStream(MemberStream):
    """Degenerate member stream: one member == the whole file.

    The fast parser does its own in-buffer record splitting for the
    uncompressed case, so this exists only for API uniformity.
    """

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._done = False
        self._pos = 0

    def next_member(self) -> bytes | None:
        if self._done:
            return None
        self._done = True
        data = self._raw.read()
        self._pos = len(data)
        return data

    def tell_compressed(self) -> int:
        return self._pos


def open_member_stream(raw: BinaryIO) -> tuple[MemberStream | None, str]:
    """Sniff compression and return the matching member stream.

    zstd returns ``(None, "zstd")`` — it has no cheap member boundaries;
    callers should wrap the source in :class:`ZstdStream` for bulk reads.
    """
    head = raw.read(8)
    if not raw.seekable():  # pragma: no cover - all our sources are seekable
        raise ValueError("non-seekable source")
    raw.seek(-len(head), io.SEEK_CUR)
    kind = detect_compression(head)
    if kind == "gzip":
        return GZipStream(raw), kind
    if kind == "lz4":
        return LZ4Stream(raw), kind
    return None, kind


def open_member_stream_at(raw: BinaryIO,
                          offset: int) -> tuple[MemberStream | None, str]:
    """:func:`open_member_stream`, positioned at compressed ``offset``.

    The respawn path of :class:`ProcessReadaheadDecoder`: a replacement
    decode child resumes exactly where the last fully-received batch of
    its predecessor ended, so parent-visible offsets stay absolute and
    results stay deterministic across child deaths.
    """
    stream, kind = open_member_stream(raw)
    if offset and stream is not None:
        if kind == "gzip":
            raw.seek(offset)
            stream._buf = b""
            stream._abs = offset
        else:  # lz4: whole file is already buffered, offsets are absolute
            stream._pos = offset
    return stream, kind


def next_member_tolerant(stream: MemberStream, out: bytearray, stats,
                         report) -> tuple[int, int] | None:
    """Decode the next member, resyncing past damaged ones.

    The tolerant-mode twin of ``stream.next_member_into``: a member that
    fails to decode (bad header, corrupt deflate/LZ4 blocks, truncated
    tail) has its partial output rolled back off ``out``, the stream
    resynced to the next member header, and the damaged compressed range
    reported via ``report(offset, error_class, bytes_skipped, message)``.

    Returns ``(nbytes, member_offset)`` for the next good member, or
    ``None`` at EOF. Catches ``Exception`` broadly: damaged compressed
    data surfaces as ``zlib.error``, ``LZ4Error``, ``struct.error``,
    ``IndexError``... — any of them means "this member is gone", and in
    tolerant mode no member may take down the shard.
    """
    from .errors import classify_member_error

    while True:
        offset = stream.tell_compressed()
        base = len(out)
        try:
            n = stream.next_member_into(out, stats)
        except Exception as exc:  # noqa: BLE001 - tolerant by contract
            del out[base:]  # roll the partial decode off the slot
            skipped = stream.resync(offset)
            if skipped is None:
                report(offset, "truncated_tail",
                       stream.tell_compressed() - offset, repr(exc))
                return None
            report(offset, classify_member_error(exc), skipped, repr(exc))
            continue
        if n is None:
            return None
        return n, offset


# --------------------------------------------------------------------------
# WARCIO-faithful chunked decompressing reader (baseline parser only)
# --------------------------------------------------------------------------

class ChunkedGzipReader:
    """Python-chunked gzip reader modeled on WARCIO's
    ``DecompressingBufferedReader``: 16 KiB compressed chunks, incremental
    decompress on every ``read``/``readline``, member restart on EOF of a
    member. This *is* the measured baseline behaviour, do not optimize."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._decomp = zlib.decompressobj(31)
        self._buf = b""
        self._off = 0
        self._comp_tail = b""
        self._eof = False

    def _fill(self) -> None:
        while not self._eof and self._off >= len(self._buf):
            comp = self._comp_tail or self._raw.read(_CHUNK)
            self._comp_tail = b""
            if not comp:
                self._eof = True
                return
            out = self._decomp.decompress(comp)
            if self._decomp.eof:
                self._comp_tail = self._decomp.unused_data
                self._decomp = zlib.decompressobj(31)
            if out:
                self._buf = out
                self._off = 0
                return

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            parts = [self._buf[self._off:]]
            self._off = len(self._buf)
            while True:
                self._fill()
                if self._off >= len(self._buf):
                    break
                parts.append(self._buf[self._off:])
                self._off = len(self._buf)
            return b"".join(parts)
        parts = []
        need = n
        while need > 0:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            if self._off >= len(self._buf):
                self._fill()
                if self._off >= len(self._buf):
                    break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


class PlainBufferedReader:
    """Uncompressed counterpart of :class:`ChunkedGzipReader` (baseline)."""

    def __init__(self, raw: BinaryIO) -> None:
        self._raw = raw
        self._buf = b""
        self._off = 0

    def _fill(self) -> None:
        if self._off >= len(self._buf):
            self._buf = self._raw.read(_CHUNK)
            self._off = 0

    def read(self, n: int = -1) -> bytes:
        if n < 0:
            rest = self._buf[self._off:] + self._raw.read()
            self._buf = b""
            self._off = 0
            return rest
        parts = []
        need = n
        while need > 0:
            self._fill()
            if self._off >= len(self._buf):
                break
            take = self._buf[self._off:self._off + need]
            self._off += len(take)
            need -= len(take)
            parts.append(take)
        return b"".join(parts)

    def readline(self) -> bytes:
        parts = []
        while True:
            self._fill()
            if self._off >= len(self._buf):
                break
            i = self._buf.find(b"\n", self._off)
            if i >= 0:
                parts.append(self._buf[self._off:i + 1])
                self._off = i + 1
                break
            parts.append(self._buf[self._off:])
            self._off = len(self._buf)
        return b"".join(parts)


# --------------------------------------------------------------------------
# Zero-copy pooled parse arena (FastWARC-style buffered reader, DESIGN.md §9)
# --------------------------------------------------------------------------

class CopyStats:
    """Byte-copy / allocation ledger for the ingest hot path.

    Every Python-level copy of payload bytes (buffer joins, compaction,
    header-block slices, ``detach()``/``content`` materialization) and
    every arena allocation is counted here, so the ingest benchmark can
    *prove* — not eyeball — that the zero-copy path stopped copying.
    Decompressor output is deliberately not counted: producing those
    bytes is the work itself, not overhead.

    Member decode is split the same way (ISSUE 5): legacy member paths
    materialize every decompressed member as a fresh ``bytes`` object —
    those bytes are tallied in ``member_bytes_copied`` — while the
    decode-into-arena paths append decompressor output straight onto a
    pooled slot, tallied in ``decode_into_arena`` (informational: it is
    the decompression work itself, not copy overhead). A gzip/LZ4 sweep
    whose ``bytes_copied + member_bytes_copied`` per record collapses to
    the uncompressed path's header-copy budget has stopped paying the
    per-record member-allocation tax.
    """

    __slots__ = ("copies", "bytes_copied", "allocs", "bytes_allocated",
                 "arena_reuses", "member_bytes_copied", "decode_into_arena")

    def __init__(self) -> None:
        self.copies = 0
        self.bytes_copied = 0
        self.allocs = 0
        self.bytes_allocated = 0
        self.arena_reuses = 0
        self.member_bytes_copied = 0
        self.decode_into_arena = 0

    def count_copy(self, nbytes: int) -> None:
        self.copies += 1
        self.bytes_copied += nbytes

    def count_alloc(self, nbytes: int) -> None:
        self.allocs += 1
        self.bytes_allocated += nbytes

    def count_member_copy(self, nbytes: int) -> None:
        """A decompressed member materialized as a per-record ``bytes``."""
        self.member_bytes_copied += nbytes

    def count_decode_into(self, nbytes: int) -> None:
        """Member bytes decoded directly into a pooled arena slot."""
        self.decode_into_arena += nbytes

    def as_dict(self) -> dict:
        return {k: getattr(self, k) for k in self.__slots__}

    def __repr__(self) -> str:  # pragma: no cover - debug aid
        return (f"CopyStats(copies={self.copies}, "
                f"bytes_copied={self.bytes_copied}, "
                f"member_bytes_copied={self.member_bytes_copied}, "
                f"allocs={self.allocs}, reuses={self.arena_reuses})")


_ARENA_BYTES = 1 << 20   # default arena size; grows geometrically per record
_ARENA_POOL_MAX = 4      # retired arenas kept for recycling


class RecordBuffer:
    """Pooled-arena buffered reader: the zero-copy parse surface.

    The parser addresses the stream by **absolute offset**; this class
    maps those offsets onto a reusable ``bytearray`` arena filled with
    ``readinto`` (no intermediate ``bytes`` objects where the source
    supports it). Record content is handed out as :meth:`view`
    ``memoryview`` slices — no per-record ``bytes`` slicing.

    Lifetime contract: a view pins its arena. Retired arenas go to a
    small pool and are recycled **only when no outstanding view
    references them** (checked via the arena's refcount), so borrowed
    views are never silently clobbered — consumers that drop records as
    they stream get steady-state zero allocation, consumers that hold
    records trade memory (fresh arenas) for safety. ``WarcRecord.detach``
    copies a record out and releases its pin.
    """

    def __init__(self, raw, *, arena_bytes: int = _ARENA_BYTES,
                 stats: CopyStats | None = None) -> None:
        self._raw = raw
        self._readinto = getattr(raw, "readinto", None)
        self._arena_bytes = max(arena_bytes, 4096)
        self.stats = stats if stats is not None else CopyStats()
        self._buf = bytearray(self._arena_bytes)
        self.stats.count_alloc(self._arena_bytes)
        self._pool: list[bytearray] = []
        self._start = 0   # discard watermark (buffer-relative)
        self._end = 0     # fill watermark (buffer-relative)
        self._base = 0    # absolute stream offset of _buf[0]
        self.eof = False

    # -- addressing ------------------------------------------------------
    @property
    def end_abs(self) -> int:
        """Absolute offset one past the last buffered byte."""
        return self._base + self._end

    def ensure(self, pos: int, need: int) -> bool:
        """Make ``[pos, pos + need)`` addressable; never moves ``pos``."""
        while True:
            if self._base + self._end - pos >= need:
                return True
            if self.eof:
                return False
            if self._end >= len(self._buf) or \
                    pos - self._base + need > len(self._buf):
                self._roll(pos, need)
            self._fill_tail()

    def find(self, needle: bytes, pos: int, end: int | None = None) -> int:
        """Absolute offset of ``needle`` in the buffered region, or -1."""
        rel_end = self._end if end is None else min(end - self._base,
                                                   self._end)
        i = self._buf.find(needle, max(pos - self._base, 0), rel_end)
        return -1 if i < 0 else self._base + i

    def startswith(self, needle: bytes, pos: int) -> bool:
        return self._buf.startswith(needle, pos - self._base)

    def view(self, a: int, b: int) -> memoryview:
        """Zero-copy borrow of ``[a, b)``; pins the arena (see class doc)."""
        return memoryview(self._buf)[a - self._base:b - self._base]

    def take_bytes(self, a: int, b: int) -> bytes:
        """Owning ``bytes`` copy of ``[a, b)`` (counted)."""
        out = bytes(memoryview(self._buf)[a - self._base:b - self._base])
        self.stats.count_copy(len(out))
        return out

    def discard(self, pos: int) -> None:
        """Mark everything below absolute ``pos`` consumed (reusable)."""
        rel = pos - self._base
        if rel > self._start:
            self._start = rel

    def scan_field(self, needle: bytes, a: int, b: int) -> bytes | None:
        """Line-anchored ``Name:``-field scan inside ``[a, b)``, in-arena.

        Delegates to :func:`repro.core.warc.record.scan_header_field_in`
        (shared with the member-decode slots): skipped records get their
        type/length sniffed straight off the arena — no header block is
        ever sliced out for them. Only the (tiny) field value is
        materialized.
        """
        return scan_header_field_in(self._buf, needle,
                                    a - self._base, b - self._base)

    # -- internals -------------------------------------------------------
    def _take_arena(self, capacity: int) -> bytearray:
        """Recycle a retired arena iff nothing references it anymore."""
        import sys

        for i in range(len(self._pool)):
            cand = self._pool[i]
            # refs: pool list + `cand` local + getrefcount argument == 3;
            # any outstanding memoryview/ndarray raises the count
            if len(cand) >= capacity and sys.getrefcount(cand) <= 3:
                self.stats.arena_reuses += 1
                return self._pool.pop(i)
        cap = self._arena_bytes
        while cap < capacity:
            cap *= 2
        self.stats.count_alloc(cap)
        return bytearray(cap)

    def _roll(self, pos: int, need: int) -> None:
        """Move the live tail onto a fresh/recycled arena.

        The only copy on the whole parse path: the bytes of the record
        currently straddling the arena edge (amortized: a fraction of one
        record per arena, not per record). Growth is geometric — at most
        a doubling per roll, never ``need`` upfront: a hostile or corrupt
        ``Content-Length`` (terabyte ``need``) must not allocate anything
        the stream hasn't backed with bytes; ``ensure`` keeps rolling as
        real data arrives and surfaces EOF as a truncated record instead.
        """
        live_start = min(self._start, pos - self._base)
        live = self._end - live_start
        cap_limit = max(2 * len(self._buf), self._arena_bytes)
        new = self._take_arena(max(min(live + need, cap_limit), live + 1))
        if live:
            new[:live] = memoryview(self._buf)[live_start:self._end]
            self.stats.count_copy(live)
        old = self._buf
        self._buf = new
        self._base += live_start
        self._end = live
        self._start = 0
        if len(self._pool) >= _ARENA_POOL_MAX:
            self._pool.pop(0)  # dropped; freed once its views die
        self._pool.append(old)

    def _fill_tail(self) -> None:
        space = len(self._buf) - self._end
        if space <= 0:
            return
        if self._readinto is not None:
            n = self._readinto(memoryview(self._buf)[self._end:])
            if not n:
                self.eof = True
            else:
                self._end += n
            return
        chunk = self._raw.read(space)
        if not chunk:
            self.eof = True
            return
        self._buf[self._end:self._end + len(chunk)] = chunk
        self.stats.count_copy(len(chunk))  # copy-in: source lacks readinto
        self._end += len(chunk)


# --------------------------------------------------------------------------
# Member decode arenas + pipelined readahead decoder (DESIGN.md §9, ISSUE 5)
# --------------------------------------------------------------------------

class MemberArena:
    """Pooled decode-target slots for member-oriented zero-copy parsing.

    The member-stream twin of :class:`RecordBuffer`'s arena pool:
    decode targets are reusable ``bytearray`` slots filled through the
    ``next_member_into`` append API; records borrow ``memoryview``
    slices of a slot, so a released slot is recycled **only when nothing
    references it anymore** (refcount check, exactly the
    :class:`RecordBuffer` contract) — held records cost fresh slots,
    never corruption. A recycled slot keeps no stale content
    (``clear()``) but its growth history keeps Python's allocator warm
    at the high-water member size. Thread-safe: the readahead decoder
    acquires from its thread while the parser releases from the
    consumer side.
    """

    __slots__ = ("stats", "_pool", "_pool_max", "_lock")

    def __init__(self, *, stats: CopyStats | None = None,
                 pool_max: int = _ARENA_POOL_MAX) -> None:
        self.stats = stats if stats is not None else CopyStats()
        self._pool: list[bytearray] = []
        self._pool_max = pool_max
        self._lock = threading.Lock()

    def acquire(self) -> bytearray:
        """An empty slot: recycled if a pooled one is reference-free."""
        with self._lock:
            for i in range(len(self._pool)):
                cand = self._pool[i]
                # refs: pool list + `cand` local + getrefcount argument == 3;
                # any outstanding record view raises the count
                if sys.getrefcount(cand) <= 3:
                    self.stats.arena_reuses += 1
                    slot = self._pool.pop(i)
                    slot.clear()
                    return slot
        self.stats.allocs += 1  # byte volume grows with appends, not here
        return bytearray()

    def release(self, slot: bytearray) -> None:
        """Return a slot to the pool (parser done; borrowed views keep it
        alive until their records die)."""
        with self._lock:
            if len(self._pool) >= self._pool_max:
                self._pool.pop(0)  # dropped; freed once its views die
            self._pool.append(slot)


class ReadaheadDecoder:
    """Double-buffered member-decode stage: one decoder thread per stream.

    The thread pulls slots from a :class:`MemberArena`, packs
    consecutive decompressed members into each slot (amortizing queue
    hand-offs over up to ``max_members`` records), and posts
    ``(slot, [(start, nbytes, comp_offset), ...])`` batches into a
    bounded ring; the consumer parses records straight out of borrowed
    slot views while the thread inflates the next batch — file I/O and
    member decode overlap record parsing (zlib releases the GIL during
    inflate, so the overlap is real on ≥2 cores). Decode errors are
    posted in-band *after* the members decoded before them, so the
    consumer yields exactly the records the synchronous path would have
    yielded before re-raising. ``close()`` is idempotent: stops the
    thread, drains the ring (releasing slots), joins.
    """

    _IDLE = 0.05  # poll quantum for stop-responsive queue ops

    def __init__(self, decode_member, arena: MemberArena, *,
                 depth: int = 3, watermark: int = _ARENA_BYTES,
                 max_members: int = 128) -> None:
        # decode_member(slot) appends one member: -> (nbytes, comp_offset)
        # or None at EOF; called only from the decoder thread.
        self._decode = decode_member
        self._arena = arena
        self._watermark = watermark
        self._max_members = max_members
        self._ring: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self.thread = threading.Thread(target=self._run,
                                       name="warc-readahead", daemon=True)
        self.thread.start()

    # -- decoder thread --------------------------------------------------
    def _post(self, item) -> bool:
        while not self._stop.is_set():
            try:
                self._ring.put(item, timeout=self._IDLE)
                return True
            except queue.Full:
                continue
        return False

    def _run(self) -> None:
        eof = False
        batch_cap = min(32, self._max_members)  # ramp-up (fill bubble)
        while not eof and not self._stop.is_set():
            slot = self._arena.acquire()
            members: list[tuple[int, int, int]] = []
            fill = 0
            error: BaseException | None = None
            while len(members) < batch_cap and \
                    fill < self._watermark:
                try:
                    res = self._decode(slot)
                except BaseException as exc:
                    error = exc
                    break
                if res is None:
                    eof = True
                    break
                nbytes, offset = res
                members.append((fill, nbytes, offset))
                fill += nbytes
            batch_cap = self._max_members
            if members:
                if not self._post(("batch", slot, members)):
                    return
            else:
                self._arena.release(slot)
            if error is not None:
                self._post(("raise", error))
                return
        if eof:
            self._post(("eof",))

    # -- consumer side ---------------------------------------------------
    def get(self):
        """Next ``("batch", slot, members)`` or ``None`` after EOF /
        close; re-raises errors the decoder thread hit, in stream
        order."""
        while True:
            try:
                item = self._ring.get(timeout=self._IDLE)
            except queue.Empty:
                if self._stop.is_set():
                    return None
                if not self.thread.is_alive() and self._ring.empty():
                    return None  # defensive: thread died without posting
                continue
            if item[0] == "batch":
                return item
            if item[0] == "raise":
                raise item[1]
            return None  # eof

    def release(self, slot: bytearray) -> None:
        """Hand a consumed batch's slot back for recycling."""
        self._arena.release(slot)

    def close(self) -> None:
        """Stop decoding, drain the ring (releasing slots), join the
        thread. Safe to call repeatedly and from ``finally`` blocks."""
        self._stop.set()
        while True:
            try:
                item = self._ring.get_nowait()
            except queue.Empty:
                break
            if item[0] == "batch":
                self._arena.release(item[1])
        self.thread.join(timeout=5.0)


# pipe protocol: [u8 kind][u32 len][payload]. Batch payload = slot/blob
# header + a packed member table. A raw pipe written from the child's
# *main* thread replaces mp.Queue: the queue's feeder thread would
# contend with the decode loop for the child's GIL (the same convoy the
# process exists to escape) and pickle every descriptor.
_RA_BATCH, _RA_BLOB, _RA_EOF, _RA_RAISE, _RA_LEDGER = 0, 1, 2, 3, 4
_RA_HDR = struct.Struct("<BI")
_RA_BATCH_HDR = struct.Struct("<IIQ")  # slot_idx, nbytes, next_offset
_RA_MEMBER = struct.Struct("<IIQ")     # start, nbytes, offset


def _ra_send(wfd: int, kind: int, payload: bytes) -> None:
    msg = _RA_HDR.pack(kind, len(payload)) + payload
    mv = memoryview(msg)
    while mv:
        written = os.write(wfd, mv)
        mv = mv[written:]


def _ra_send_ledger(wfd: int, offset: int, error_class: str,
                    bytes_skipped: int, message: str) -> None:
    _ra_send(wfd, _RA_LEDGER,
             pickle.dumps((offset, error_class, bytes_skipped, message)))


def _maybe_member_fault(count: int) -> None:
    """Deterministic decoder-child fault hook (chaos tests only).

    ``REPRO_FAULT_DECODER_STALL=<latch-path>:<member-N>:<seconds>``
    stalls the decode loop at member ``count == N`` — exactly once
    globally, via an ``O_EXCL`` latch file, so the respawned child sails
    past the same member. Environment-variable plumbing survives both
    fork and spawn; a no-op unless the variable is set.
    """
    spec = os.environ.get("REPRO_FAULT_DECODER_STALL")
    if not spec:
        return
    try:
        latch, n_s, secs_s = spec.rsplit(":", 2)
        n, secs = int(n_s), float(secs_s)
    except ValueError:
        return
    if count != n:
        return
    try:
        fd = os.open(latch, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
    except FileExistsError:
        return
    os.close(fd)
    time.sleep(secs)


class _MvSink:
    """Member-decode sink writing straight into a shared-memory slot.

    Output chunks land at ``pos`` in the slot's memoryview; once a chunk
    would cross ``limit`` (a member bigger than the slot), the remainder
    spills into a bytearray so the caller can reassemble the oversized
    member for the pipe-blob fallback.
    """

    __slots__ = ("mv", "pos", "limit", "spill")

    def __init__(self, mv, pos: int, limit: int) -> None:
        self.mv = mv
        self.pos = pos
        self.limit = limit
        self.spill: bytearray | None = None

    def append(self, chunk) -> None:
        if self.spill is not None:
            self.spill += chunk
            return
        end = self.pos + len(chunk)
        if end > self.limit:
            self.spill = bytearray(chunk)
            return
        self.mv[self.pos:end] = chunk
        self.pos = end


class _ChildObs:
    """Decoder-child counter surface: a plain dict published through a
    seqlock stats slot after every batch (and at EOF/error), so the
    parent can harvest the child's cumulative ``decoder.*`` counters even
    if the child is later SIGKILLed. ``writer=None`` (no stats slot, e.g.
    an old-style spawn) degrades to counting without publishing."""

    __slots__ = ("counters", "_writer")

    def __init__(self, writer: StatsSlotWriter | None) -> None:
        self.counters = {
            "decoder.members": 0, "decoder.batches": 0,
            "decoder.bytes": 0, "decoder.giant_blobs": 0,
            "decoder.ledger_entries": 0,
        }
        self._writer = writer

    def bump(self, name: str, n: int = 1) -> None:
        self.counters[name] += n

    def publish(self) -> None:
        if self._writer is not None:
            self._writer.publish(ObsSnapshot(
                counters=dict(self.counters),
                sources=("readahead-decoder",)))


def _member_decode_child(src, shm_name: str, slot_bytes: int, slots: int,
                         sem, rfd: int, wfd: int, watermark: int,
                         max_members: int, start_offset: int = 0,
                         tolerant: bool = False,
                         stats_off: int = 0) -> None:
    """Child-process main of :class:`ProcessReadaheadDecoder`.

    Opens its own view of the source (a path, or forked bytes), inflates
    members back-to-back into local batches, memcpys each batch into its
    shared-memory ring slot and writes a tiny packed descriptor to the
    pipe — all from one thread. Runs only stdlib zlib + the from-scratch
    LZ4 — never touches jax, so it is safe under the fork start method
    (all imports it needs are at module top, so it cannot trip over a
    fork-held import lock). Errors are shipped in-band *after* the
    members decoded before them (the parent then re-raises in stream
    order, matching the synchronous path).
    """
    os.close(rfd)  # parent's read end: child must not hold it open
    try:
        raw = open(src, "rb") if isinstance(src, str) else io.BytesIO(src)
        stream, _kind = open_member_stream_at(raw, start_offset)
        if stream is None:
            _ra_send(wfd, _RA_EOF, b"")
            return
        # parent owns the segment's lifetime: attach without registering
        # (see ParallelWarcPool._ShmSlotWriter for the full rationale)
        from multiprocessing import resource_tracker

        orig_register = resource_tracker.register
        resource_tracker.register = lambda *a, **k: None
        try:
            shm = _shm_mod.SharedMemory(name=shm_name)
        finally:
            resource_tracker.register = orig_register
        sbuf = shm.buf[stats_off:stats_off + STATS_SLOT_BYTES] \
            if stats_off else None
        cobs = _ChildObs(StatsSlotWriter(sbuf) if sbuf is not None else None)
        try:
            if isinstance(stream, GZipStream):
                _gzip_decode_into_ring(stream, shm, slot_bytes, slots, sem,
                                       wfd, watermark, max_members, tolerant,
                                       cobs)
            else:
                _member_decode_into_ring(stream, shm, slot_bytes, slots,
                                         sem, wfd, watermark, max_members,
                                         tolerant, cobs)
        finally:
            # memoryview exports must be gone before shm.close()
            if cobs._writer is not None:
                cobs._writer.close()
            if sbuf is not None:
                sbuf.release()
            shm.close()
    except BaseException as exc:  # attach/open failures etc.
        try:
            _ra_send(wfd, _RA_RAISE, pickle.dumps(RuntimeError(repr(exc))))
        except Exception:  # pragma: no cover - pipe already torn down
            pass


def _ra_send_error(wfd: int, error: BaseException) -> None:
    try:
        blob = pickle.dumps(error)
    except Exception:
        blob = pickle.dumps(RuntimeError(repr(error)))
    _ra_send(wfd, _RA_RAISE, blob)


def _member_decode_into_ring(stream, shm, slot_bytes: int, slots: int,
                             sem, wfd: int, watermark: int,
                             max_members: int, tolerant: bool = False,
                             cobs: "_ChildObs | None" = None) -> None:
    """Generic child decode loop: members append to a local bytearray
    batch, then one memcpy into the ring slot (LZ4's decode-into API is
    append-based). gzip uses :func:`_gzip_decode_into_ring` instead,
    which skips the local buffer entirely. With ``tolerant``, damaged
    members resync instead of erroring, shipping a ledger message."""
    from .errors import classify_member_error

    if cobs is None:
        cobs = _ChildObs(None)
    slot_idx = 0
    local = bytearray()
    eof = False
    decoded = 0
    # ramp-up: a small first batch shortens the pipeline-fill bubble
    # (the parent would otherwise idle a full batch time)
    batch_cap = min(32, max_members)
    while not eof:
        local.clear()
        members: list[tuple[int, int, int]] = []
        error: BaseException | None = None
        while len(members) < batch_cap and len(local) < watermark:
            offset = stream.tell_compressed()
            base = len(local)
            try:
                n = stream.next_member_into(local)
            except Exception as exc:
                if tolerant:
                    del local[base:]  # roll the partial decode off
                    skipped = stream.resync(offset)
                    if skipped is None:
                        _ra_send_ledger(
                            wfd, offset, "truncated_tail",
                            stream.tell_compressed() - offset, repr(exc))
                        cobs.bump("decoder.ledger_entries")
                        eof = True
                        break
                    _ra_send_ledger(wfd, offset, classify_member_error(exc),
                                    skipped, repr(exc))
                    cobs.bump("decoder.ledger_entries")
                    continue
                error = exc
                break
            except BaseException as exc:
                error = exc
                break
            if n is None:
                eof = True
                break
            members.append((len(local) - n, n, offset))
            decoded += 1
            _maybe_member_fault(decoded)
        batch_cap = max_members
        if members:
            nbytes = len(local)
            next_off = stream.tell_compressed()
            table = b"".join(_RA_MEMBER.pack(*m) for m in members)
            if nbytes <= slot_bytes:
                sem.acquire()  # FIFO drain: target slot is free
                base = slot_idx * slot_bytes
                shm.buf[base:base + nbytes] = local
                _ra_send(wfd, _RA_BATCH,
                         _RA_BATCH_HDR.pack(slot_idx, nbytes, next_off)
                         + table)
                slot_idx = (slot_idx + 1) % slots
            else:  # oversized batch (huge member): pipe fallback
                _ra_send(wfd, _RA_BLOB,
                         _RA_BATCH_HDR.pack(0, nbytes, next_off)
                         + table + local)
                cobs.bump("decoder.giant_blobs")
            cobs.bump("decoder.members", len(members))
            cobs.bump("decoder.batches")
            cobs.bump("decoder.bytes", nbytes)
            cobs.publish()
        if error is not None:
            _ra_send_error(wfd, error)
            cobs.publish()
            return
    _ra_send(wfd, _RA_EOF, b"")
    cobs.publish()


def _gzip_decode_into_ring(stream: "GZipStream", shm, slot_bytes: int,
                           slots: int, sem, wfd: int, watermark: int,
                           max_members: int, tolerant: bool = False,
                           cobs: "_ChildObs | None" = None) -> None:
    """gzip child decode loop: members inflate **directly into the ring
    slot** through a :class:`_MvSink` — no local batch buffer, no batch
    memcpy, each output byte written once. A member that outgrows its
    slot spills and travels as a pipe blob instead. With ``tolerant``,
    damaged members are rolled back off the slot, the stream resyncs to
    the next member magic, and a ledger message ships in-band."""
    from .errors import classify_member_error

    if cobs is None:
        cobs = _ChildObs(None)
    slot_idx = 0
    eof = False
    decoded = 0
    batch_cap = min(32, max_members)  # ramp-up (fill bubble)
    buf = shm.buf
    while not eof:
        sem.acquire()  # slot needed up front: decode writes straight in
        base = slot_idx * slot_bytes
        sink = _MvSink(buf, base, base + slot_bytes)
        members: list[tuple[int, int, int]] = []
        error: BaseException | None = None
        giant: tuple[bytes, int] | None = None
        while len(members) < batch_cap and sink.pos - base < watermark:
            offset = stream._abs + stream._off  # inlined tell_compressed
            member_start = sink.pos
            try:
                if stream._skip_member_header() is None:
                    eof = True
                    break
                stream._decode_member_body(sink.append)
            except Exception as exc:
                if tolerant:
                    sink.pos = member_start  # roll the partial back off
                    sink.spill = None
                    skipped = stream.resync(offset)
                    if skipped is None:
                        _ra_send_ledger(
                            wfd, offset, "truncated_tail",
                            stream.tell_compressed() - offset, repr(exc))
                        cobs.bump("decoder.ledger_entries")
                        eof = True
                        break
                    _ra_send_ledger(wfd, offset, classify_member_error(exc),
                                    skipped, repr(exc))
                    cobs.bump("decoder.ledger_entries")
                    continue
                error = exc
                break
            except BaseException as exc:
                error = exc
                break
            if sink.spill is not None:  # member outgrew the slot
                giant = (bytes(buf[member_start:sink.pos])
                         + bytes(sink.spill), offset)
                sink.spill = None
                sink.pos = member_start  # roll it back off the slot
                break
            members.append((member_start - base,
                            sink.pos - member_start, offset))
            decoded += 1
            _maybe_member_fault(decoded)
        batch_cap = max_members
        next_off = stream._abs + stream._off
        if members:
            # resume cursor of the *batch* message stops short of a giant
            # member sent separately below — a death between the two must
            # re-drive the giant, not skip it
            batch_next = giant[1] if giant is not None else next_off
            table = b"".join(_RA_MEMBER.pack(*m) for m in members)
            _ra_send(wfd, _RA_BATCH,
                     _RA_BATCH_HDR.pack(slot_idx, sink.pos - base,
                                        batch_next) + table)
            slot_idx = (slot_idx + 1) % slots
            cobs.bump("decoder.members", len(members))
            cobs.bump("decoder.batches")
            cobs.bump("decoder.bytes", sink.pos - base)
            cobs.publish()
        else:
            sem.release()  # nothing landed: hand the slot straight back
        if giant is not None:
            data, offset = giant
            _ra_send(wfd, _RA_BLOB,
                     _RA_BATCH_HDR.pack(0, len(data), next_off)
                     + _RA_MEMBER.pack(0, len(data), offset) + data)
            cobs.bump("decoder.members")
            cobs.bump("decoder.giant_blobs")
            cobs.bump("decoder.bytes", len(data))
            cobs.publish()
        if error is not None:
            _ra_send_error(wfd, error)
            cobs.publish()
            return
    _ra_send(wfd, _RA_EOF, b"")
    cobs.publish()


class ProcessReadaheadDecoder:
    """True-parallel readahead: member decode in a child process, batches
    handed over through a shared-memory slot ring.

    Why a process: the thread decoder cannot overlap a CPU-bound parse
    loop under CPython's GIL — after every ~10 µs GIL-released inflate
    the decoder waits up to the 5 ms switch interval for a hot consumer
    to yield (measured ~10 ms reacquire latency on a contended 2-core
    host, EXPERIMENTS.md §Ingest), which degenerates any two-thread CPU
    pipeline to serial. A child process decodes on its own core.

    The parent lands each ring batch in a :class:`MemberArena` slot with
    one memcpy — decompressor-output transport, tallied as
    ``decode_into_arena`` exactly like the thread path's chunk appends,
    never as parse-path copies — and releases the ring slot immediately,
    so slot lifetime never crosses the process boundary and borrowed
    record views keep the plain arena refcount contract.

    Consumer API is identical to :class:`ReadaheadDecoder`:
    ``get()`` → ``("batch", slot, members)`` / ``None``, ``release()``,
    ``close()``. Construction raises where shared memory or a safe fork
    context is unavailable — callers fall back to the thread decoder.
    """

    _IDLE = 0.05
    _BACKOFF = 0.05  # first respawn delay; doubles per attempt, capped
    _BACKOFF_CAP = 1.0

    def __init__(self, src, arena: MemberArena, *, depth: int = 3,
                 watermark: int = _ARENA_BYTES,
                 max_members: int = 128, tolerant: bool = False,
                 on_ledger=None, max_respawns: int = 2,
                 stall_timeout_s: float | None = None) -> None:
        import multiprocessing as mp

        # pre-import in the parent so the forked child's function-level
        # import is a sys.modules hit, never a fork-held import lock
        from multiprocessing import resource_tracker  # noqa: F401

        if _shm_mod is None:  # pragma: no cover - py>=3.8 everywhere
            raise RuntimeError("shared_memory unavailable")
        if mp.current_process().daemon:
            # daemonic processes (e.g. ParallelWarcPool workers) may not
            # have children — those parses use the thread decoder, which
            # is the right shape anyway: the shards are already fanned
            # out one per core, there is no spare core to decode on
            raise RuntimeError("daemonic parent cannot fork a decoder")
        if "fork" not in mp.get_all_start_methods():
            # spawn/forkserver pay ~100 ms interpreter startup per stream;
            # the thread decoder is the right fallback there. Unlike pool
            # workers (repro.core.parallel._default_context forbids fork
            # once jax is imported because workers run arbitrary code),
            # this child executes only the pre-imported stdlib zlib /
            # from-scratch LZ4 paths below — it can never call into XLA,
            # so fork stays safe with a live jax runtime in the parent.
            raise RuntimeError("no fork start method on this platform")
        self._ctx = mp.get_context("fork")
        self._src = src
        self._arena = arena
        self._slot_bytes = max(2 * watermark, 1 << 16)
        self._slots = depth
        self._watermark = watermark
        self._max_members = max_members
        self._tolerant = tolerant
        self._on_ledger = on_ledger
        self._max_respawns = max_respawns
        if stall_timeout_s is None:
            env = os.environ.get("REPRO_DECODER_STALL_S")
            stall_timeout_s = float(env) if env else None
        self._stall_timeout_s = stall_timeout_s
        self._resume = 0        # compressed offset the next child starts at
        self._respawns = 0
        self._closed = False
        self._shm = None
        self._rfd = None
        self._spawn()

    def _spawn(self) -> None:
        """Create segment + semaphore + pipe and start a decode child.

        Called at construction and again by :meth:`_recover` after a
        child death/stall — every spawn gets a *fresh* ring (segment and
        semaphore), so permits a dead child took to its grave can never
        shrink the replacement's ring.
        """
        from .. import reaper as _reaper

        # ring slots plus one trailing seqlock stats slot the child
        # publishes its cumulative decoder.* counters into (harvested by
        # the parent at teardown — survives a SIGKILLed child)
        stats_off = self._slot_bytes * self._slots
        self._shm = _reaper.create_segment(stats_off + STATS_SLOT_BYTES)
        self._rfd = wfd = None
        try:
            self._sem = self._ctx.Semaphore(self._slots)
            self._rfd, wfd = os.pipe()
            self.process = self._ctx.Process(
                target=_member_decode_child,
                args=(self._src, self._shm.name, self._slot_bytes,
                      self._slots, self._sem, self._rfd, wfd,
                      self._watermark, self._max_members, self._resume,
                      self._tolerant, stats_off),
                name="warc-readahead-decoder", daemon=True)
            import warnings

            with warnings.catch_warnings():
                # jax warns on any fork from a process with live XLA
                # threads; this child provably never calls into XLA (see
                # class doc) — the blanket warning is suppressed narrowly
                warnings.filterwarnings(
                    "ignore", message=r"os\.fork\(\) was called",
                    category=RuntimeWarning)
                self.process.start()
        except BaseException:
            # partial construction (sem ENOSPC, pipe EMFILE, fork EAGAIN)
            # must not leak the segment/fds: callers fall back to the
            # thread decoder per shard, and silent leaks would fill
            # /dev/shm under exactly the pressure that triggers them
            for fd in (self._rfd, wfd):
                if fd is not None:
                    try:
                        os.close(fd)
                    except OSError:  # pragma: no cover - teardown race
                        pass
            self._rfd = None
            self._unlink_segment()
            raise
        os.close(wfd)  # child holds the only write end: EOF == child gone

    def _unlink_segment(self) -> None:
        from .. import reaper as _reaper

        if self._shm is None:
            return
        try:
            self._shm.close()
            self._shm.unlink()
        except (OSError, FileNotFoundError):  # pragma: no cover - race
            pass
        _reaper.unregister(self._shm)
        self._shm = None

    def _harvest_stats(self) -> None:
        """Absorb the child's last published ``decoder.*`` counters into
        the process-default registry. Best effort: a child killed between
        publishes loses only its in-flight batch's counts; a respawned
        child re-decoding from the resume cursor may re-count members the
        dead child decoded but never shipped."""
        if self._shm is None:
            return
        stats_off = self._slot_bytes * self._slots
        view = self._shm.buf[stats_off:stats_off + STATS_SLOT_BYTES]
        reader = StatsSlotReader(view)
        snap = reader.read()
        reader.close()
        view.release()  # export must be gone before close/unlink
        if snap is not None:
            from repro import obs

            obs.registry().absorb(snap)

    def _teardown_child(self) -> None:
        if self.process.is_alive():
            self.process.terminate()
        self.process.join(timeout=5.0)
        if self._rfd is not None:
            try:
                os.close(self._rfd)
            except OSError:  # pragma: no cover - teardown race
                pass
            self._rfd = None
        self._harvest_stats()
        self._unlink_segment()

    def _recover(self, reason: str) -> None:
        """Reap a dead/stalled child and respawn from the resume cursor.

        Every batch the parent has fully received is final (its bytes
        were copied into the arena at ``get()`` time), so the
        replacement child restarts decoding at ``self._resume`` — the
        compressed offset just past the last received batch — and the
        member stream continues deterministically. Capped exponential
        backoff; budget exhaustion re-raises the underlying failure.
        """
        if self._respawns >= self._max_respawns:
            raise RuntimeError(
                f"readahead decoder process {reason}; respawn budget "
                f"({self._max_respawns}) exhausted")
        self._respawns += 1
        from repro import obs

        obs.registry().counter_add("decoder.respawns")
        delay = min(self._BACKOFF * (2 ** (self._respawns - 1)),
                    self._BACKOFF_CAP)
        self._teardown_child()
        time.sleep(delay)
        self._spawn()

    # -- consumer side ---------------------------------------------------
    def _read_exact(self, n: int) -> bytes | None:
        """Read exactly ``n`` pipe bytes; ``None`` on EOF (child gone)."""
        parts = []
        need = n
        while need:
            chunk = os.read(self._rfd, need)
            if not chunk:
                return None
            parts.append(chunk)
            need -= len(chunk)
        return parts[0] if len(parts) == 1 else b"".join(parts)

    def get(self):
        """Next ``("batch", slot, members)`` with ``slot`` already landed
        in the parent arena, or ``None`` after EOF / close; re-raises
        child decode errors in stream order. A child that dies or stalls
        mid-stream is reaped and respawned from the resume cursor
        (capped backoff) instead of failing the whole shard."""
        waited = 0.0
        while True:
            ready, _, _ = select.select([self._rfd], [], [], self._IDLE)
            if not ready:
                if self._closed:
                    return None
                waited += self._IDLE
                if (self._stall_timeout_s is not None
                        and waited >= self._stall_timeout_s):
                    self._recover(
                        f"stalled (> {self._stall_timeout_s:.1f}s silent)")
                    waited = 0.0
                continue
            waited = 0.0
            hdr = self._read_exact(_RA_HDR.size)
            if hdr is None:
                if self._closed:
                    return None
                self._recover(f"died (exit {self.process.exitcode})")
                continue
            kind, plen = _RA_HDR.unpack(hdr)
            payload = self._read_exact(plen) if plen else b""
            if payload is None:
                if self._closed:
                    return None
                self._recover("died mid-message (pipe truncated)")
                continue
            if kind == _RA_EOF:
                return None
            if kind == _RA_RAISE:
                raise pickle.loads(payload)
            if kind == _RA_LEDGER:
                if self._on_ledger is not None:
                    self._on_ledger(*pickle.loads(payload))
                continue
            slot_idx, nbytes, next_off = _RA_BATCH_HDR.unpack_from(payload)
            table_end = len(payload) if kind == _RA_BATCH else \
                len(payload) - nbytes
            members = list(_RA_MEMBER.iter_unpack(
                payload[_RA_BATCH_HDR.size:table_end]))
            slot = self._arena.acquire()
            if kind == _RA_BATCH:
                base = slot_idx * self._slot_bytes
                if trace.enabled():  # per batch, never per record
                    with trace.span("ingest.arena_land"):
                        slot += self._shm.buf[base:base + nbytes]
                else:
                    slot += self._shm.buf[base:base + nbytes]
                self._sem.release()  # ring slot free before parsing starts
            else:  # _RA_BLOB: oversized batch travelled in the pipe
                slot += memoryview(payload)[table_end:]
            # the batch is now owned by the parent: a replacement child
            # may resume just past it without losing or repeating data
            self._resume = next_off
            self._arena.stats.count_decode_into(nbytes)
            return ("batch", slot, members)

    def release(self, slot: bytearray) -> None:
        self._arena.release(slot)

    def close(self) -> None:
        """Terminate the child, close the pipe, release the segment.
        Safe to call repeatedly and from ``finally`` blocks."""
        if self._closed:
            return
        self._closed = True
        self._teardown_child()


def iter_members(path_or_buf, kind: str | None = None) -> Iterator[bytes]:
    """Convenience: yield decompressed members of a WARC file."""
    raw = open(path_or_buf, "rb") if isinstance(path_or_buf, str) else io.BytesIO(path_or_buf)
    try:
        stream, detected = open_member_stream(raw)
        if stream is None:
            data = ZstdStream(raw).read() if detected == "zstd" else raw.read()
            if data:
                yield data
            return
        while True:
            member = stream.next_member()
            if member is None:
                return
            yield member
    finally:
        if isinstance(path_or_buf, str):
            raw.close()
