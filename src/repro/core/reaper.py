"""Orphaned shared-memory reaper: no segment survives its creator.

The shm transports (``ParallelWarcPool``'s per-worker rings, the
``ProcessReadaheadDecoder`` slot ring) create ``/dev/shm`` segments that
normally die in ``close()``. Two abnormal paths used to leak them:

* the parent is SIGKILLed mid-bench — ``finally`` blocks never run, the
  segment outlives every process that knew its name;
* an exception between segment creation and the owning object's
  construction completing (partially mitigated case-by-case before).

This module closes both holes structurally:

1. every segment is created through :func:`create_segment` under a
   parseable name — ``repro-shm-<pid>-<seq>-<tag>`` — and registered for
   an ``atexit`` unlink (covers normal exits and unhandled exceptions);
2. :func:`reap_orphans` scans ``/dev/shm`` for our prefix and unlinks
   any segment whose creator pid is gone (covers SIGKILL: the *next* run
   sweeps the leak). It runs lazily, once per process, the first time a
   segment is created.

POSIX semaphores need no reaping: CPython's ``SemLock`` calls
``sem_unlink`` immediately after ``sem_open``, so a killed process can
strand at most the kernel object until its last inheritor dies — nothing
persists on the filesystem across runs.
"""
from __future__ import annotations

import atexit
import itertools
import os
import secrets
import threading

try:
    from multiprocessing import shared_memory as _shm_mod
except ImportError:  # pragma: no cover - py>=3.8 everywhere we run
    _shm_mod = None

__all__ = ["SHM_PREFIX", "create_segment", "unregister", "reap_orphans"]

SHM_PREFIX = "repro-shm"
_SHM_DIR = "/dev/shm"

_lock = threading.Lock()
_seq = itertools.count()
_live: dict[str, object] = {}  # name -> SharedMemory (this process's own)
_atexit_armed = False
_swept_pid: int | None = None  # pid that last ran the orphan sweep


def _pid_alive(pid: int) -> bool:
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except PermissionError:  # pragma: no cover - exists, not ours
        return True
    return True


def _cleanup_at_exit() -> None:
    pid = str(os.getpid())
    with _lock:
        doomed = list(_live.values())
        _live.clear()
    for shm in doomed:
        if shm.name.split("-")[2] != pid:
            # forked child inherited the parent's registry + atexit hook:
            # the parent's live segments are not ours to unlink
            continue
        try:
            shm.close()
            shm.unlink()
        except (OSError, FileNotFoundError):  # already gone / teardown race
            pass


def reap_orphans() -> list[str]:
    """Unlink prefix-matching segments whose creator process is dead.

    Returns the names reaped (for tests/telemetry). Safe to call any
    time; never touches segments of live processes (including ours).
    """
    reaped: list[str] = []
    try:
        names = os.listdir(_SHM_DIR)
    except OSError:  # pragma: no cover - non-Linux or exotic mount
        return reaped
    for name in names:
        if not name.startswith(SHM_PREFIX + "-"):
            continue
        parts = name.split("-")
        try:
            pid = int(parts[2])
        except (IndexError, ValueError):
            continue
        if pid == os.getpid() or _pid_alive(pid):
            continue
        try:
            os.unlink(os.path.join(_SHM_DIR, name))
            reaped.append(name)
        except OSError:  # pragma: no cover - lost a race with another reaper
            pass
    return reaped


def create_segment(size: int):
    """Create a registered, reapable ``SharedMemory`` segment."""
    global _atexit_armed, _swept_pid
    if _shm_mod is None:  # pragma: no cover - py>=3.8 everywhere
        raise RuntimeError("shared_memory unavailable")
    pid = os.getpid()
    with _lock:
        if not _atexit_armed or _swept_pid != pid:
            # first segment of this process: arm the exit hook and sweep
            # leftovers of dead predecessors (both re-armed after fork —
            # the child has its own pid, registry entries stay parent's)
            atexit.register(_cleanup_at_exit)
            _atexit_armed = True
            _swept_pid = pid
            _live.clear()  # forked copy of the parent's registry: not ours
    reap_orphans()
    name = f"{SHM_PREFIX}-{pid}-{next(_seq)}-{secrets.token_hex(4)}"
    # keep the segment out of multiprocessing's resource tracker — a
    # helper process that unlinks whatever its creator registered the
    # instant the creator dies. That defeats this module's ownership
    # model twice over: a SIGKILLed creator must leave the segment for
    # the next run's sweep (the contract reap_orphans tests), and a
    # live parent must not lose a pool ring because one forked worker
    # exited and a shared tracker "cleaned up". Lifetime here belongs
    # to atexit + reap_orphans exclusively, so registration is stubbed
    # out around creation (the attach path in parallel.py does the
    # same) and unlink() is wrapped to skip the tracker's unregister —
    # which would otherwise traceback in the tracker process over the
    # registration that never happened.
    from multiprocessing import resource_tracker

    orig_register = resource_tracker.register
    resource_tracker.register = lambda *a, **k: None
    try:
        shm = _shm_mod.SharedMemory(name=name, create=True, size=size)
    finally:
        resource_tracker.register = orig_register
    raw_unlink = shm.unlink

    def _unlink_untracked() -> None:
        orig_unregister = resource_tracker.unregister
        resource_tracker.unregister = lambda *a, **k: None
        try:
            raw_unlink()
        finally:
            resource_tracker.unregister = orig_unregister

    shm.unlink = _unlink_untracked
    with _lock:
        _live[shm.name] = shm
    return shm


def unregister(shm) -> None:
    """Drop a segment from the atexit registry (owner closed it cleanly)."""
    with _lock:
        _live.pop(getattr(shm, "name", shm), None)
