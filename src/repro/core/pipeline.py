"""Streaming analytics pipeline: WARC bytes → clean text documents.

The deployment context the paper targets (§Introduction: "web search and
other large-scale web data analytics"): pull response records out of
archive shards, extract payload text, and hand documents downstream (here:
the LM tokenizer/packer in ``repro.data``). Stages:

    shard file → FastWARCIterator(record_types=response, lazy HTTP)
               → status/content-type gate → HTML → text extraction

Everything upstream of text extraction rides the optimized parser — the
pipeline *is* the paper's system in its intended role.
"""
from __future__ import annotations

import re
from dataclasses import dataclass
from typing import Iterator

from repro.core.warc import FastWARCIterator, WarcRecordType

_SCRIPT_RE = re.compile(rb"<(script|style)\b.*?</\1\s*>", re.S | re.I)
_TAG_RE = re.compile(rb"<[^>]*>")
_WS_RE = re.compile(rb"\s+")
_ENTITIES = {b"&amp;": b"&", b"&lt;": b"<", b"&gt;": b">",
             b"&quot;": b'"', b"&#39;": b"'", b"&nbsp;": b" "}


def html_to_text(html: bytes | memoryview) -> bytes:
    """Cheap, allocation-light HTML→text (analytics-grade, not a browser).

    Accepts a borrowed ``memoryview`` directly (``re`` scans any
    bytes-like buffer) — the zero-copy parse path feeds
    ``record.payload_view()`` straight in, no ``bytes`` materialization.
    """
    text = _SCRIPT_RE.sub(b" ", html)
    text = _TAG_RE.sub(b" ", text)
    for ent, rep in _ENTITIES.items():
        if ent in text:
            text = text.replace(ent, rep)
    return _WS_RE.sub(b" ", text).strip()


@dataclass
class Document:
    uri: str | None
    text: bytes
    record_offset: int


def iter_documents(source, *, min_length: int = 64,
                   status_ok_only: bool = True,
                   readahead: bool | None = None,
                   tolerant: bool = False) -> Iterator[Document]:
    """Yield text documents from one WARC file (path, bytes, or fileobj).

    ``readahead`` is forwarded to :class:`FastWARCIterator` (default
    auto: gzip members inflate on a decoder thread ahead of extraction).
    The iterator is closed on generator teardown, so an abandoned
    consumer (e.g. the token loader stopping mid-shard) deterministically
    joins the decoder thread and releases the shard's fd. ``tolerant``
    recovers from damaged records instead of aborting the shard (the
    skipped ranges land in the iterator's error ledger).
    """
    it = FastWARCIterator(source, record_types=WarcRecordType.response,
                          parse_http=True, readahead=readahead,
                          tolerant=tolerant)
    try:
        for record in it:
            http = record.http_headers
            if http is None:
                continue
            if status_ok_only and http.status_code != 200:
                continue
            ctype = http.get_bytes(b"Content-Type", b"")
            if not ctype.startswith(b"text/html"):
                continue
            # borrow-only: the payload never leaves the parse arena; only
            # the (much smaller) extracted text is materialized
            text = html_to_text(record.payload_view())
            if len(text) < min_length:
                continue
            yield Document(record.target_uri, text, record.stream_offset)
    finally:
        it.close()


_HREF_RE = re.compile(rb"""href\s*=\s*["']?(https?://[^"'\s>]+)""", re.I)


def extract_links(html: bytes | memoryview) -> list[bytes]:
    """Outgoing absolute links of a page (web-graph edge extraction)."""
    return [m.group(1) for m in _HREF_RE.finditer(html)]


def host_of(uri: bytes | str) -> str:
    s = uri.decode("utf-8", "replace") if isinstance(uri, (bytes, memoryview)) else uri
    rest = s.split("://", 1)[-1]
    return rest.split("/", 1)[0].lower()


def web_graph_from_warc(source, *, min_length: int = 0) -> dict:
    """Host-level web graph from a WARC file's response records.

    Returns {"hosts": [str], "edge_src": np.ndarray, "edge_dst": np.ndarray}
    with edges src→dst for every (page host → link host) pair — the
    classic web-graph use of archive crawls, and the bridge between the
    paper's parser and the GNN architectures in this framework.
    """
    import numpy as np

    host_ids: dict[str, int] = {}
    src_list: list[int] = []
    dst_list: list[int] = []

    def hid(h: str) -> int:
        if h not in host_ids:
            host_ids[h] = len(host_ids)
        return host_ids[h]

    it = FastWARCIterator(source, record_types=WarcRecordType.response,
                          parse_http=True)
    for record in it:
        if record.http_headers is None or record.target_uri is None:
            continue
        page_host = hid(host_of(record.target_uri))
        for link in extract_links(record.payload_view()):
            src_list.append(page_host)
            dst_list.append(hid(host_of(link)))
    return {"hosts": list(host_ids),
            "edge_src": np.asarray(src_list, np.int32),
            "edge_dst": np.asarray(dst_list, np.int32)}


def merge_web_graphs(partials: list[dict]) -> dict:
    """Reduce per-shard partial graphs into one host-level graph.

    Host ids are shard-local (each partial numbered its hosts by first
    appearance), so edges are remapped through a global host table before
    concatenation. First-appearance order across the partial list keeps
    the merge deterministic.
    """
    import numpy as np

    host_ids: dict[str, int] = {}
    src_parts: list[np.ndarray] = []
    dst_parts: list[np.ndarray] = []
    for g in partials:
        remap = np.empty(len(g["hosts"]), np.int32)
        for local, host in enumerate(g["hosts"]):
            if host not in host_ids:
                host_ids[host] = len(host_ids)
            remap[local] = host_ids[host]
        if g["edge_src"].size:
            src_parts.append(remap[g["edge_src"]])
            dst_parts.append(remap[g["edge_dst"]])
    cat = (lambda parts: np.concatenate(parts) if parts
           else np.empty(0, np.int32))
    return {"hosts": list(host_ids),
            "edge_src": cat(src_parts).astype(np.int32),
            "edge_dst": cat(dst_parts).astype(np.int32)}


def _web_graph_partial(source) -> dict:
    # module-level so the parallel pool can pickle it under spawn
    return web_graph_from_warc(source)


def web_graph_from_warcs(sources, *, workers: int = 0) -> dict:
    """Host-level web graph over many shards (map-reduce form).

    ``workers > 0`` builds per-shard partial graphs in a
    :class:`repro.core.parallel.ParallelWarcPool` and merges them with
    host-id remapping; ``workers=0`` maps serially. Both paths produce
    identical edge multisets (host numbering follows first appearance in
    shard order either way).
    """
    from repro.core.parallel import map_shards

    partials = map_shards(_web_graph_partial, list(sources), workers=workers)
    return merge_web_graphs(partials)
