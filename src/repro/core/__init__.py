# The paper's primary contribution: the FastWARC web-archive processing
# pipeline (repro.core.warc), the streaming analytics pipeline that feeds
# parsed payloads into JAX training (repro.core.pipeline), and the
# process-parallel shard ingestion engine (repro.core.parallel).
from . import warc  # noqa: F401
from . import parallel  # noqa: F401
