# The paper's primary contribution: the FastWARC web-archive processing
# pipeline (repro.core.warc) and the streaming analytics pipeline that feeds
# parsed payloads into JAX training (repro.core.pipeline).
from . import warc  # noqa: F401
