"""Shared ragged-batch helpers for the byte kernels' public wrappers.

Both byte kernels (`adler32`, `pattern_scan`) batch ragged payload lists
into padded ``(B, W)`` matrices; ``bucket_width`` is the common
power-of-two width-bucketing rule (one gridded dispatch per bucket, so
padding waste is ≤ 2× per row and repeated ragged batches reuse a
bounded set of compiled shapes). Kept in one place so the wrappers —
and consumers that account dispatches, like the index query engine —
cannot drift apart.
"""
from __future__ import annotations

import numpy as np

__all__ = ["as_u8", "bucket_width", "dispatch_count"]


def as_u8(data) -> np.ndarray:
    """View bytes-like or array input as a uint8 numpy array."""
    if isinstance(data, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(data), dtype=np.uint8)
    return np.asarray(data, np.uint8)


def bucket_width(size: int, block: int) -> int:
    """Block-multiple width bucket: next power-of-two block count."""
    nblocks = max((size + block - 1) // block, 1)
    return block * (1 << (nblocks - 1).bit_length())


def dispatch_count(sizes, block: int) -> int:
    """Kernel dispatches a batch of these payload sizes costs: one per
    distinct width bucket (what the batched wrappers actually issue).
    Consumers that account dispatches (query engine, serve gateway)
    share this so their books match the wrappers."""
    return len({bucket_width(int(s), block) for s in sizes})
