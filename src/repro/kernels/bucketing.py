"""Shared ragged-batch helpers for the byte kernels' public wrappers.

The byte kernels (`adler32`, `pattern_scan`, `digest_sig`) batch ragged
payload lists into padded ``(B, W)`` matrices; ``bucket_width`` is the
common width-bucketing rule (one gridded dispatch per bucket, repeated
ragged batches reuse a bounded set of compiled shapes). Kept in one
place so the wrappers — and consumers that account dispatches, like the
index query engine — cannot drift apart.

Bucket boundaries are **half-step** quantized: sizes round up to
``m · 2^j`` blocks with mantissa ``m ∈ {2, 3}`` (plus the single-block
floor), i.e. the bucket ladder runs 1, 2, 3, 4, 6, 8, 12, 16, … blocks
instead of pure powers of two. The PR 7 dispatch profiler measured the
pure-pow2 rule shipping 90.2 % padding on ``digest_signature_batch``
(BENCH_ingest.json): a row just past a boundary padded up to 2×, and
row-count padding multiplied on top. Half-steps bound per-row width
waste at 1.5× (worst case, mean ≈1.2×) while only doubling the shape
ladder, so compiled-shape reuse stays high. The same quantizer pads
*row counts* (``quantize_count``), replacing the old pow2-multiple-of-
group rule that inflated a 6-row flush to 128 kernel rows.
"""
from __future__ import annotations

import numpy as np

__all__ = ["ROWGROUP_PAD", "SMALL_BLOCK", "as_u8", "bucket_width",
           "dispatch_count", "payload_width", "quantize_count"]

# Width-bucket granularity for payloads below one full kernel block
# (digest path: the 2048 Adler block is an overflow *bound*, not a width
# floor). Lane-aligned; yields the sub-block half-step ladder 256, 512,
# 768, 1024, 1536 under the 2048 boundary.
SMALL_BLOCK = 256

# Zero right-padding of packed row-group matrices: (B, width + ROWGROUP_PAD)
# uint8, payload left-justified, zeros after. Lane-aligned (128); bounds both
# the digest kernel's n-gram reach (n − 1) and the pattern kernel's window
# reach (MAX_PATTERN − 1), so one packed layout — in RAM or mmapped from a
# columnar shard — feeds both kernels with no halo input and no re-copy.
ROWGROUP_PAD = 128


def as_u8(data) -> np.ndarray:
    """View bytes-like or array input as a uint8 numpy array."""
    if isinstance(data, (bytes, bytearray, memoryview)):
        return np.frombuffer(bytes(data), dtype=np.uint8)
    return np.asarray(data, np.uint8)


def quantize_count(n: int) -> int:
    """Smallest half-step-pow2 value ≥ ``n``: 1, 2, 3, 4, 6, 8, 12, ….

    The shared shape quantizer for both bucket widths (in blocks) and
    padded row counts: worst-case padding 1.5× (3→4 gap is 1.33×,
    2→3 gap is 1.5×), shape ladder only 2× denser than pure pow2.
    """
    if n <= 1:
        return 1
    pow2 = 1 << (max(n, 1) - 1).bit_length()   # next power of two ≥ n
    half = (pow2 // 4) * 3                     # the 3·2^j step just below it
    return half if half >= n else pow2


def bucket_width(size: int, block: int) -> int:
    """Block-multiple width bucket: half-step quantized block count."""
    nblocks = max((size + block - 1) // block, 1)
    return block * quantize_count(nblocks)


def payload_width(size: int, block: int, small: int | None = SMALL_BLOCK
                  ) -> int:
    """Width bucket with sub-block granularity below one block.

    The digest/derive bucketing rule: payloads that fit in a single
    kernel block take finer ``small``-granular buckets (the whole row is
    one block, so nothing forces the full-block floor — a shard's tiny
    request/metadata records were the dominant term of the measured
    90.2% pad waste). Larger payloads use the block-multiple ladder.
    """
    if small and size <= block:
        return min(bucket_width(size, small), block)
    return bucket_width(size, block)


def dispatch_count(sizes, block: int) -> int:
    """Kernel dispatches a batch of these payload sizes costs: one per
    distinct width bucket (what the batched wrappers actually issue).
    Consumers that account dispatches (query engine, serve gateway)
    share this so their books match the wrappers."""
    return len({bucket_width(int(s), block) for s in sizes})
