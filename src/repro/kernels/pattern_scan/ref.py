"""Pure-jnp oracle for the pattern-scan kernel."""
from __future__ import annotations

import jax.numpy as jnp


def pattern_mask_ref(buf, pattern) -> jnp.ndarray:
    """mask[i] = 1 iff buf[i:i+len(pattern)] == pattern (uint8 arrays)."""
    buf = jnp.asarray(buf, dtype=jnp.uint8)
    pattern = jnp.asarray(pattern, dtype=jnp.uint8)
    n, p = buf.size, pattern.size
    if n < p:
        return jnp.zeros((max(n, 0),), dtype=jnp.uint8)
    acc = jnp.ones((n - p + 1,), dtype=bool)
    for j in range(p):
        acc = acc & (buf[j:n - p + 1 + j] == pattern[j])
    # positions whose window would run past the end can never match
    return jnp.concatenate(
        [acc, jnp.zeros((p - 1,), dtype=bool)]).astype(jnp.uint8)
