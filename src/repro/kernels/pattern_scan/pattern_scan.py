"""Pallas kernel: multi-byte pattern scan over uint8 buffers.

TPU adaptation of FastWARC's SIMD bulk scanning (DESIGN.md §4): the VPU is
an (8, 128) vector unit, so a byte-compare sweep maps onto it directly.
For a pattern ``p`` of length P, the match mask is

    mask[i] = AND_{j<P} (buf[i+j] == p[j])

computed as P shifted uint8 compares over a VMEM-resident tile — no
per-byte control flow, which is the whole point: the host parser's
per-record work becomes a handful of wide vector ops.

Blocking: the input is tiled with real blocked ``BlockSpec``s — grid step
``(b, j)`` maps only its ``(1, block)`` tile into VMEM, never the whole
buffer. Match windows crossing a tile's right edge need the next
``P − 1`` bytes; Pallas cannot express overlapping BlockSpecs, so the
wrapper passes an explicit **halo input**: a ``(B, nblocks·MAX_PATTERN)``
matrix whose ``(1, MAX_PATTERN)`` tile for step ``(b, j)`` holds the
bytes just past tile ``j``'s edge. The kernel concatenates tile + halo
and does P shifted compares, all static.

The 2D ``(B, nblocks)`` grid batches many record payloads into one
``pallas_call`` (``find_pattern_mask_batch``): amortized dispatch is how
a shard's worth of delimiter scans becomes a single kernel launch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bucketing import ROWGROUP_PAD

LANES = 128
DEFAULT_BLOCK = 64 * 1024  # 64 KiB tile + halo + mask comfortably < VMEM
MAX_PATTERN = 16
GROUP_BYTES = 1 << 21  # payload bytes per row-group grid step (VMEM budget)
MAX_GROUP = 256


def scan_group_rows(width: int, nrows: int) -> int:
    """Rows per grid step for the row-group scan kernels: the largest
    divisor of ``nrows`` within the VMEM budget. Row counts are half-step
    quantized (m·2^k, m ∈ {1, 3}) by packers, so divisors are dense."""
    g = max(1, min(MAX_GROUP, GROUP_BYTES // max(width, 1)))
    g = min(g, nrows)
    while nrows % g:
        g -= 1
    return g


def _scan_kernel(buf_ref, halo_ref, pat_ref, mask_ref, *,
                 block: int, pat_len: int):
    """One grid step: compare one (1, block) tile against the pattern."""
    # tile plus its right halo: every window starting in the tile is in-bounds
    ext = jnp.concatenate([buf_ref[0, :], halo_ref[0, :]])
    # P shifted static slices — each a wide VPU compare, no per-byte control flow
    acc = ext[0:block] == pat_ref[0]
    for j in range(1, pat_len):  # unrolled: P is static
        acc = jnp.logical_and(acc, ext[j:j + block] == pat_ref[j])
    mask_ref[0, :] = acc.astype(jnp.uint8)


def _scan_kernel_multi(buf_ref, halo_ref, pat_ref, len_ref, mask_ref, *,
                       block: int, max_len: int):
    """One grid step with a *per-row* pattern (cross-request batching).

    ``pat_ref`` holds this row's padded pattern and ``len_ref`` its true
    length; compare positions past the length are forced to match, so
    rows carrying different-length patterns coexist in one dispatch.
    """
    ext = jnp.concatenate([buf_ref[0, :], halo_ref[0, :]])
    plen = len_ref[0, 0]
    acc = ext[0:block] == pat_ref[0, 0]
    for j in range(1, max_len):  # unrolled: max_len is static per dispatch
        hit = ext[j:j + block] == pat_ref[0, j]
        acc = jnp.logical_and(acc, jnp.logical_or(hit, j >= plen))
    mask_ref[0, :] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("max_len", "block", "interpret"))
def pattern_scan_batch_multi(padded_bufs: jax.Array, halos: jax.Array,
                             pattern_mat: jax.Array, pat_lens: jax.Array, *,
                             max_len: int, block: int = DEFAULT_BLOCK,
                             interpret: bool = True) -> jax.Array:
    """Per-row-pattern match masks — **one** dispatch for a mixed batch.

    The cross-request primitive behind ``repro.serve.archive``: rows
    belonging to *different* queries (different patterns, same width
    bucket) share a single ``pallas_call``. ``pattern_mat`` is
    ``(B, MAX_PATTERN)`` uint8 (zero-padded), ``pat_lens`` is ``(B, 1)``
    int32; ``max_len`` bounds the static compare unroll (the longest
    true pattern in the batch). Everything else matches
    :func:`pattern_scan_batch`.
    """
    nrows, width = padded_bufs.shape
    assert width % block == 0, "wrapper must pad to a block multiple"
    nblocks = width // block
    assert halos.shape == (nrows, nblocks * MAX_PATTERN)
    assert pattern_mat.shape == (nrows, MAX_PATTERN)
    assert pat_lens.shape == (nrows, 1)
    kernel = functools.partial(_scan_kernel_multi, block=block,
                               max_len=max_len)
    return pl.pallas_call(
        kernel,
        grid=(nrows, nblocks),
        in_specs=[
            pl.BlockSpec((1, block), lambda b, j: (b, j)),
            pl.BlockSpec((1, MAX_PATTERN), lambda b, j: (b, j)),
            pl.BlockSpec((1, MAX_PATTERN), lambda b, j: (b, 0)),
            pl.BlockSpec((1, 1), lambda b, j: (b, 0)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((nrows, width), jnp.uint8),
        interpret=interpret,
    )(padded_bufs, halos, pattern_mat, pat_lens)


@functools.partial(jax.jit, static_argnames=("pat_len", "block", "interpret"))
def pattern_scan_batch(padded_bufs: jax.Array, halos: jax.Array,
                       pattern_vec: jax.Array, *, pat_len: int,
                       block: int = DEFAULT_BLOCK,
                       interpret: bool = True) -> jax.Array:
    """Match mask over a padded byte matrix (one dispatch for the batch).

    ``padded_bufs`` is ``(B, W)`` uint8 with ``W % block == 0``; ``halos``
    is ``(B, (W // block) · MAX_PATTERN)`` holding each tile's right-edge
    spillover (built by :mod:`.ops`). Returns a ``(B, W)`` uint8 mask.
    """
    nrows, width = padded_bufs.shape
    assert width % block == 0, "wrapper must pad to a block multiple"
    nblocks = width // block
    assert halos.shape == (nrows, nblocks * MAX_PATTERN)
    kernel = functools.partial(_scan_kernel, block=block, pat_len=pat_len)
    return pl.pallas_call(
        kernel,
        grid=(nrows, nblocks),
        in_specs=[
            # blocked specs: each step maps only its tile (+halo), never
            # the full buffer
            pl.BlockSpec((1, block), lambda b, j: (b, j)),
            pl.BlockSpec((1, MAX_PATTERN), lambda b, j: (b, j)),
            pl.BlockSpec(pattern_vec.shape, lambda b, j: (0,)),
        ],
        out_specs=pl.BlockSpec((1, block), lambda b, j: (b, j)),
        out_shape=jax.ShapeDtypeStruct((nrows, width), jnp.uint8),
        interpret=interpret,
    )(padded_bufs, halos, pattern_vec)


def _scan_kernel_group(buf_ref, pat_ref, mask_ref, *, width: int,
                       pat_len: int):
    """One grid step: compare a (G, width + ROWGROUP_PAD) row group.

    The zero right-pad (≥ MAX_PATTERN) replaces the halo input of the
    blocked kernel: every window starting inside a row is in-bounds in
    the tile, spilled windows compare against zeros and lose (packers
    reject all-zero patterns). P shifted compares over the whole group —
    one grid step per G rows instead of per (row, block), which is what
    makes full-corpus columnar scans cheap: per-step dispatch overhead
    is amortized over megabytes, not one 64 KiB tile.
    """
    ext = buf_ref[:, :]
    acc = ext[:, 0:width] == pat_ref[0]
    for j in range(1, pat_len):  # unrolled: P is static
        acc = jnp.logical_and(acc, ext[:, j:j + width] == pat_ref[j])
    mask_ref[:, :] = acc.astype(jnp.uint8)


def _scan_kernel_group_multi(buf_ref, pat_ref, len_ref, mask_ref, *,
                             width: int, max_len: int):
    """Row-group step with a per-row pattern (mixed-query batching)."""
    ext = buf_ref[:, :]
    plen = len_ref[:, :]                       # (G, 1) broadcasts over width
    acc = ext[:, 0:width] == pat_ref[:, 0:1]
    for j in range(1, max_len):  # unrolled: max_len is static per dispatch
        hit = ext[:, j:j + width] == pat_ref[:, j:j + 1]
        acc = jnp.logical_and(acc, jnp.logical_or(hit, j >= plen))
    mask_ref[:, :] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("pat_len", "interpret"))
def pattern_scan_rowgroup(matrix: jax.Array, pattern_vec: jax.Array, *,
                          pat_len: int, interpret: bool = True) -> jax.Array:
    """Match mask over a packed row-group matrix — grouped-rows grid.

    ``matrix`` is ``(B, width + ROWGROUP_PAD)`` uint8 in the shared
    row-group layout (:mod:`repro.kernels.bucketing`): payload bytes
    left-justified, zero tail. No halo input — the zero tail bounds
    every window. Returns a ``(B, width)`` uint8 mask (positions past
    each row's true length must be trimmed by the caller).
    """
    nrows, padded_width = matrix.shape
    width = padded_width - ROWGROUP_PAD
    assert width > 0, "matrix must carry the ROWGROUP_PAD zero tail"
    assert 0 < pat_len <= MAX_PATTERN
    group = scan_group_rows(width, nrows)
    kernel = functools.partial(_scan_kernel_group, width=width,
                               pat_len=pat_len)
    return pl.pallas_call(
        kernel,
        grid=(nrows // group,),
        in_specs=[
            pl.BlockSpec((group, padded_width), lambda g: (g, 0)),
            pl.BlockSpec(pattern_vec.shape, lambda g: (0,)),
        ],
        out_specs=pl.BlockSpec((group, width), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, width), jnp.uint8),
        interpret=interpret,
    )(matrix, pattern_vec)


@functools.partial(jax.jit, static_argnames=("max_len", "interpret"))
def pattern_scan_rowgroup_multi(matrix: jax.Array, pattern_mat: jax.Array,
                                pat_lens: jax.Array, *, max_len: int,
                                interpret: bool = True) -> jax.Array:
    """Per-row-pattern match masks over a packed row-group matrix.

    The columnar twin of :func:`pattern_scan_batch_multi`: rows carrying
    different patterns (different queries) share one grouped dispatch.
    ``pattern_mat`` is ``(B, MAX_PATTERN)`` uint8 zero-padded,
    ``pat_lens`` ``(B, 1)`` int32; compare positions past a row's true
    pattern length are forced to match.
    """
    nrows, padded_width = matrix.shape
    width = padded_width - ROWGROUP_PAD
    assert width > 0, "matrix must carry the ROWGROUP_PAD zero tail"
    assert pattern_mat.shape == (nrows, MAX_PATTERN)
    assert pat_lens.shape == (nrows, 1)
    assert 0 < max_len <= MAX_PATTERN
    group = scan_group_rows(width, nrows)
    kernel = functools.partial(_scan_kernel_group_multi, width=width,
                               max_len=max_len)
    return pl.pallas_call(
        kernel,
        grid=(nrows // group,),
        in_specs=[
            pl.BlockSpec((group, padded_width), lambda g: (g, 0)),
            pl.BlockSpec((group, MAX_PATTERN), lambda g: (g, 0)),
            pl.BlockSpec((group, 1), lambda g: (g, 0)),
        ],
        out_specs=pl.BlockSpec((group, width), lambda g: (g, 0)),
        out_shape=jax.ShapeDtypeStruct((nrows, width), jnp.uint8),
        interpret=interpret,
    )(matrix, pattern_mat, pat_lens)
