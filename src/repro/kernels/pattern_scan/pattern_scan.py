"""Pallas kernel: multi-byte pattern scan over uint8 buffers.

TPU adaptation of FastWARC's SIMD bulk scanning (DESIGN.md §4): the VPU is
an (8, 128) vector unit, so a byte-compare sweep maps onto it directly.
For a pattern ``p`` of length P, the match mask is

    mask[i] = AND_{j<P} (buf[i+j] == p[j])

computed as P shifted uint8 compares over a VMEM-resident chunk — no
per-byte control flow, which is the whole point: the host parser's
per-record work becomes a handful of wide vector ops.

Blocking: the buffer is processed in chunks of ``block`` bytes reshaped to
(block // 128, 128) so the lane dimension is hardware-native. Each grid
step loads its chunk plus a (P-1)-byte halo from the padded input (the
wrapper pads; overlapping loads are expressed with ``pl.ds`` on a full
VMEM ref rather than overlapping BlockSpecs, which Pallas cannot express).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128
DEFAULT_BLOCK = 64 * 1024  # 64 KiB chunk + halo + mask comfortably < VMEM
MAX_PATTERN = 16


def _scan_kernel(buf_ref, pat_ref, mask_ref, *, block: int, pat_len: int):
    """One grid step: compare `block` positions against the pattern."""
    i = pl.program_id(0)
    start = i * block
    # P shifted block loads (the halo makes the last shift in-bounds);
    # each is a wide VPU compare — per-byte control flow never happens
    acc = buf_ref[pl.ds(start, block)] == pat_ref[0]
    for j in range(1, pat_len):  # unrolled: P is static
        acc = jnp.logical_and(
            acc, buf_ref[pl.ds(start + j, block)] == pat_ref[j])
    mask_ref[pl.ds(start, block)] = acc.astype(jnp.uint8)


@functools.partial(jax.jit, static_argnames=("pat_len", "block", "interpret"))
def pattern_scan(padded_buf: jax.Array, pattern_vec: jax.Array, *,
                 pat_len: int, block: int = DEFAULT_BLOCK,
                 interpret: bool = True) -> jax.Array:
    """Match mask over ``padded_buf`` (uint8, padded to block + MAX_PATTERN).

    Returns uint8 mask of length ``padded_buf.size - MAX_PATTERN``.
    Callers use :mod:`.ops`, which handles padding and trimming.
    """
    n = padded_buf.size - MAX_PATTERN
    assert n % block == 0, "wrapper must pad to a block multiple"
    grid = (n // block,)
    kernel = functools.partial(_scan_kernel, block=block, pat_len=pat_len)
    return pl.pallas_call(
        kernel,
        grid=grid,
        # full-array specs: the kernel slices its own (overlapping) windows
        in_specs=[
            pl.BlockSpec(padded_buf.shape, lambda i: (0,)),
            pl.BlockSpec(pattern_vec.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((n,), lambda i: (0,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.uint8),
        interpret=interpret,
    )(padded_buf, pattern_vec)
