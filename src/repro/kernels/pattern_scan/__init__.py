from .ops import (
    count_matches,
    find_pattern_mask,
    find_pattern_mask_batch,
    find_pattern_mask_rowgroup,
    find_pattern_masks_multi,
    find_pattern_masks_multi_rowgroup,
    find_pattern_positions,
)

__all__ = ["find_pattern_mask", "find_pattern_mask_batch",
           "find_pattern_mask_rowgroup", "find_pattern_masks_multi",
           "find_pattern_masks_multi_rowgroup", "find_pattern_positions",
           "count_matches"]
