from .ops import find_pattern_mask, find_pattern_positions, count_matches

__all__ = ["find_pattern_mask", "find_pattern_positions", "count_matches"]
