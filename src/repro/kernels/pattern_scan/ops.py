"""Public jit'd wrappers for the pattern-scan kernel."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .pattern_scan import DEFAULT_BLOCK, MAX_PATTERN, pattern_scan


def _prepare(buf, pattern, block: int):
    buf = np.frombuffer(bytes(buf), dtype=np.uint8) if isinstance(
        buf, (bytes, bytearray, memoryview)) else np.asarray(buf, np.uint8)
    pat = np.frombuffer(bytes(pattern), dtype=np.uint8) if isinstance(
        pattern, (bytes, bytearray, memoryview)) else np.asarray(pattern, np.uint8)
    if not 0 < pat.size <= MAX_PATTERN:
        raise ValueError(f"pattern length must be in [1, {MAX_PATTERN}]")
    n = buf.size
    padded_n = max(((n + block - 1) // block) * block, block)
    padded = np.zeros(padded_n + MAX_PATTERN, dtype=np.uint8)
    padded[:n] = buf
    # zero-pad never false-positives: pattern bytes are non-zero in WARC use;
    # all-zero patterns are rejected to keep that invariant
    if not pat.any():
        raise ValueError("all-zero patterns are not supported")
    pad_vec = np.zeros(MAX_PATTERN, dtype=np.uint8)
    pad_vec[:pat.size] = pat
    return jnp.asarray(padded), jnp.asarray(pad_vec), int(pat.size), n


def find_pattern_mask(buf, pattern, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = True):
    """uint8 match mask (same length as ``buf``)."""
    padded, pat_vec, plen, n = _prepare(buf, pattern, block)
    mask = pattern_scan(padded, pat_vec, pat_len=plen, block=block,
                        interpret=interpret)
    mask = np.array(mask[:n])  # own the buffer: device arrays are read-only
    # matches that would read past the true end are padding artifacts
    if plen > 1 and n >= plen:
        mask[n - plen + 1:] = 0
    elif n < plen:
        mask[:] = 0
    return mask


def find_pattern_positions(buf, pattern, **kw) -> np.ndarray:
    """Sorted match start offsets (host-side compaction of the mask)."""
    return np.flatnonzero(find_pattern_mask(buf, pattern, **kw))


def count_matches(buf, pattern, **kw) -> int:
    return int(find_pattern_mask(buf, pattern, **kw).sum())
