"""Public jit'd wrappers for the pattern-scan kernel.

``find_pattern_mask`` scans one buffer; ``find_pattern_mask_batch`` packs
a ragged batch of payloads into padded byte matrices and issues one
``(B, nblocks)``-gridded dispatch per power-of-two **width bucket**
(parity with ``adler32_batch``): a uniform batch costs a single dispatch,
repeated ragged batches reuse a handful of compiled shapes instead of
recompiling per max-length, and one giant outlier cannot inflate every
row to its width. Both wrappers build the explicit halo input the
blocked kernel needs (see :mod:`.pattern_scan`).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.bucketing import (
    ROWGROUP_PAD,
    as_u8 as _as_u8,
    bucket_width,
    quantize_count,
)
from repro.obs.kernels import record_dispatch
from .pattern_scan import (
    DEFAULT_BLOCK,
    MAX_PATTERN,
    pattern_scan_batch,
    pattern_scan_batch_multi,
    pattern_scan_rowgroup,
    pattern_scan_rowgroup_multi,
)

__all__ = ["find_pattern_mask", "find_pattern_mask_batch",
           "find_pattern_mask_rowgroup", "find_pattern_masks_multi",
           "find_pattern_masks_multi_rowgroup", "find_pattern_positions",
           "count_matches"]


def _check_pattern(pattern) -> tuple[np.ndarray, int]:
    pat = _as_u8(pattern)
    if not 0 < pat.size <= MAX_PATTERN:
        raise ValueError(f"pattern length must be in [1, {MAX_PATTERN}]")
    # zero-pad never false-positives: pattern bytes are non-zero in WARC use;
    # all-zero patterns are rejected to keep that invariant
    if not pat.any():
        raise ValueError("all-zero patterns are not supported")
    pad_vec = np.zeros(MAX_PATTERN, dtype=np.uint8)
    pad_vec[:pat.size] = pat
    return pad_vec, int(pat.size)


def _pack(bufs: list[np.ndarray], block: int, width: int
          ) -> tuple[np.ndarray, np.ndarray]:
    """Stack ragged buffers into (B, width) plus each tile's right-edge halo."""
    nblocks = width // block
    # W + MAX_PATTERN scratch so every halo gather is in-bounds (zeros there)
    ext = np.zeros((len(bufs), width + MAX_PATTERN), dtype=np.uint8)
    for i, buf in enumerate(bufs):
        ext[i, :buf.size] = buf
    # halo for tile j = bytes [ (j+1)·block , (j+1)·block + MAX_PATTERN )
    gather = ((np.arange(nblocks)[:, None] + 1) * block
              + np.arange(MAX_PATTERN)[None, :])        # (nblocks, MP)
    halos = ext[:, gather.reshape(-1)]                  # (B, nblocks·MP)
    return ext[:, :width], halos


def _pad_rows(n: int) -> int:
    """Row-count bucket: half-step quantized (1, 2, 3, 4, 6, 8, 12, …),
    so repeated ragged batches reuse a bounded set of compiled ``(B, W)``
    shapes along B as well as W while row padding stays ≤ 1.5× (pad rows
    are all-zero buffers; their masks are discarded)."""
    return quantize_count(n)


def _trim(mask_row: np.ndarray, n: int, plen: int) -> np.ndarray:
    out = np.array(mask_row[:n])  # own the buffer: device arrays are read-only
    # matches that would read past the true end are padding artifacts
    if plen > 1 and n >= plen:
        out[n - plen + 1:] = 0
    elif n < plen:
        out[:] = 0
    return out


def find_pattern_mask_batch(bufs, pattern, *, block: int = DEFAULT_BLOCK,
                            interpret: bool = True) -> list[np.ndarray]:
    """uint8 match masks for a ragged batch — few kernel dispatches.

    Returns one mask per input, each the same length as its buffer.
    Inputs are grouped into power-of-two width buckets — one
    ``(B, nblocks)``-gridded call per bucket — so a uniform batch is a
    single dispatch and ragged query batches hit a bounded set of
    compiled shapes (padding waste ≤ 2× per row).
    """
    pat_vec, plen = _check_pattern(pattern)
    arrs = [_as_u8(b) for b in bufs]
    if not arrs:
        return []
    out: list = [None] * len(arrs)
    buckets: dict[int, list[int]] = {}
    for i, arr in enumerate(arrs):
        buckets.setdefault(bucket_width(arr.size, block), []).append(i)
    empty = np.empty(0, np.uint8)
    for width, idxs in buckets.items():
        rows = [arrs[i] for i in idxs]
        rows += [empty] * (_pad_rows(len(rows)) - len(rows))
        padded, halos = _pack(rows, block, width)
        record_dispatch("find_pattern_mask_batch", width=width,
                        rows=len(idxs), padded_rows=len(rows),
                        useful_bytes=sum(arrs[i].size for i in idxs))
        masks = pattern_scan_batch(jnp.asarray(padded), jnp.asarray(halos),
                                   jnp.asarray(pat_vec), pat_len=plen,
                                   block=block, interpret=interpret)
        masks = np.asarray(masks)
        for row, i in enumerate(idxs):
            out[i] = _trim(masks[row], arrs[i].size, plen)
    return out


def find_pattern_masks_multi(bufs, patterns, *, block: int = DEFAULT_BLOCK,
                             interpret: bool = True) -> list[np.ndarray]:
    """Match masks for a ragged batch where **each row has its own
    pattern** — the cross-request batching entry point.

    ``patterns[i]`` scans ``bufs[i]``; rows from different queries that
    land in the same power-of-two width bucket share one
    ``pattern_scan_batch_multi`` dispatch (the unroll bound is the
    bucket's longest pattern). Same bucketing/trim semantics as
    :func:`find_pattern_mask_batch`, so for equal patterns the two are
    interchangeable.
    """
    if len(bufs) != len(patterns):
        raise ValueError("bufs and patterns must pair up")
    arrs = [_as_u8(b) for b in bufs]
    pats: list[np.ndarray] = []
    plens: list[int] = []
    for p in patterns:
        vec, n = _check_pattern(p)
        pats.append(vec)
        plens.append(n)
    if not arrs:
        return []
    out: list = [None] * len(arrs)
    buckets: dict[int, list[int]] = {}
    for i, arr in enumerate(arrs):
        buckets.setdefault(bucket_width(arr.size, block), []).append(i)
    empty = np.empty(0, np.uint8)
    pad_pat = np.zeros(MAX_PATTERN, np.uint8)
    pad_pat[0] = 1  # inert: never matches an all-zero pad row
    for width, idxs in buckets.items():
        rows = [arrs[i] for i in idxs]
        n_pad = _pad_rows(len(rows)) - len(rows)
        rows += [empty] * n_pad
        padded, halos = _pack(rows, block, width)
        pat_mat = np.stack([pats[i] for i in idxs] + [pad_pat] * n_pad)
        lens = np.asarray([[plens[i]] for i in idxs] + [[1]] * n_pad,
                          np.int32)
        record_dispatch("find_pattern_masks_multi", width=width,
                        rows=len(idxs), padded_rows=len(rows),
                        useful_bytes=sum(arrs[i].size for i in idxs))
        masks = pattern_scan_batch_multi(
            jnp.asarray(padded), jnp.asarray(halos), jnp.asarray(pat_mat),
            jnp.asarray(lens), max_len=max(plens[i] for i in idxs),
            block=block, interpret=interpret)
        masks = np.asarray(masks)
        for row, i in enumerate(idxs):
            out[i] = _trim(masks[row], arrs[i].size, plens[i])
    return out


def _trim_rows(masks: np.ndarray, lengths: np.ndarray, plens) -> np.ndarray:
    """Vectorized :func:`_trim` over row-group masks: zero every position
    whose match window would read past its row's true length."""
    width = masks.shape[1]
    last = np.maximum(lengths[:, None] - np.asarray(plens).reshape(-1, 1) + 1,
                      0)
    return np.where(np.arange(width)[None, :] < last, masks, 0)


def find_pattern_mask_rowgroup(matrix, lengths, pattern, *,
                               interpret: bool = True,
                               trim: bool = True) -> np.ndarray:
    """Match masks over an **already-packed row-group** — one dispatch.

    The columnar scan entry point: ``matrix`` is ``(B, width +
    ROWGROUP_PAD)`` uint8 in the shared row-group layout (typically a
    zero-copy mmap view of a columnar shard), ``lengths`` the true
    payload lengths of the first ``len(lengths)`` rows (trailing rows
    are padding). No per-payload copy, re-bucketing, or halo build —
    the packing cost was paid once at derive time. Returns a
    ``(live, width)`` uint8 mask, trimmed per row exactly like
    :func:`find_pattern_mask_batch` trims its outputs.

    ``trim=False`` skips the per-row trim and hands back the raw
    kernel output (a read-only view of the device buffer): positions
    past ``length - len(pattern) + 1`` may carry padding artifacts the
    caller must filter out. The column-scan hot path does exactly that
    on the compacted hit list, saving the full-matrix where-copy.
    """
    pat_vec, plen = _check_pattern(pattern)
    mat = np.ascontiguousarray(matrix, np.uint8)
    nrows, padded_width = mat.shape
    width = padded_width - ROWGROUP_PAD
    if width <= 0:
        raise ValueError("matrix must carry the ROWGROUP_PAD zero tail")
    lengths = np.asarray(lengths, np.int64)
    live = lengths.size
    if not 0 < live <= nrows:
        raise ValueError(f"need 1 <= live rows <= {nrows}, got {live}")
    record_dispatch("find_pattern_mask_rowgroup", width=width, rows=live,
                    padded_rows=nrows, useful_bytes=int(lengths.sum()))
    masks = pattern_scan_rowgroup(jnp.asarray(mat), jnp.asarray(pat_vec),
                                  pat_len=plen, interpret=interpret)
    if not trim:
        return np.asarray(masks)[:live]
    return _trim_rows(np.asarray(masks)[:live], lengths, plen)


def find_pattern_masks_multi_rowgroup(matrix, lengths, patterns, *,
                                      interpret: bool = True) -> np.ndarray:
    """Per-row-pattern masks over a packed row-group — one dispatch.

    ``patterns[i]`` scans row ``i``; rows from different queries share
    the single grouped dispatch (unroll bound = longest true pattern).
    Same layout/trim semantics as :func:`find_pattern_mask_rowgroup`.
    """
    lengths = np.asarray(lengths, np.int64)
    live = lengths.size
    if live != len(patterns):
        raise ValueError("lengths and patterns must pair up")
    mat = np.ascontiguousarray(matrix, np.uint8)
    nrows, padded_width = mat.shape
    width = padded_width - ROWGROUP_PAD
    if width <= 0:
        raise ValueError("matrix must carry the ROWGROUP_PAD zero tail")
    if not 0 < live <= nrows:
        raise ValueError(f"need 1 <= live rows <= {nrows}, got {live}")
    pats, plens = zip(*(_check_pattern(p) for p in patterns))
    pad_pat = np.zeros(MAX_PATTERN, np.uint8)
    pad_pat[0] = 1  # inert: never matches an all-zero pad row
    pat_mat = np.stack(list(pats) + [pad_pat] * (nrows - live))
    lens = np.asarray([[n] for n in plens] + [[1]] * (nrows - live),
                      np.int32)
    record_dispatch("find_pattern_masks_multi_rowgroup", width=width,
                    rows=live, padded_rows=nrows,
                    useful_bytes=int(lengths.sum()))
    masks = pattern_scan_rowgroup_multi(
        jnp.asarray(mat), jnp.asarray(pat_mat), jnp.asarray(lens),
        max_len=max(plens), interpret=interpret)
    return _trim_rows(np.asarray(masks)[:live], lengths, np.asarray(plens))


def find_pattern_mask(buf, pattern, *, block: int = DEFAULT_BLOCK,
                      interpret: bool = True):
    """uint8 match mask (same length as ``buf``)."""
    return find_pattern_mask_batch([buf], pattern, block=block,
                                   interpret=interpret)[0]


def find_pattern_positions(buf, pattern, **kw) -> np.ndarray:
    """Sorted match start offsets (host-side compaction of the mask)."""
    return np.flatnonzero(find_pattern_mask(buf, pattern, **kw))


def count_matches(buf, pattern, **kw) -> int:
    return int(find_pattern_mask(buf, pattern, **kw).sum())
