"""Pure-jnp oracle: exact (materialized-scores) GQA attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def attention_ref(q, k, v, *, causal: bool = True) -> jnp.ndarray:
    """q [B,H,Sq,D], k/v [B,Hkv,Sk,D] -> [B,H,Sq,D], fp32 math."""
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    group = H // Hkv
    q = q.astype(jnp.float32)
    k = jnp.repeat(k.astype(jnp.float32), group, axis=1)
    v = jnp.repeat(v.astype(jnp.float32), group, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, k) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((Sq, Sk), dtype=bool), k=Sk - Sq)
        s = jnp.where(mask, s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = p / p.sum(axis=-1, keepdims=True)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v)
