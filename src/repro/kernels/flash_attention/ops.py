"""Public wrapper for the flash-attention kernel.

Dispatch policy (used by the model layer):
* interpret-mode Pallas on CPU for correctness work and tests;
* on TPU (not this container) the same `pallas_call` lowers natively;
* ``use_kernel=False`` falls back to the jnp reference (the dry-run uses
  this path so XLA's cost model sees the attention FLOPs explicitly).
"""
from __future__ import annotations

import jax

from .flash_attention import (
    DEFAULT_BLOCK_K,
    DEFAULT_BLOCK_Q,
    flash_attention_bhsd,
)
from .ref import attention_ref


def flash_attention(q, k, v, *, causal: bool = True,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    use_kernel: bool = True,
                    interpret: bool = True) -> jax.Array:
    """GQA attention: q [B,H,Sq,D], k/v [B,Hkv,Sk,D] -> [B,H,Sq,D]."""
    if not use_kernel:
        return attention_ref(q, k, v, causal=causal).astype(q.dtype)
    Sq, Sk = q.shape[2], k.shape[2]
    bq = min(block_q, Sq)
    bk = min(block_k, Sk)
    if Sq % bq or Sk % bk:
        # shapes in this framework are pre-padded; tiny test shapes fall back
        return attention_ref(q, k, v, causal=causal).astype(q.dtype)
    return flash_attention_bhsd(q, k, v, causal=causal, block_q=bq,
                                block_k=bk, interpret=interpret)
