"""Pallas kernel: blocked GQA flash attention (training/serving hot-spot).

TPU-blocked online-softmax attention in the FlashAttention-2 style
[arXiv:2307.08691], restructured for the TPU grid model: the KV-block loop
is the *innermost grid dimension* and the running max / denominator /
accumulator live in VMEM scratch that persists across those grid steps
(the canonical Pallas-TPU pattern — revisit the same output block, carry
state, finalize on the last step). MXU alignment: block sizes are
multiples of 128 on the matmul dims.

GQA: ``q`` has H heads, ``k``/``v`` have Hkv ≤ H heads; the BlockSpec
index maps query-head h to kv-head h // (H // Hkv) — grouped heads read
the same KV block, which on hardware amortizes KV HBM reads across the
group (the GQA bandwidth win).

Causal masking: KV blocks strictly above the diagonal are skipped with
``pl.when`` (no FLOPs, no loads in the skipped branch on hardware); the
diagonal block is masked with broadcasted iotas.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  kv_offset: int):
    """Grid = (batch, q_heads, num_q_blocks, num_k_blocks); innermost = kv.

    ``kv_offset = Sk - Sq`` aligns the causal diagonal when the KV side is
    longer than the query side (decode with a cache).
    """
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * block_q
    k_start = ki * block_k

    def _body():
        q = q_ref[0, 0].astype(jnp.float32) * scale              # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)                      # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)                      # [bk, d]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())))  # [bq, bk]
        if causal:
            rows = (q_start + kv_offset
                    + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0))
            cols = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, NEG_INF)
        m_prev = m_scr[...]                                      # [bq]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])                          # [bq, bk]
        corr = jnp.exp(m_prev - m_new)                           # [bq]
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())))
        m_scr[...] = m_new

    if causal:
        # skip KV blocks entirely above the (offset) diagonal
        @pl.when(k_start <= q_start + kv_offset + block_q - 1)
        def _run():
            _body()
    else:
        _body()

    @pl.when(ki == nk - 1)
    def _finalize():
        l = l_scr[...]
        safe_l = jnp.where(l == 0.0, 1.0, l)  # fully-masked row guard
        o_ref[0, 0] = (acc_scr[...] / safe_l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "interpret"))
def flash_attention_bhsd(
    q: jax.Array,  # [B, H, Sq, D]
    k: jax.Array,  # [B, Hkv, Sk, D]
    v: jax.Array,  # [B, Hkv, Sk, D]
    *,
    causal: bool = True,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, H, Sq, D = q.shape
    _, Hkv, Sk, _ = k.shape
    assert H % Hkv == 0, "q heads must be a multiple of kv heads (GQA)"
    assert Sq % block_q == 0 and Sk % block_k == 0, "pad seqs to block size"
    group = H // Hkv
    scale = 1.0 / math.sqrt(D)
    grid = (B, H, Sq // block_q, Sk // block_k)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal,
        block_q=block_q, block_k=block_k, kv_offset=Sk - Sq)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki: (b, h // group, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running denominator
            pltpu.VMEM((block_q, D), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
