from .ops import adler32, adler32_batch

__all__ = ["adler32", "adler32_batch"]
