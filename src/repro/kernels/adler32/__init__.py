from .ops import adler32

__all__ = ["adler32"]
