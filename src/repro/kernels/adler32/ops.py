"""Public wrappers: Adler-32 of byte buffers via the Pallas kernel.

``adler32`` checksums one buffer; ``adler32_batch`` stacks a ragged batch
of payloads into one ``(B, W)`` matrix and issues a *single* gridded
``pallas_call`` — N record checksums for one dispatch (DESIGN.md §4).
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.bucketing import as_u8 as _as_u8, bucket_width
from repro.obs.kernels import record_dispatch
from .adler32 import BLOCK, MOD, adler32_partials_batch

__all__ = ["adler32", "adler32_batch", "combine_partials"]


def combine_partials(s: np.ndarray, t: np.ndarray, lengths: np.ndarray,
                     block: int) -> np.ndarray:
    """Host-side reduction of per-block partials to final checksums.

    Zero padding contributes nothing to S or T, so full-row sums with each
    row's *true* length are exact for every ragged entry. Shared with the
    fused ``digest_signature_batch`` wrapper, whose kernel emits the same
    ``(S, T)`` partial layout.
    """
    s = s.astype(np.int64)
    t = t.astype(np.int64)
    offsets = np.arange(s.shape[1], dtype=np.int64) * block   # o_j
    n = lengths.astype(np.int64)[:, None]                     # (B, 1)
    a = (1 + s.sum(axis=1)) % MOD
    b = (n[:, 0] + ((n - offsets) * s - t).sum(axis=1)) % MOD
    out = ((b << 16) | a).astype(np.uint32)
    out[lengths == 0] = 1  # adler32(b"") == 1
    return out


def adler32_batch(payloads, *, block: int = BLOCK,
                  interpret: bool = True) -> np.ndarray:
    """Adler-32 of every payload in a ragged batch (few kernel dispatches).

    Returns a uint32 array matching ``zlib.adler32`` entry-wise. Payloads
    are zero-padded and grouped into power-of-two width buckets — one
    ``(B, nblocks)``-gridded call per bucket — so a uniform batch costs a
    single dispatch while one giant outlier cannot inflate every row to
    its width (padding waste is bounded at 2× per row, not B × max_len).
    """
    bufs = [_as_u8(p) for p in payloads]
    nrows = len(bufs)
    if nrows == 0:
        return np.empty(0, np.uint32)
    out = np.empty(nrows, np.uint32)
    buckets: dict[int, list[int]] = {}
    for i, buf in enumerate(bufs):
        buckets.setdefault(bucket_width(buf.size, block), []).append(i)
    for width, idxs in buckets.items():
        padded = np.zeros((len(idxs), width), dtype=np.uint8)
        for row, i in enumerate(idxs):
            padded[row, :bufs[i].size] = bufs[i]
        lengths = np.asarray([bufs[i].size for i in idxs], np.int64)
        record_dispatch("adler32_batch", width=width, rows=len(idxs),
                        padded_rows=len(idxs),
                        useful_bytes=int(lengths.sum()))
        s, t = adler32_partials_batch(jnp.asarray(padded), block=block,
                                      interpret=interpret)
        out[idxs] = combine_partials(np.asarray(s), np.asarray(t), lengths,
                                     block)
    return out


def adler32(data, *, block: int = BLOCK, interpret: bool = True) -> int:
    """Adler-32 checksum (matches ``zlib.adler32``)."""
    buf = _as_u8(data)
    if buf.size == 0:
        return 1
    return int(adler32_batch([buf], block=block, interpret=interpret)[0])
