"""Public wrapper: Adler-32 of arbitrary byte buffers via the Pallas kernel."""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from .adler32 import BLOCK, MOD, adler32_partials


def adler32(data, *, block: int = BLOCK, interpret: bool = True) -> int:
    """Adler-32 checksum (matches ``zlib.adler32``)."""
    buf = np.frombuffer(bytes(data), dtype=np.uint8) if isinstance(
        data, (bytes, bytearray, memoryview)) else np.asarray(data, np.uint8)
    n = buf.size
    if n == 0:
        return 1
    padded_n = ((n + block - 1) // block) * block
    padded = np.zeros(padded_n, dtype=np.uint8)
    padded[:n] = buf  # zero padding contributes nothing to either sum
    s, t = adler32_partials(jnp.asarray(padded), block=block)
    s = np.asarray(s, dtype=np.int64)
    t = np.asarray(t, dtype=np.int64)
    offsets = np.arange(s.size, dtype=np.int64) * block
    a = (1 + s.sum()) % MOD
    b = (n + ((n - offsets) * s - t).sum()) % MOD
    return int((b << 16) | a)
