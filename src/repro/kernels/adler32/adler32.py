"""Pallas kernel: Adler-32 rolling checksum as blocked reductions.

The TPU-side record checksum (DESIGN.md §4). CRC-32's per-bit feedback
loop has no VPU mapping, but Adler-32 — zlib's other checksum —
decomposes into two reductions. With ``b`` the bytes and n = len(b):

    A = 1 + Σ b_i                      (mod 65521)
    B = n + Σ (n - i) · b_i            (mod 65521, i zero-based)

Per block j at offset o_j of length L, the kernel emits

    S_j = Σ_t b_{o_j+t}              (plain sum)
    T_j = Σ_t t · b_{o_j+t}          (dot with iota)

and the wrapper combines: B = n + Σ_j [(n − o_j)·S_j − T_j]  (mod 65521).

Block length 2048 keeps T_j < 2³¹ in int32 (2048·2047/2·255 ≈ 5.3e8), so
the kernel needs no in-loop modulo; the wrapper reduces in int64 once.
The byte sum and the iota dot both vectorize across the (8, 128) VPU.

**Batched dispatch**: record payloads are stacked into a ``(B, W)`` byte
matrix (rows zero-padded — zero bytes contribute nothing to either sum)
and the kernel runs on a ``(B, nblocks)`` grid with *blocked*
``BlockSpec``s: grid step ``(b, j)`` sees only its ``(1, block)`` tile —
never the whole buffer — and writes one ``(1, 1)`` partial per output.
One ``pallas_call`` checksums an entire batch of records, which is how
the bulk digest-verification path amortizes dispatch overhead across a
WARC shard instead of paying it per record.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048
MOD = 65521


def _adler_kernel(buf_ref, s_ref, t_ref, *, block: int):
    # buf_ref is one (1, block) tile of the batch; outputs are (1, 1)
    chunk = buf_ref[0, :].astype(jnp.int32)
    iota = jax.lax.iota(jnp.int32, block)
    s_ref[0, 0] = jnp.sum(chunk)
    t_ref[0, 0] = jnp.sum(chunk * iota)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adler32_partials_batch(padded_bufs: jax.Array, *, block: int = BLOCK,
                           interpret: bool = True
                           ) -> tuple[jax.Array, jax.Array]:
    """Per-(row, block) ``(S, T)`` int32 partials over a padded byte matrix.

    ``padded_bufs`` is ``(B, W)`` uint8 with ``W % block == 0``; returns two
    ``(B, W // block)`` arrays. One call covers the whole batch.
    """
    nrows, width = padded_bufs.shape
    assert width % block == 0
    nblocks = width // block
    kernel = functools.partial(_adler_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nrows, nblocks),
        in_specs=[pl.BlockSpec((1, block), lambda b, j: (b, j))],
        out_specs=[
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),
            pl.BlockSpec((1, 1), lambda b, j: (b, j)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nrows, nblocks), jnp.int32),
            jax.ShapeDtypeStruct((nrows, nblocks), jnp.int32),
        ],
        interpret=interpret,
    )(padded_bufs)
