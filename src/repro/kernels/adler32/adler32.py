"""Pallas kernel: Adler-32 rolling checksum as blocked reductions.

The TPU-side record checksum (DESIGN.md §4). CRC-32's per-bit feedback
loop has no VPU mapping, but Adler-32 — zlib's other checksum —
decomposes into two reductions. With ``b`` the bytes and n = len(b):

    A = 1 + Σ b_i                      (mod 65521)
    B = n + Σ (n - i) · b_i            (mod 65521, i zero-based)

Per block j at offset o_j of length L, the kernel emits

    S_j = Σ_t b_{o_j+t}              (plain sum)
    T_j = Σ_t t · b_{o_j+t}          (dot with iota)

and the wrapper combines: B = n + Σ_j [(n − o_j)·S_j − T_j]  (mod 65521).

Block length 2048 keeps T_j < 2³¹ in int32 (2048·2047/2·255 ≈ 5.3e8), so
the kernel needs no in-loop modulo; the wrapper reduces in int64 once.
The byte sum and the iota dot both vectorize across the (8, 128) VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 2048
MOD = 65521


def _adler_kernel(buf_ref, s_ref, t_ref, *, block: int):
    i = pl.program_id(0)
    chunk = buf_ref[pl.ds(i * block, block)].astype(jnp.int32)
    iota = jax.lax.iota(jnp.int32, block)
    s_ref[i] = jnp.sum(chunk)
    t_ref[i] = jnp.sum(chunk * iota)


@functools.partial(jax.jit, static_argnames=("block", "interpret"))
def adler32_partials(padded_buf: jax.Array, *, block: int = BLOCK,
                     interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Per-block (S_j, T_j) int32 partial sums over a block-padded buffer."""
    n = padded_buf.size
    assert n % block == 0
    nblocks = n // block
    kernel = functools.partial(_adler_kernel, block=block)
    return pl.pallas_call(
        kernel,
        grid=(nblocks,),
        in_specs=[pl.BlockSpec(padded_buf.shape, lambda i: (0,))],
        out_specs=[
            pl.BlockSpec((nblocks,), lambda i: (0,)),
            pl.BlockSpec((nblocks,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nblocks,), jnp.int32),
            jax.ShapeDtypeStruct((nblocks,), jnp.int32),
        ],
        interpret=interpret,
    )(padded_buf)
