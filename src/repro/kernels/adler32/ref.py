"""Oracles for the Adler-32 kernel: zlib's C implementation + pure jnp.

The jnp oracle deliberately uses uint32 modular arithmetic — TPUs (and
JAX's default x64-disabled mode) have no int64, so this is also the
arithmetic a hardware deployment would use: 65521² = 4.293e9 just fits
uint32, so one modulo per block keeps every intermediate in range.
"""
from __future__ import annotations

import zlib

import jax.numpy as jnp

MOD = 65521
_BLOCK = 2048  # T_j = Σ t·b_t ≤ 2048·2047/2·255 ≈ 5.3e8 < 2³¹


def adler32_zlib(data: bytes) -> int:
    return zlib.adler32(data) & 0xFFFFFFFF


def adler32_jnp(buf) -> int:
    """Pure-jnp blocked-modular Adler-32 (buffers up to ~128 MiB)."""
    b = jnp.asarray(buf, dtype=jnp.uint32)
    n = b.size
    if n == 0:
        return 1
    pad = (-n) % _BLOCK
    b = jnp.pad(b, (0, pad))  # zeros contribute nothing to either sum
    rows = b.reshape(-1, _BLOCK)
    iota = jnp.arange(_BLOCK, dtype=jnp.uint32)
    s = rows.sum(axis=1) % MOD                    # S_j mod M
    t = (rows * iota).sum(axis=1) % MOD           # T_j mod M
    offsets = jnp.arange(rows.shape[0], dtype=jnp.uint32) * _BLOCK
    w = (jnp.uint32(n) - offsets) % MOD           # (n - o_j) mod M
    # products < M² < 2³²: safe in uint32 with a mod after each block term
    per_block = (w * s % MOD + (MOD - t)) % MOD   # (n-o_j)·S_j − T_j mod M
    a = (1 + s.sum() % MOD) % MOD
    bsum = (jnp.uint32(n % MOD) + per_block.sum() % MOD) % MOD
    return int((int(bsum) << 16) | int(a))
