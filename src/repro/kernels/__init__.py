"""Pallas TPU kernels for the compute hot-spots the paper optimizes.

The paper's speedups come from three per-byte passes (delimiter scanning,
checksumming) plus the downstream model compute this framework feeds:

* ``pattern_scan`` — multi-byte delimiter search over uint8 buffers: the
  TPU-VPU adaptation of FastWARC's SIMD ``memchr``/``strstr`` bulk scans.
* ``adler32``     — the rolling checksum as blocked reductions (CRC-32's
  bit-feedback loop does not transfer to the VPU; see DESIGN.md §4).
* ``digest_sig``  — fused Adler-32 + n-gram-signature sweep: both CDX
  byte columns from one batched pass (DESIGN.md §9).
* ``flash_attention`` — blocked GQA attention with online softmax: the
  training/serving hot-spot of the LM architectures this pipeline feeds.

Each subpackage: ``<name>.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper), ``ref.py`` (pure-jnp oracle used by the tests).
Kernels are TPU-targeted and validated on CPU via ``interpret=True``.
"""
