"""Public wrapper: fused digests + signatures for ragged payload batches.

``digest_signature_batch`` stacks a ragged batch of record payloads into
power-of-two width buckets (the shared :mod:`repro.kernels.bucketing`
rule, so dispatch accounting matches the other byte kernels), sweeps
each bucket **once** through the fused Pallas kernel, and finishes on
the host:

* Adler-32: the kernel's ``(S, T)`` partials reduce through the same
  :func:`repro.kernels.adler32.ops.combine_partials` the plain digest
  path uses — entry-wise equal to ``zlib.adler32``.
* signatures: the kernel's n-gram hash matrix feeds the shared
  double-hash position derivation
  (:func:`repro.index.signature.positions_from_hashes`) and the batch
  ``packbits`` fold — bit-identical to
  :func:`repro.index.signature.signature_of` per row.

This is the index build's single-sweep hot path: each payload byte is
read once by the kernel; all host work after it is O(#n-grams) on hash
values, never on payload bytes.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.adler32.ops import combine_partials
from repro.kernels.bucketing import as_u8 as _as_u8, bucket_width
from repro.obs.kernels import record_dispatch
from .digest_sig import BLOCK, HPAD, digest_sig_partials_batch, group_rows

__all__ = ["digest_signature_batch"]


def _pad_rows(n: int, group: int) -> int:
    """Row-count bucket: next power-of-two multiple of the group size, so
    repeated ragged batches reuse a bounded set of compiled shapes (pad
    rows are all-zero; their outputs are discarded)."""
    return group * (1 << max(-(-n // group) - 1, 0).bit_length())


def digest_signature_batch(payloads, *, bits: int | None = None,
                           n: int | None = None, k: int | None = None,
                           block: int = BLOCK, interpret: bool = True
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Adler-32 digests **and** n-gram signatures of a ragged batch,
    one fused kernel sweep per width bucket.

    Returns ``(digests, signatures)``: uint32 ``(B,)`` matching
    ``zlib.adler32`` and uint64 ``(B, bits // 64)`` matching
    ``signature_of`` row-wise. ``bits`` must be a power of two (the
    position masking and packbits fold rely on it); the signature
    geometry defaults to the :mod:`repro.index.signature` constants.
    """
    from repro.index.signature import (
        SIG_BITS, SIG_HASHES, SIG_NGRAM, fold_positions_rows,
        positions_from_hashes,
    )

    bits = SIG_BITS if bits is None else bits
    n = SIG_NGRAM if n is None else n
    k = SIG_HASHES if k is None else k
    if bits <= 0 or bits & (bits - 1) or bits % 64:
        raise ValueError(f"bits must be a power of two multiple of 64, "
                         f"got {bits}")
    if not 1 < n <= HPAD + 1 or k < 1:
        raise ValueError(f"need 2 <= n <= {HPAD + 1} and k >= 1")
    bufs = [_as_u8(p) for p in payloads]
    nrows = len(bufs)
    digests = np.empty(nrows, np.uint32)
    sigs = np.zeros((nrows, bits // 64), np.uint64)
    if nrows == 0:
        return digests, sigs
    buckets: dict[int, list[int]] = {}
    for i, buf in enumerate(bufs):
        buckets.setdefault(bucket_width(buf.size, block), []).append(i)
    for width, idxs in buckets.items():
        group = group_rows(width)
        padded = np.zeros((_pad_rows(len(idxs), group), width + HPAD),
                          np.uint8)
        for row, i in enumerate(idxs):
            padded[row, :bufs[i].size] = bufs[i]
        lengths = np.asarray([bufs[i].size for i in idxs], np.int64)
        record_dispatch("digest_signature_batch", width=width,
                        rows=len(idxs), padded_rows=padded.shape[0],
                        useful_bytes=int(lengths.sum()))
        s, t, h = digest_sig_partials_batch(jnp.asarray(padded), n=n,
                                            block=block, interpret=interpret)
        live = len(idxs)
        # full-array np.asarray is zero-copy on the CPU backend; slicing
        # happens host-side (a device-side h[:live] would dispatch + copy)
        s_np, t_np, h_np = np.asarray(s), np.asarray(t), np.asarray(h)
        digests[idxs] = combine_partials(s_np[:live], t_np[:live], lengths,
                                         block)
        # hash → k bit positions → flat packbits fold; all O(#n-grams) on
        # the hash matrix, payload bytes were consumed by the single
        # sweep. Valid n-grams are a per-row prefix, so the flat gather
        # indices come from repeat/cumsum — no boolean mask sweep.
        hu = h_np.view(np.uint32)
        m = np.maximum(lengths - (n - 1), 0)         # valid n-grams per row
        rows = np.arange(live, dtype=np.int64)
        offs = np.cumsum(m) - m                      # per-row prefix starts
        gidx = np.arange(int(m.sum()), dtype=np.int64)
        gidx += np.repeat(rows * width - offs, m)    # flat (row, col) index
        hv = hu.ravel()[gidx]
        pos = positions_from_hashes(hv, bits, k)     # (k, total) planes
        sigs[idxs] = fold_positions_rows(live, np.repeat(rows, m), pos, bits)
    return digests, sigs
