"""Public wrapper: fused digests + signatures for ragged payload batches.

``digest_signature_batch`` stacks a ragged batch of record payloads into
power-of-two width buckets (the shared :mod:`repro.kernels.bucketing`
rule, so dispatch accounting matches the other byte kernels), sweeps
each bucket **once** through the fused Pallas kernel, and finishes on
the host:

* Adler-32: the kernel's ``(S, T)`` partials reduce through the same
  :func:`repro.kernels.adler32.ops.combine_partials` the plain digest
  path uses — entry-wise equal to ``zlib.adler32``.
* signatures: the kernel's n-gram hash matrix feeds the shared
  double-hash position derivation
  (:func:`repro.index.signature.positions_from_hashes`) and the batch
  ``packbits`` fold — bit-identical to
  :func:`repro.index.signature.signature_of` per row.

This is the index build's single-sweep hot path: each payload byte is
read once by the kernel; all host work after it is O(#n-grams) on hash
values, never on payload bytes.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from repro.kernels.adler32.ops import combine_partials
from repro.kernels.bucketing import (
    as_u8 as _as_u8,
    payload_width,
    quantize_count,
)
from repro.obs.kernels import record_dispatch
from .digest_sig import BLOCK, HPAD, digest_sig_partials_batch

__all__ = ["digest_signature_batch", "digest_signature_rowgroup"]


def _pad_rows(n: int) -> int:
    """Row-count bucket: half-step quantized (1, 2, 3, 4, 6, 8, 12, …),
    so repeated ragged batches reuse a bounded set of compiled shapes
    while row padding stays ≤ 1.5× (pad rows are all-zero; their outputs
    are discarded). The kernel's row group adapts to any quantized count."""
    return quantize_count(n)


def _sig_geometry(bits: int | None, n: int | None, k: int | None
                  ) -> tuple[int, int, int]:
    """Validated signature geometry, defaulting to the index constants."""
    from repro.index.signature import SIG_BITS, SIG_HASHES, SIG_NGRAM

    bits = SIG_BITS if bits is None else bits
    n = SIG_NGRAM if n is None else n
    k = SIG_HASHES if k is None else k
    if bits <= 0 or bits & (bits - 1) or bits % 64:
        raise ValueError(f"bits must be a power of two multiple of 64, "
                         f"got {bits}")
    if not 1 < n <= HPAD + 1 or k < 1:
        raise ValueError(f"need 2 <= n <= {HPAD + 1} and k >= 1")
    return bits, n, k


def _host_fold(s, t, h, lengths: np.ndarray, *, width: int, bits: int,
               n: int, k: int, block: int) -> tuple[np.ndarray, np.ndarray]:
    """Finish the fused sweep on the host for the first ``len(lengths)``
    rows of the kernel partials: Adler combine + hash → k bit positions
    → flat packbits fold. All O(#n-grams) on hash values — payload bytes
    were consumed by the single kernel sweep. Valid n-grams are a
    per-row prefix, so the flat gather indices come from repeat/cumsum —
    no boolean mask sweep."""
    from repro.index.signature import fold_positions_rows, positions_from_hashes

    live = lengths.size
    # full-array np.asarray is zero-copy on the CPU backend; slicing
    # happens host-side (a device-side h[:live] would dispatch + copy)
    s_np, t_np, h_np = np.asarray(s), np.asarray(t), np.asarray(h)
    digests = combine_partials(s_np[:live], t_np[:live], lengths, block)
    hu = h_np.view(np.uint32)
    m = np.maximum(lengths - (n - 1), 0)         # valid n-grams per row
    rows = np.arange(live, dtype=np.int64)
    offs = np.cumsum(m) - m                      # per-row prefix starts
    gidx = np.arange(int(m.sum()), dtype=np.int64)
    gidx += np.repeat(rows * width - offs, m)    # flat (row, col) index
    hv = hu.ravel()[gidx]
    pos = positions_from_hashes(hv, bits, k)     # (k, total) planes
    sigs = fold_positions_rows(live, np.repeat(rows, m), pos, bits)
    return digests, sigs


def digest_signature_batch(payloads, *, bits: int | None = None,
                           n: int | None = None, k: int | None = None,
                           block: int = BLOCK, interpret: bool = True
                           ) -> tuple[np.ndarray, np.ndarray]:
    """Adler-32 digests **and** n-gram signatures of a ragged batch,
    one fused kernel sweep per width bucket.

    Returns ``(digests, signatures)``: uint32 ``(B,)`` matching
    ``zlib.adler32`` and uint64 ``(B, bits // 64)`` matching
    ``signature_of`` row-wise. ``bits`` must be a power of two (the
    position masking and packbits fold rely on it); the signature
    geometry defaults to the :mod:`repro.index.signature` constants.
    """
    bits, n, k = _sig_geometry(bits, n, k)
    bufs = [_as_u8(p) for p in payloads]
    nrows = len(bufs)
    digests = np.empty(nrows, np.uint32)
    sigs = np.zeros((nrows, bits // 64), np.uint64)
    if nrows == 0:
        return digests, sigs
    buckets: dict[int, list[int]] = {}
    for i, buf in enumerate(bufs):
        # BLOCK is the Adler overflow *bound*, not a width floor: payloads
        # below one block take sub-block width buckets (the whole row is a
        # single Adler block) — see payload_width
        buckets.setdefault(payload_width(buf.size, block), []).append(i)
    for width, idxs in buckets.items():
        kblock = min(block, width)  # sub-2048 widths are one Adler block
        padded = np.zeros((_pad_rows(len(idxs)), width + HPAD), np.uint8)
        for row, i in enumerate(idxs):
            padded[row, :bufs[i].size] = bufs[i]
        lengths = np.asarray([bufs[i].size for i in idxs], np.int64)
        record_dispatch("digest_signature_batch", width=width,
                        rows=len(idxs), padded_rows=padded.shape[0],
                        useful_bytes=int(lengths.sum()))
        s, t, h = digest_sig_partials_batch(jnp.asarray(padded), n=n,
                                            block=kblock, interpret=interpret)
        digests[idxs], sigs[idxs] = _host_fold(
            s, t, h, lengths, width=width, bits=bits, n=n, k=k, block=kblock)
    return digests, sigs


def digest_signature_rowgroup(matrix, lengths, *, bits: int | None = None,
                              n: int | None = None, k: int | None = None,
                              block: int = BLOCK, interpret: bool = True
                              ) -> tuple[np.ndarray, np.ndarray]:
    """Fused digests + signatures over an **already-packed row-group**.

    The columnar derive/scan entry point: ``matrix`` is a
    ``(B, width + HPAD)`` uint8 row-group in the kernel's native layout
    (payload bytes left-justified, zero tail ≥ HPAD — exactly what
    :mod:`repro.columnar.store` mmaps from disk), ``lengths`` the true
    payload lengths of the first ``len(lengths)`` rows; trailing rows
    are padding. No per-payload copy or re-bucketing happens here — the
    packing cost was paid once at derive time, so pad waste is whatever
    the row-group packer achieved, not the ragged-batch bucketing rule.

    Returns ``(digests, signatures)`` for the live rows, bit-identical
    to :func:`digest_signature_batch` on the same payloads.
    """
    bits, n, k = _sig_geometry(bits, n, k)
    mat = np.ascontiguousarray(matrix, np.uint8)
    nrows, padded_width = mat.shape
    width = padded_width - HPAD
    if width <= 0 or width % block:
        raise ValueError(f"row-group width {padded_width} must be HPAD "
                         f"plus a multiple of block={block}")
    lengths = np.asarray(lengths, np.int64)
    live = lengths.size
    if not 0 < live <= nrows:
        raise ValueError(f"need 1 <= live rows <= {nrows}, got {live}")
    if lengths.max(initial=0) > width:
        raise ValueError("length exceeds row-group width")
    record_dispatch("digest_signature_rowgroup", width=width, rows=live,
                    padded_rows=nrows, useful_bytes=int(lengths.sum()))
    s, t, h = digest_sig_partials_batch(jnp.asarray(mat), n=n, block=block,
                                        interpret=interpret)
    return _host_fold(s, t, h, lengths, width=width, bits=bits, n=n, k=k,
                      block=block)
