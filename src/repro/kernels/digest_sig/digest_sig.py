"""Pallas kernel: fused Adler-32 + n-gram-signature sweep (DESIGN.md §9).

CDX index construction needs two per-record byte reductions: the Adler-32
content digest and the Bloom-style n-gram signature
(:mod:`repro.index.signature`). Shipping them as separate passes walks
every payload byte twice; this kernel fuses both into **one** batched
sweep over a padded ``(B, W)`` byte matrix:

* per 2048-byte sub-block it emits the Adler partials
  ``S_j = Σ b, T_j = Σ t·b`` (same partial layout as
  :mod:`repro.kernels.adler32` — the host combiner is shared), and
* the rolling polynomial hash of every overlapping byte n-gram,
  ``h_i = Σ_{j<n} b_{i+j}·P^{n-1-j}`` (uint32 wraparound, the exact
  formula of :func:`repro.index.signature._ngram_hashes`), one lane per
  position.

Tiling: one grid step processes a **group of rows** ``(G, W + HPAD)``
rather than one ``(1, block)`` tile — the fused sweep is a long chain of
cheap vector ops, so per-step dispatch overhead (pronounced in interpret
mode, real on TPU too) dominates a fine grid. The sub-block Adler
partials come from a static unroll of strided slices (no reshape — tile
layouts stay 2-D), and the ``HPAD`` right padding (zeros, ≥ n−1 wide)
replaces an explicit halo input: every n-gram window starting in the row
is in-bounds inside the tile. Int32 with wraparound multiplies matches
uint32 mod-2³² semantics on both TPU and in interpret mode.

The (cheap, O(#n-grams)) double-hash fold of hash values into signature
bit positions stays on the host (:mod:`.ops`): it touches hashes, not
payload bytes, so the "each payload byte is touched once" property of
the fused build is preserved.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.bucketing import ROWGROUP_PAD

BLOCK = 2048          # Adler overflow bound: T_j < 2048·2047/2·255 < 2³¹
HPAD = ROWGROUP_PAD   # zero right-padding (lane-aligned); bounds n − 1
FNV_PRIME = 0x01000193  # matches repro.index.signature._FNV_PRIME
GROUP_BYTES = 1 << 21   # target payload bytes per grid step (VMEM budget:
                        # ~2 MiB u8 tile + int32 hash/temp arrays ≈ 12 MiB)
MAX_GROUP = 128


def group_rows(width: int, nrows: int | None = None) -> int:
    """Rows per grid step for a bucket of this padded width.

    With ``nrows`` given, shrinks to the largest value that divides the
    row count — batches are row-padded by the half-step quantizer
    (:func:`repro.kernels.bucketing.quantize_count`, values ``m·2^k``
    with m ∈ {1, 3}), so a large divisor always exists and the grid
    never forces extra all-pad rows just to hit a group multiple.
    """
    g = max(1, min(MAX_GROUP, GROUP_BYTES // max(width, 1)))
    if nrows is not None:
        g = min(g, nrows)
        while nrows % g:
            g -= 1
    return g


def _digest_sig_kernel(buf_ref, s_ref, t_ref, h_ref, *,
                       width: int, block: int, n: int):
    """One grid step: Adler partials + n-gram hashes of (G, width) rows."""
    ext = buf_ref[:, :].astype(jnp.int32)      # (G, width + HPAD)
    data = ext[:, :width]
    iota = jax.lax.broadcasted_iota(jnp.int32, (1, block), 1)
    for j in range(width // block):            # static unroll: sub-blocks
        seg = data[:, j * block:(j + 1) * block]
        s_ref[:, j] = jnp.sum(seg, axis=1)
        t_ref[:, j] = jnp.sum(seg * iota, axis=1)
    h = data
    for j in range(1, n):                      # static unroll: n-gram poly
        h = h * FNV_PRIME + ext[:, j:j + width]  # int32 wrap == mod 2^32
    h_ref[:, :] = h


@functools.partial(jax.jit, static_argnames=("n", "block", "interpret"))
def digest_sig_partials_batch(padded_bufs: jax.Array, *, n: int,
                              block: int = BLOCK, interpret: bool = True
                              ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Fused per-(row, block) partials over a padded byte matrix.

    ``padded_bufs`` is ``(B, W + HPAD)`` uint8 — payload bytes in the
    first ``W`` columns (``W % block == 0``), zeros after. The row group
    adapts to ``B`` (largest divisor within the VMEM budget), so any row
    count works; wrappers still quantize ``B`` so divisors are large.
    Returns ``(S, T, H)``: two ``(B, W // block)`` int32 Adler partial
    arrays plus the ``(B, W)`` int32 n-gram hash matrix (uint32 bit
    patterns). One call sweeps the whole batch once.
    """
    nrows, padded_width = padded_bufs.shape
    width = padded_width - HPAD
    assert width > 0 and width % block == 0, \
        "wrapper must pad to HPAD plus a block multiple"
    assert 1 < n <= HPAD + 1
    group = group_rows(width, nrows)
    nblocks = width // block
    kernel = functools.partial(_digest_sig_kernel, width=width, block=block,
                               n=n)
    return pl.pallas_call(
        kernel,
        grid=(nrows // group,),
        in_specs=[pl.BlockSpec((group, padded_width), lambda g: (g, 0))],
        out_specs=[
            pl.BlockSpec((group, nblocks), lambda g: (g, 0)),
            pl.BlockSpec((group, nblocks), lambda g: (g, 0)),
            pl.BlockSpec((group, width), lambda g: (g, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((nrows, nblocks), jnp.int32),
            jax.ShapeDtypeStruct((nrows, nblocks), jnp.int32),
            jax.ShapeDtypeStruct((nrows, width), jnp.int32),
        ],
        interpret=interpret,
    )(padded_bufs)
