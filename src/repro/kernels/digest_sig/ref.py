"""Host reference for the fused digest+signature sweep (kernel oracle)."""
from __future__ import annotations

import zlib

import numpy as np


def digest_signature_reference(payloads, *, bits: int | None = None,
                               n: int | None = None, k: int | None = None
                               ) -> tuple[np.ndarray, np.ndarray]:
    """Two-pass host computation: ``zlib.adler32`` + ``signature_of``.

    This *is* the PR 2-era index-build byte path — the exact code the
    fused kernel replaces — kept as the equivalence oracle and as the
    benchmark's "two-pass" baseline.
    """
    from repro.index.signature import (
        SIG_BITS, SIG_HASHES, SIG_NGRAM, signature_of,
    )

    bits = SIG_BITS if bits is None else bits
    n = SIG_NGRAM if n is None else n
    k = SIG_HASHES if k is None else k
    digests = np.asarray(
        [zlib.adler32(p) & 0xFFFFFFFF for p in payloads], np.uint32)
    sigs = (np.stack([signature_of(p, bits=bits, n=n, k=k)
                      for p in payloads])
            if len(payloads) else np.empty((0, bits // 64), np.uint64))
    return digests, sigs
