"""Fused Adler-32 + n-gram-signature batch kernel (DESIGN.md §9).

One batched Pallas sweep produces both per-record CDX byte columns —
the content digest and the query pre-filter signature — so index
construction touches each payload byte once.
"""
from .digest_sig import BLOCK, FNV_PRIME, HPAD, digest_sig_partials_batch
from .ops import digest_signature_batch, digest_signature_rowgroup
from .ref import digest_signature_reference

__all__ = [
    "BLOCK",
    "FNV_PRIME",
    "HPAD",
    "digest_sig_partials_batch",
    "digest_signature_batch",
    "digest_signature_rowgroup",
    "digest_signature_reference",
]
