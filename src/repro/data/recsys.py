"""Synthetic recsys batches (criteo/amazon-like) for training and serving."""
from __future__ import annotations

import numpy as np

from repro.models.recsys import RecsysConfig


def make_batch(cfg: RecsysConfig, batch: int, seed: int = 0) -> dict:
    """Family-appropriate input dict + binary labels."""
    rng = np.random.default_rng(seed)
    out = {"labels": (rng.random(batch) < 0.25).astype(np.float32)}
    if cfg.kind in ("dcn_v2", "autoint"):
        out["sparse_ids"] = np.stack(
            [rng.integers(0, v, batch) for v in cfg.vocabs],
            axis=1).astype(np.int32)
        if cfg.kind == "dcn_v2":
            out["dense_feats"] = np.log1p(
                rng.exponential(size=(batch, cfg.n_dense))).astype(np.float32)
    else:  # din / dien
        L = cfg.seq_len
        lengths = rng.integers(1, L + 1, batch)
        mask = (np.arange(L)[None, :] < lengths[:, None])
        out["profile_ids"] = rng.integers(
            0, cfg.profile_vocab,
            (batch, cfg.n_profile_fields)).astype(np.int32)
        out["hist_items"] = (rng.integers(0, cfg.item_vocab, (batch, L))
                             * mask).astype(np.int32)
        out["hist_cates"] = (rng.integers(0, cfg.cate_vocab, (batch, L))
                             * mask).astype(np.int32)
        out["hist_mask"] = mask.astype(np.float32)
        out["target_item"] = rng.integers(0, cfg.item_vocab,
                                          batch).astype(np.int32)
        out["target_cate"] = rng.integers(0, cfg.cate_vocab,
                                          batch).astype(np.int32)
    return out


def make_candidates(cfg: RecsysConfig, n: int, seed: int = 1) -> np.ndarray:
    rng = np.random.default_rng(seed)
    vocab = cfg.vocabs[0] if cfg.kind in ("dcn_v2", "autoint") else cfg.item_vocab
    return rng.integers(0, vocab, n).astype(np.int32)
