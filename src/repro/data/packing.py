"""Sequence packing: document token streams → fixed [B, S] training batches.

GPT-style contiguous packing (documents concatenated, EOS-separated,
crossing sequence boundaries) with an optional segment-ids output for
packers that mask cross-document attention.
"""
from __future__ import annotations

import numpy as np

from .tokenizer import EOS_ID, PAD_ID


class SequencePacker:
    """Stateful packer; feed token arrays, emit full [S+1] rows.

    Rows carry S+1 tokens so the trainer derives (inputs, labels) =
    (row[:-1], row[1:]) without re-reading. The internal remainder buffer
    is part of the checkpointable pipeline state.
    """

    def __init__(self, seq_len: int) -> None:
        self.seq_len = seq_len
        self._buf = np.zeros((0,), np.int32)

    def feed(self, tokens: np.ndarray) -> list[np.ndarray]:
        buf = np.concatenate([self._buf, tokens.astype(np.int32)])
        rows = []
        row = self.seq_len + 1
        while buf.size >= row:
            rows.append(buf[:row].copy())
            # overlap by one token so labels stay contiguous across rows
            buf = buf[self.seq_len:]
        self._buf = buf
        return rows

    def state(self) -> dict:
        return {"buf": self._buf.tolist()}

    def restore(self, state: dict) -> None:
        self._buf = np.asarray(state["buf"], np.int32)


def segment_ids(row: np.ndarray) -> np.ndarray:
    """Document index per position (EOS starts a new segment)."""
    return np.cumsum(np.concatenate(([0], (row[:-1] == EOS_ID))))\
        .astype(np.int32)


def pad_batch(rows: list[np.ndarray], batch: int, seq_len: int) -> np.ndarray:
    """Stack rows into [batch, seq_len+1], padding short batches."""
    out = np.full((batch, seq_len + 1), PAD_ID, np.int32)
    for i, r in enumerate(rows[:batch]):
        out[i, :r.size] = r
    return out
