"""Sharded, prefetching, exactly-resumable WARC→token training loader.

The host-side input pipeline of the framework (DESIGN.md §2):

* **sharding** — shard files are assigned round-robin by
  ``host_id mod n_hosts`` (multi-host data parallelism: each host feeds
  its own slice of the global batch);
* **prefetch** — a daemon thread parses/tokenizes/packs ahead into a
  bounded queue, overlapping host CPU with device compute;
* **exact resume** — the cursor (shard index, documents consumed in the
  current shard, packer remainder) is exposed via :meth:`state` and
  restored via :meth:`restore`; the train loop stores it in every
  checkpoint (``repro/train/checkpoint.py`` extras).
"""
from __future__ import annotations

import queue
import threading
from typing import Iterator

import numpy as np

from repro.core.pipeline import iter_documents
from .packing import SequencePacker, pad_batch
from .tokenizer import encode_document


class WarcTokenLoader:
    def __init__(self, shard_paths: list[str], *, batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1, min_doc_len: int = 64,
                 prefetch: int = 4, loop: bool = True) -> None:
        self.all_shards = list(shard_paths)
        self.my_shards = [p for i, p in enumerate(self.all_shards)
                          if i % n_hosts == host_id]
        if not self.my_shards:
            raise ValueError("no shards assigned to this host")
        self.batch = batch
        self.seq_len = seq_len
        self.min_doc_len = min_doc_len
        self.loop = loop
        self.prefetch = prefetch
        self._packer = SequencePacker(seq_len)
        self._rows: list[np.ndarray] = []   # packed, not yet emitted
        self._shard_idx = 0
        self._docs_consumed = 0
        self._epoch = 0
        self._queue: queue.Queue | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- checkpointable cursor -------------------------------------------
    def state(self) -> dict:
        return {"shard_idx": self._shard_idx,
                "docs_consumed": self._docs_consumed,
                "epoch": self._epoch,
                "packer": self._packer.state(),
                "rows": [r.tolist() for r in self._rows]}

    def restore(self, state: dict) -> None:
        self._shard_idx = state["shard_idx"]
        self._docs_consumed = state["docs_consumed"]
        self._epoch = state.get("epoch", 0)
        self._packer.restore(state["packer"])
        self._rows = [np.asarray(r, np.int32) for r in state.get("rows", [])]

    # -- synchronous batch generator --------------------------------------
    def batches(self) -> Iterator[np.ndarray]:
        """Yield [batch, seq_len+1] int32 arrays (row = inputs+labels).

        The not-yet-emitted row backlog lives on the object (``_rows``) so
        :meth:`state` snapshots taken between batches resume exactly.
        """
        while True:
            shard = self.my_shards[self._shard_idx % len(self.my_shards)]
            skip = self._docs_consumed
            for n_doc, doc in enumerate(
                    iter_documents(shard, min_length=self.min_doc_len)):
                if n_doc < skip:
                    continue
                self._docs_consumed = n_doc + 1
                self._rows.extend(self._packer.feed(encode_document(doc.text)))
                while len(self._rows) >= self.batch:
                    out = np.stack(self._rows[:self.batch])
                    self._rows = self._rows[self.batch:]
                    yield out
            self._shard_idx += 1
            self._docs_consumed = 0
            if self._shard_idx % len(self.my_shards) == 0:
                self._epoch += 1
                if not self.loop:
                    if self._rows:
                        yield pad_batch(self._rows, self.batch, self.seq_len)
                        self._rows = []
                    return

    # -- prefetching iterator ----------------------------------------------
    def __iter__(self) -> Iterator[np.ndarray]:
        if self.prefetch <= 0:
            yield from self.batches()
            return
        self._queue = queue.Queue(maxsize=self.prefetch)
        self._stop.clear()

        def worker():
            try:
                for batch in self.batches():
                    if self._stop.is_set():
                        return
                    self._queue.put(batch)
            finally:
                self._queue.put(None)

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        while True:
            item = self._queue.get()
            if item is None:
                return
            yield item

    def close(self) -> None:
        self._stop.set()
        if self._queue is not None:
            try:  # unblock the worker if it's waiting on a full queue
                self._queue.get_nowait()
            except queue.Empty:
                pass


def split_batch(batch: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """[B, S+1] row -> (inputs [B, S], labels [B, S])."""
    return batch[:, :-1], batch[:, 1:]
