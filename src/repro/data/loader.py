"""Sharded, prefetching, exactly-resumable WARC→token training loader.

The host-side input pipeline of the framework (DESIGN.md §2):

* **sharding** — shard files are assigned round-robin by
  ``host_id mod n_hosts`` (multi-host data parallelism: each host feeds
  its own slice of the global batch);
* **prefetch** — a daemon thread parses/tokenizes/packs ahead into a
  bounded queue, overlapping host CPU with device compute;
* **exact resume** — the cursor (shard index, documents consumed in the
  current shard, packer remainder) is exposed via :meth:`state` and
  restored via :meth:`restore`; the train loop stores it in every
  checkpoint (``repro/train/checkpoint.py`` extras);
* **multi-core parse** — ``workers=N`` runs WARC parse + HTML→text +
  tokenization for the shards *ahead of the cursor* in N worker processes
  (:class:`repro.core.parallel.ParallelWarcPool`, ordered mode), while the
  packer — the only stateful stage — stays in this process, so the cursor
  semantics are bit-identical to the serial path.
"""
from __future__ import annotations

import functools
import queue
import threading
import time
from typing import Iterator

import numpy as np

from repro.core.pipeline import iter_documents
from .packing import SequencePacker, pad_batch
from .tokenizer import encode_document


def _tokenized_docs(path: str, *, min_length: int,
                    readahead: bool | None = None):
    """Worker-side shard stage: parse → extract → tokenize (module-level
    so the process pool can pickle it under spawn)."""
    for doc in iter_documents(path, min_length=min_length,
                              readahead=readahead):
        yield encode_document(doc.text)


class WarcTokenLoader:
    def __init__(self, shard_paths: list[str], *, batch: int, seq_len: int,
                 host_id: int = 0, n_hosts: int = 1, min_doc_len: int = 64,
                 prefetch: int = 4, loop: bool = True,
                 workers: int = 0, readahead: bool | None = None) -> None:
        self.all_shards = list(shard_paths)
        self.my_shards = [p for i, p in enumerate(self.all_shards)
                          if i % n_hosts == host_id]
        if not self.my_shards:
            raise ValueError("no shards assigned to this host")
        self.batch = batch
        self.seq_len = seq_len
        self.min_doc_len = min_doc_len
        self.loop = loop
        self.prefetch = prefetch
        self.workers = workers
        # member-decode readahead inside each shard parse (None = auto);
        # close() joins those decoder threads via the iter_documents
        # teardown chain, same contract as the prefetch thread itself
        self.readahead = readahead
        self._pool = None
        self._packer = SequencePacker(seq_len)
        self._rows: list[np.ndarray] = []   # packed, not yet emitted
        self._shard_idx = 0
        self._docs_consumed = 0
        self._epoch = 0
        self._queue: queue.Queue | None = None
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- checkpointable cursor -------------------------------------------
    def state(self) -> dict:
        return {"shard_idx": self._shard_idx,
                "docs_consumed": self._docs_consumed,
                "epoch": self._epoch,
                "packer": self._packer.state(),
                "rows": [r.tolist() for r in self._rows]}

    def restore(self, state: dict) -> None:
        self._shard_idx = state["shard_idx"]
        self._docs_consumed = state["docs_consumed"]
        self._epoch = state.get("epoch", 0)
        self._packer.restore(state["packer"])
        self._rows = [np.asarray(r, np.int32) for r in state.get("rows", [])]

    # -- synchronous batch generator --------------------------------------
    def batches(self) -> Iterator[np.ndarray]:
        """Yield [batch, seq_len+1] int32 arrays (row = inputs+labels).

        The not-yet-emitted row backlog lives on the object (``_rows``) so
        :meth:`state` snapshots taken between batches resume exactly.
        With ``workers > 0`` the per-shard parse/tokenize stages run in
        worker processes; document order, cursor updates, and emitted
        batches are identical to the serial path.
        """
        if self.workers > 0:
            yield from self._batches_parallel()
            return
        while not self._stop.is_set():
            shard = self.my_shards[self._shard_idx % len(self.my_shards)]
            skip = self._docs_consumed
            for n_doc, doc in enumerate(
                    iter_documents(shard, min_length=self.min_doc_len,
                                   readahead=self.readahead)):
                if self._stop.is_set():  # close() must not wait a shard out
                    return
                if n_doc < skip:
                    continue
                self._docs_consumed = n_doc + 1
                self._rows.extend(self._packer.feed(encode_document(doc.text)))
                while len(self._rows) >= self.batch:
                    out = np.stack(self._rows[:self.batch])
                    self._rows = self._rows[self.batch:]
                    yield out
            self._shard_idx += 1
            self._docs_consumed = 0
            if self._shard_idx % len(self.my_shards) == 0:
                self._epoch += 1
                if not self.loop:
                    if self._rows:
                        yield pad_batch(self._rows, self.batch, self.seq_len)
                        self._rows = []
                    return

    # -- process-parallel shard parsing ------------------------------------
    def _shard_paths_from(self, start: int) -> Iterator[str]:
        """Shard path sequence the serial loop would visit from ``start``:
        round-robin forever when looping, else to the next epoch boundary."""
        n = len(self.my_shards)
        if self.loop:
            k = start
            while True:
                yield self.my_shards[k % n]
                k += 1
        else:
            for k in range(start, (start // n + 1) * n):
                yield self.my_shards[k % n]

    def _batches_parallel(self) -> Iterator[np.ndarray]:
        from repro.core.parallel import ParallelWarcPool

        n = len(self.my_shards)
        fn = functools.partial(_tokenized_docs, min_length=self.min_doc_len,
                               readahead=self.readahead)
        pool = ParallelWarcPool(fn, workers=self.workers)
        self._pool = pool
        try:
            skip = self._docs_consumed
            n_doc = 0  # position within the current shard (incl. skipped)
            for event in pool.iter_events(
                    self._shard_paths_from(self._shard_idx), ordered=True):
                if self._stop.is_set():  # close() must not wait a shard out
                    return
                if event[0] == "chunk":
                    for ids in event[2]:
                        if n_doc >= skip:
                            self._docs_consumed = n_doc + 1
                            self._rows.extend(self._packer.feed(ids))
                            while len(self._rows) >= self.batch:
                                out = np.stack(self._rows[:self.batch])
                                self._rows = self._rows[self.batch:]
                                yield out
                        n_doc += 1
                    continue
                # shard boundary
                self._shard_idx += 1
                self._docs_consumed = 0
                skip = 0
                n_doc = 0
                if self._shard_idx % n == 0:
                    self._epoch += 1
                    if not self.loop:
                        break
            if not self.loop and self._rows:
                yield pad_batch(self._rows, self.batch, self.seq_len)
                self._rows = []
        finally:
            self._pool = None
            pool.close()

    # -- prefetching iterator ----------------------------------------------
    def __iter__(self) -> Iterator[np.ndarray]:
        if self.prefetch <= 0:
            yield from self.batches()
            return
        self._queue = queue.Queue(maxsize=self.prefetch)
        self._stop.clear()

        def worker():
            try:
                for batch in self.batches():
                    # bounded put that stays responsive to close()
                    while not self._stop.is_set():
                        try:
                            self._queue.put(batch, timeout=0.1)
                            break
                        except queue.Full:
                            continue
                    if self._stop.is_set():
                        return
            finally:
                try:
                    self._queue.put_nowait(None)
                except queue.Full:
                    pass

        self._thread = threading.Thread(target=worker, daemon=True)
        self._thread.start()
        while True:
            try:
                item = self._queue.get(timeout=0.5)
            except queue.Empty:
                if self._stop.is_set() or not self._thread.is_alive():
                    return
                continue
            if item is None:
                return
            yield item

    def close(self) -> None:
        """Stop the prefetch thread (and any worker pool) and join it.

        ``batches()`` polls the stop flag per document/event, so the join
        normally returns within one document's parse time; the deadline
        is a backstop (the thread is a daemon either way).
        """
        self._stop.set()
        thread = self._thread
        if thread is not None:
            deadline = time.monotonic() + 10.0
            while thread.is_alive() and time.monotonic() < deadline:
                if self._queue is not None:
                    try:  # unblock a producer waiting on a full queue
                        self._queue.get_nowait()
                    except queue.Empty:
                        pass
                thread.join(timeout=0.05)
            self._thread = None
        pool = self._pool
        if pool is not None:
            pool.close()
            self._pool = None


def split_batch(batch: np.ndarray) -> tuple[np.ndarray, np.ndarray]:
    """[B, S+1] row -> (inputs [B, S], labels [B, S])."""
    return batch[:, :-1], batch[:, 1:]
