"""Graph data: CSR structures, synthetic graphs, real neighbor sampling.

``minibatch_lg`` (GraphSAGE-style sampled training on a Reddit-scale
graph) needs an actual neighbor sampler, not a stub: :func:`sample_subgraph`
does multi-hop uniform fanout sampling over CSR adjacency and emits a
fixed-shape (padded) subgraph so the jitted train step sees static shapes.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    indptr: np.ndarray     # [N+1]
    indices: np.ndarray    # [E] neighbor ids
    feats: np.ndarray      # [N, d]
    labels: np.ndarray     # [N]

    @property
    def n_nodes(self) -> int:
        return self.indptr.size - 1

    @property
    def n_edges(self) -> int:
        return self.indices.size

    def edge_list(self) -> tuple[np.ndarray, np.ndarray]:
        """(src, dst) arrays; messages flow src -> dst."""
        dst = np.repeat(np.arange(self.n_nodes, dtype=np.int32),
                        np.diff(self.indptr))
        return self.indices.astype(np.int32), dst


def random_graph(n_nodes: int, n_edges: int, d_feat: int, n_classes: int,
                 seed: int = 0) -> CSRGraph:
    """Power-law-ish random graph with features/labels (synthetic stand-in
    for Cora / Reddit / ogbn-products at their published sizes)."""
    rng = np.random.default_rng(seed)
    # preferential-attachment flavored degree distribution
    weights = 1.0 / (1.0 + np.arange(n_nodes, dtype=np.float64)) ** 0.8
    weights /= weights.sum()
    dst = rng.choice(n_nodes, size=n_edges, p=weights).astype(np.int64)
    src = rng.integers(0, n_nodes, size=n_edges, dtype=np.int64)
    order = np.argsort(dst, kind="stable")
    dst_sorted = dst[order]
    indices = src[order].astype(np.int32)
    indptr = np.zeros(n_nodes + 1, np.int64)
    np.add.at(indptr, dst_sorted + 1, 1)
    indptr = np.cumsum(indptr)
    feats = rng.normal(size=(n_nodes, d_feat)).astype(np.float32)
    labels = rng.integers(0, n_classes, size=n_nodes).astype(np.int32)
    return CSRGraph(indptr, indices, feats, labels)


def sample_subgraph(g: CSRGraph, seeds: np.ndarray, fanouts: list[int],
                    rng: np.random.Generator) -> dict:
    """Multi-hop uniform neighbor sampling (GraphSAGE).

    Returns a padded fixed-shape subgraph:
      nodes        [max_nodes]   global node ids (0-padded)
      node_mask    [max_nodes]
      edge_src/dst [max_edges]   *local* indices (padding edges self-loop
                                 onto node 0, which node_mask zeroes)
      edge_mask    [max_edges]
      seed_count   int — first ``seed_count`` local nodes are the seeds
    """
    frontier = np.asarray(seeds, np.int64)
    local_of = {int(n): i for i, n in enumerate(frontier)}
    nodes = list(map(int, frontier))
    src_loc: list[int] = []
    dst_loc: list[int] = []
    for fanout in fanouts:
        next_frontier = []
        for n in frontier:
            lo, hi = g.indptr[n], g.indptr[n + 1]
            deg = hi - lo
            if deg == 0:
                continue
            take = rng.integers(lo, hi, size=min(fanout, int(deg)))
            for t in take:
                nb = int(g.indices[t])
                if nb not in local_of:
                    local_of[nb] = len(nodes)
                    nodes.append(nb)
                    next_frontier.append(nb)
                src_loc.append(local_of[nb])
                dst_loc.append(local_of[int(n)])
        frontier = np.asarray(next_frontier, np.int64)
        if frontier.size == 0:
            break
    max_nodes = subgraph_max_nodes(len(seeds), fanouts)
    max_edges = subgraph_max_edges(len(seeds), fanouts)
    out_nodes = np.zeros(max_nodes, np.int32)
    out_nodes[:len(nodes)] = nodes
    node_mask = np.zeros(max_nodes, np.float32)
    node_mask[:len(nodes)] = 1.0
    e_src = np.zeros(max_edges, np.int32)
    e_dst = np.zeros(max_edges, np.int32)
    e_src[:len(src_loc)] = src_loc
    e_dst[:len(dst_loc)] = dst_loc
    edge_mask = np.zeros(max_edges, np.float32)
    edge_mask[:len(src_loc)] = 1.0
    return {"nodes": out_nodes, "node_mask": node_mask,
            "edge_src": e_src, "edge_dst": e_dst, "edge_mask": edge_mask,
            "seed_count": len(seeds)}


def subgraph_max_nodes(n_seeds: int, fanouts: list[int]) -> int:
    total, layer = n_seeds, n_seeds
    for f in fanouts:
        layer *= f
        total += layer
    return total


def subgraph_max_edges(n_seeds: int, fanouts: list[int]) -> int:
    total, layer = 0, n_seeds
    for f in fanouts:
        total += layer * f
        layer *= f
    return total
