"""Data substrate: synthetic corpora, tokenization, packing, loaders."""
