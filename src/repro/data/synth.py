"""Deterministic synthetic Common-Crawl-like WARC corpus generator.

No network access in this container, so benchmark and pipeline inputs are
generated: realistic record-type mix (request/response/metadata per page +
one warcinfo per file, mirroring Common Crawl's layout), HTTP response
headers, and HTML payloads with Zipf-ish token distributions. Everything is
seeded — corpora are bit-reproducible, which the digest tests rely on.
"""
from __future__ import annotations

import io
import random
from dataclasses import dataclass

from repro.core.warc.checksum import block_digest
from repro.core.warc.writer import WarcWriter, serialize_record

_WORDS = (
    "the of and to in is was for that on as with by at from web archive "
    "crawl data page http html search index text content link site user "
    "time year service new system information large scale analytics record "
    "format library performance processing python research common format "
    "university science compute storage stream parser benchmark result "
).split()

_PATHS = ("index.html", "about", "news/2021/item", "products/view", "blog/post",
          "search?q=warc", "static/page", "docs/spec", "api/v1/items", "home")

_HOSTS = ("example.com", "research.edu", "webarchive.org", "news.example.net",
          "shop.example.io", "wiki.example.org")


@dataclass
class CorpusSpec:
    n_pages: int = 200
    seed: int = 0
    html_words_lo: int = 300
    html_words_hi: int = 3000
    with_requests: bool = True
    with_metadata: bool = True
    digests: bool = True


def _make_html(rng: random.Random, spec: CorpusSpec) -> bytes:
    n = rng.randint(spec.html_words_lo, spec.html_words_hi)
    # Zipf-ish: sample from a small head most of the time
    words = []
    for _ in range(n):
        if rng.random() < 0.8:
            words.append(_WORDS[rng.randrange(12)])
        else:
            words.append(_WORDS[rng.randrange(len(_WORDS))])
    body = " ".join(words)
    title = " ".join(rng.sample(_WORDS, 3))
    links = "".join(
        f'<a href="https://{rng.choice(_HOSTS)}/{rng.choice(_PATHS)}">'
        f"{rng.choice(_WORDS)}</a> " for _ in range(rng.randint(2, 8)))
    return (f"<!doctype html><html><head><title>{title}</title></head>"
            f"<body><p>{body}</p><nav>{links}</nav></body></html>"
            ).encode("utf-8")


def _http_response(rng: random.Random, html: bytes) -> bytes:
    headers = (
        f"HTTP/1.1 200 OK\r\n"
        f"Content-Type: text/html; charset=utf-8\r\n"
        f"Content-Length: {len(html)}\r\n"
        f"Server: nginx/1.{rng.randint(10, 25)}\r\n"
        f"Date: Mon, 01 Mar 2021 0{rng.randint(0, 9)}:00:00 GMT\r\n"
        f"X-Cache: {'HIT' if rng.random() < 0.5 else 'MISS'}\r\n"
        f"\r\n").encode("ascii")
    return headers + html


def _http_request(host: str, path: str) -> bytes:
    return (f"GET /{path} HTTP/1.1\r\nHost: {host}\r\n"
            f"User-Agent: repro-crawler/0.1\r\nAccept: text/html\r\n\r\n"
            ).encode("ascii")


def generate_warc(spec: CorpusSpec, compression: str = "none") -> bytes:
    """Generate one synthetic WARC file; returns the file bytes."""
    import uuid as _uuid

    rng = random.Random(spec.seed)

    def _rid() -> str:  # deterministic record ids: corpora are reproducible
        return f"<urn:uuid:{_uuid.UUID(int=rng.getrandbits(128))}>"

    _date = "2021-03-01T12:00:00Z"
    sink = io.BytesIO()
    writer = WarcWriter(sink, compression)
    writer.write_record(
        "warcinfo",
        b"software: repro-fastwarc-synth/0.1\r\n"
        b"format: WARC File Format 1.1\r\n"
        + f"isPartOf: synthetic-crawl-{spec.seed}\r\n".encode(),
        {"Content-Type": "application/warc-fields",
         "WARC-Record-ID": _rid(), "WARC-Date": _date})
    for _ in range(spec.n_pages):
        host = rng.choice(_HOSTS)
        path = rng.choice(_PATHS)
        uri = f"https://{host}/{path}"
        html = _make_html(rng, spec)
        response = _http_response(rng, html)
        common = {"WARC-Target-URI": uri, "WARC-Date": _date}
        if spec.with_requests:
            writer.write_record(
                "request", _http_request(host, path),
                {**common, "WARC-Record-ID": _rid(),
                 "Content-Type": "application/http; msgtype=request"},
                digests=spec.digests)
        writer.write_record(
            "response", response,
            {**common, "WARC-Record-ID": _rid(),
             "Content-Type": "application/http; msgtype=response",
             "WARC-Payload-Digest": block_digest(html, "sha1")},
            digests=spec.digests)
        if spec.with_metadata:
            meta = (f"fetchTimeMs: {rng.randint(20, 900)}\r\n"
                    f"charset-detected: utf-8\r\n").encode("ascii")
            writer.write_record(
                "metadata", meta,
                {**common, "WARC-Record-ID": _rid(),
                 "Content-Type": "application/warc-fields"},
                digests=spec.digests)
    return sink.getvalue()


def records_in(spec: CorpusSpec) -> int:
    """Total records a spec generates (warcinfo + per-page records)."""
    per_page = 1 + int(spec.with_requests) + int(spec.with_metadata)
    return 1 + spec.n_pages * per_page


def write_corpus(path: str, spec: CorpusSpec, compression: str = "none") -> int:
    data = generate_warc(spec, compression)
    with open(path, "wb") as f:
        f.write(data)
    return len(data)
