"""Byte-level tokenizer (trained-vocab-free, suits offline reproduction).

ids: 0 = PAD, 1 = BOS, 2 = EOS, byte b -> b + 3. Vocab = 259, padded to
384 for lane alignment. Models with larger vocabs simply use a prefix of
their embedding table during the end-to-end example runs.
"""
from __future__ import annotations

import numpy as np

PAD_ID = 0
BOS_ID = 1
EOS_ID = 2
BYTE_OFFSET = 3
VOCAB_SIZE = 384  # 259 used, padded to a multiple of 128


def encode(text: bytes) -> np.ndarray:
    arr = np.frombuffer(bytes(text), dtype=np.uint8).astype(np.int32)
    return arr + BYTE_OFFSET


def encode_document(text: bytes) -> np.ndarray:
    body = encode(text)
    return np.concatenate(([BOS_ID], body, [EOS_ID])).astype(np.int32)


def decode(ids) -> bytes:
    ids = np.asarray(ids)
    ids = ids[ids >= BYTE_OFFSET] - BYTE_OFFSET
    return ids.astype(np.uint8).tobytes()
