"""Roofline analysis from compiled dry-run artifacts (no hardware needed).

**Per-partition semantics**: under SPMD partitioning, both
``cost_analysis()`` and the HLO tensor shapes are *per-chip* quantities,
so each term divides by a single chip's capability:

    compute    = flops_pp      / 197e12 bf16 FLOP/s
    memory     = bytes_pp      / 819e9  B/s HBM
    collective = coll_bytes_pp / 50e9   B/s ICI link

**Loop correction**: XLA's static cost analysis counts a while-loop body
*once* regardless of trip count. Inner scans (attention KV chunks, GRU
time steps, GNN layers) are therefore unrolled in the dry-run lowering;
the LM layer scan (up to 94 layers — unrolling would blow up compile
time) is corrected by the *delta method*: compile the same cell at
n_layers=1 and n_layers=2; the difference is exactly one layer's
(flops, bytes, collectives), so

    total(L) = cell(1) + (L - 1) · (cell(2) - cell(1)).

Collective bytes are NOT in cost_analysis — they are parsed from the HLO
text: result-tensor bytes summed over every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute (sync or -start async
form), the standard per-chip traffic approximation.
"""
from __future__ import annotations

import re
from dataclasses import asdict, dataclass, field

from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

#: one HLO instruction line: results before `=`, op name after
_LINE_RE = re.compile(
    r"=\s*(.*?)\s(" + "|".join(_COLLECTIVES) + r")(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _tensor_bytes(dtype: str, dims: str) -> int:
    size = _DTYPE_BYTES.get(dtype, 4)
    if dims.strip():
        for d in dims.split(","):
            size *= int(d)
    return size


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-collective-kind result bytes (per chip) over the module."""
    out: dict[str, float] = {k: 0.0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        if "-done" in line:
            continue  # async -done re-lists the -start result
        m = _LINE_RE.search(line)
        if not m:
            continue
        results, op = m.groups()
        for dtype, dims in _SHAPE_RE.findall(results):
            if dtype in _DTYPE_BYTES:
                out[op] += _tensor_bytes(dtype, dims)
    out["total"] = sum(out[k] for k in _COLLECTIVES)
    return out


@dataclass
class RawCounts:
    """Per-partition counters from one compiled executable."""
    flops: float
    bytes_accessed: float
    coll: dict[str, float]

    def __sub__(self, other: "RawCounts") -> "RawCounts":
        return RawCounts(
            self.flops - other.flops,
            self.bytes_accessed - other.bytes_accessed,
            {k: self.coll.get(k, 0) - other.coll.get(k, 0)
             for k in self.coll})

    def scaled_add(self, other: "RawCounts", factor: float) -> "RawCounts":
        return RawCounts(
            self.flops + factor * other.flops,
            self.bytes_accessed + factor * other.bytes_accessed,
            {k: self.coll.get(k, 0) + factor * other.coll.get(k, 0)
             for k in self.coll})


def raw_counts(compiled) -> RawCounts:
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):
        cost = cost[0]
    return RawCounts(
        flops=float(cost.get("flops", 0.0)),
        bytes_accessed=float(cost.get("bytes accessed", 0.0)),
        coll=collective_bytes(compiled.as_text()),
    )


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_pp: float            # per-partition (per chip)
    bytes_pp: float
    coll_bytes_pp: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float = 0.0   # global analytic 6·N_active·D
    useful_ratio: float = 0.0  # model_flops / (flops_pp × chips)
    coll_breakdown: dict = field(default_factory=dict)

    def to_dict(self) -> dict:
        return asdict(self)


def terms_from_counts(rc: RawCounts, *, arch: str, shape: str,
                      mesh_name: str, chips: int,
                      model_flops: float = 0.0) -> RooflineTerms:
    compute_s = rc.flops / PEAK_FLOPS_BF16
    memory_s = rc.bytes_accessed / HBM_BW
    collective_s = rc.coll.get("total", 0.0) / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": collective_s}
    dominant = max(terms, key=terms.get)
    global_flops = rc.flops * chips
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_pp=rc.flops, bytes_pp=rc.bytes_accessed,
        coll_bytes_pp=rc.coll.get("total", 0.0),
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant, model_flops=model_flops,
        useful_ratio=(model_flops / global_flops) if global_flops else 0.0,
        coll_breakdown={k: v for k, v in rc.coll.items() if k != "total"},
    )


def analyze_compiled(compiled, *, arch: str, shape: str, mesh_name: str,
                     chips: int, model_flops: float = 0.0) -> RooflineTerms:
    """Single-executable analysis (callers with loops use the delta path)."""
    return terms_from_counts(
        raw_counts(compiled), arch=arch, shape=shape, mesh_name=mesh_name,
        chips=chips, model_flops=model_flops)


def model_flops_lm(cfg, batch: int, seq: int, *, training: bool) -> float:
    """MODEL_FLOPS = 6·N_active·D training / 2·N_active·D forward."""
    mult = 6 if training else 2
    return mult * cfg.active_param_count() * batch * seq


def model_flops_decode(cfg, batch: int) -> float:
    return 2 * cfg.active_param_count() * batch


def fraction_of_roofline(terms: RooflineTerms) -> float:
    """dominant / (sum of terms): 1.0 ⇒ perfect overlap would hide the
    non-dominant phases entirely; low values ⇒ balanced (bad) profiles."""
    total = terms.compute_s + terms.memory_s + terms.collective_s
    if total == 0:
        return 0.0
    return max(terms.compute_s, terms.memory_s, terms.collective_s) / total
