"""Generate the EXPERIMENTS.md §Dry-run / §Roofline tables from results.

Usage: PYTHONPATH=src python -m repro.roofline.report [results.json]
Emits markdown to stdout; the EXPERIMENTS.md sections are pasted from it.
"""
from __future__ import annotations

import json
import sys

from repro.launch.mesh import HBM_BYTES


def _fmt_bytes(n) -> str:
    if n is None:
        return "-"
    return f"{n/2**30:.2f}"


def _fmt_s(x) -> str:
    if x >= 0.1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}µ"


def dryrun_table(records: list[dict]) -> str:
    out = ["| arch | shape | mesh | status | compile s | per-dev GiB | fits |",
           "|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        if r["status"] == "skipped":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"SKIP (see DESIGN.md §5) | - | - | - |")
            continue
        if r["status"] == "error":
            out.append(f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                       f"ERROR | - | - | - |")
            continue
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | "
            f"{r.get('compile_s', 0) + r.get('delta_compile_s', 0):.0f} | "
            f"{_fmt_bytes(r.get('per_device_bytes'))} | "
            f"{'✓' if r.get('fits_hbm') else '✗ OVER'} |")
    return "\n".join(out)


def roofline_table(records: list[dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant "
           "| frac | useful | bottleneck note |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(records, key=lambda r: (r["arch"], r["shape"])):
        if r.get("mesh") != mesh or r.get("status") != "ok":
            continue
        rf = r["roofline"]
        note = _bottleneck_note(rf)
        useful = f"{rf['useful_ratio']:.2f}" if rf["model_flops"] else "-"
        out.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(rf['compute_s'])} | "
            f"{_fmt_s(rf['memory_s'])} | {_fmt_s(rf['collective_s'])} | "
            f"{rf['dominant']} | {rf['fraction_dominant']:.2f} | {useful} | "
            f"{note} |")
    return "\n".join(out)


def _bottleneck_note(rf: dict) -> str:
    dom = rf["dominant"]
    br = rf.get("coll_breakdown") or {}
    if dom == "collective":
        top = max(br, key=br.get) if br else "?"
        return (f"{top} dominates ({br.get(top, 0)/2**30:.1f} GiB/chip); "
                "reshard or overlap it")
    if dom == "memory":
        return "HBM-traffic bound; increase fusion/arithmetic intensity"
    return "compute bound — at roofline when overlapped"


def main() -> None:
    path = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun_results.json"
    with open(path) as f:
        records = json.load(f)
    ok = [r for r in records if r.get("status") == "ok"]
    sk = [r for r in records if r.get("status") == "skipped"]
    err = [r for r in records if r.get("status") == "error"]
    print(f"## Dry-run matrix ({len(ok)} ok / {len(sk)} skipped / "
          f"{len(err)} errors)\n")
    print(dryrun_table(records))
    print("\n## Roofline (single-pod 16x16, per-chip terms)\n")
    print(roofline_table(records, "16x16"))
    print("\n## Roofline (multi-pod 2x16x16)\n")
    print(roofline_table(records, "2x16x16"))


if __name__ == "__main__":
    main()
