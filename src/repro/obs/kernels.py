"""Kernel dispatch profiler for the Pallas wrapper call sites.

Every bucketed dispatch in ``adler32_batch``, ``find_pattern_mask_batch``
/ ``find_pattern_masks_multi`` and ``digest_signature_batch`` reports
here. The profiler surfaces what power-of-two bucketing hides:

* ``kernel.<name>.dispatches`` / ``.rows`` — dispatch and row volume;
* ``kernel.<name>.useful_bytes`` vs ``.padded_bytes`` — the real payload
  bytes vs the (padded_rows × width) matrix actually shipped to the
  kernel; the difference is pad waste;
* per width bucket: ``kernel.<name>.w<width>.{dispatches,useful_bytes,
  padded_bytes}`` — which buckets burn the padding;
* ``kernel.<name>.shape_compiles`` vs ``.shape_reuses`` — distinct
  (width, padded_rows) shapes seen in-process vs dispatches that hit an
  already-compiled shape. Compiled-shape caching is per process (and
  survives ``fork``), so the seen-set here is process-global and
  deliberately *not* tied to any one registry.

Recording is always on: a dispatch already amortizes hundreds of records,
so a handful of locked counter adds per dispatch is noise.
"""
from __future__ import annotations

import threading
from typing import Dict, Set, Tuple

from repro.obs.registry import ObsSnapshot

__all__ = ["pad_waste_report", "record_dispatch", "reset_shape_cache"]

_seen_shapes: Set[Tuple[str, int, int]] = set()
_shapes_lock = threading.Lock()


def record_dispatch(kernel: str, *, width: int, rows: int,
                    padded_rows: int, useful_bytes: int) -> None:
    """Account one bucketed kernel dispatch.

    ``rows`` is the number of real rows packed, ``padded_rows`` the row
    count after padding (== rows for wrappers that don't pad rows), and
    ``useful_bytes`` the sum of true payload sizes in the bucket.
    """
    from repro import obs

    reg = obs.registry()
    padded_bytes = padded_rows * width
    base = f"kernel.{kernel}"
    with _shapes_lock:
        shape = (kernel, width, padded_rows)
        fresh = shape not in _seen_shapes
        if fresh:
            _seen_shapes.add(shape)
    reg.fold_counters({
        f"{base}.dispatches": 1,
        f"{base}.rows": rows,
        f"{base}.useful_bytes": useful_bytes,
        f"{base}.padded_bytes": padded_bytes,
        f"{base}.w{width}.dispatches": 1,
        f"{base}.w{width}.useful_bytes": useful_bytes,
        f"{base}.w{width}.padded_bytes": padded_bytes,
        (f"{base}.shape_compiles" if fresh else f"{base}.shape_reuses"): 1,
    })


def reset_shape_cache() -> None:
    """Forget seen shapes (tests only — the real compile cache is jax's)."""
    with _shapes_lock:
        _seen_shapes.clear()


def pad_waste_report(snap: ObsSnapshot) -> Dict[str, Dict[str, object]]:
    """Distill per-kernel pad-waste and shape-reuse from a snapshot.

    Returns ``{kernel: {dispatches, useful_bytes, padded_bytes,
    pad_waste_ratio, shape_reuse_rate, buckets: {width: waste_ratio}}}``.
    """
    out: Dict[str, Dict[str, object]] = {}
    for name, v in snap.counters.items():
        if not name.startswith("kernel."):
            continue
        parts = name.split(".")
        if len(parts) < 3:
            continue
        kern = parts[1]
        k = out.setdefault(kern, {"dispatches": 0, "useful_bytes": 0,
                                  "padded_bytes": 0, "shape_compiles": 0,
                                  "shape_reuses": 0, "buckets": {}})
        tail = parts[2]
        if tail in ("dispatches", "useful_bytes", "padded_bytes",
                    "shape_compiles", "shape_reuses") and len(parts) == 3:
            k[tail] += v
        elif tail.startswith("w") and tail[1:].isdigit() and len(parts) == 4:
            b = k["buckets"].setdefault(int(tail[1:]),
                                        {"useful_bytes": 0,
                                         "padded_bytes": 0, "dispatches": 0})
            b[parts[3]] += v
    for k in out.values():
        padded = k["padded_bytes"]
        k["pad_waste_ratio"] = (
            1.0 - k["useful_bytes"] / padded if padded else 0.0)
        disp = k["shape_compiles"] + k["shape_reuses"]
        k["shape_reuse_rate"] = k["shape_reuses"] / disp if disp else 0.0
        for b in k["buckets"].values():
            bp = b["padded_bytes"]
            b["pad_waste_ratio"] = (
                1.0 - b["useful_bytes"] / bp if bp else 0.0)
    return out
