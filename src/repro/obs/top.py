"""``python -m repro.obs.top`` — live terminal view of a serve gateway.

Polls a gateway's merged :meth:`~repro.serve.ArchiveGateway.snapshot`
on an interval and renders the headline serving signals the way
``top(1)`` renders a host: requests/s and responses/s (counter deltas
between polls), queue depth + high-water, coalesce rate, dispatches per
request, cache hit rate, timeout/reject/error totals, and the
per-stage p50/p99 attribution table (from the request-scoped tracing
histograms, :mod:`repro.obs.export`).

Modes:

* ``--demo`` — build a tiny synthetic corpus, start a traced gateway,
  drive it with background client threads, and render live (the
  self-contained way to *see* the instrument; ``--iterations N`` bounds
  the run, which is also what the tests use);
* ``--file SNAP.json`` — render one frame from a saved snapshot (an
  ``ObsSnapshot.as_dict()`` file, a flight of ``gw.snapshot()``, or a
  ``BENCH_*.json`` with an embedded ``obs`` payload). Rates need two
  samples, so counter-rate fields render as totals.

The renderer itself (:func:`render`) is a pure function of (current
snapshot, previous snapshot, dt) — testable without a terminal.
"""
from __future__ import annotations

import argparse
import json
import sys
import time
from typing import Optional

from repro.obs.export import breakdown_from_snapshot, render_stage_table
from repro.obs.registry import ObsSnapshot

__all__ = ["main", "render"]

_CLEAR = "\x1b[2J\x1b[H"


def _rate(cur: ObsSnapshot, prev: Optional[ObsSnapshot], name: str,
          dt: float) -> float:
    if prev is None or dt <= 0:
        return 0.0
    return (cur.counter(name) - prev.counter(name)) / dt


def render(snap: ObsSnapshot, prev: Optional[ObsSnapshot] = None,
           dt: float = 0.0, *, clock: str = "") -> str:
    """One dashboard frame from a merged gateway snapshot."""
    c = snap.counter
    requests = c("gateway.requests")
    responses = max(c("gateway.responses"), 1)
    cache_hits = c("gateway.cache.hits")
    cache_total = cache_hits + c("gateway.cache.misses")
    lines = [
        f"repro.obs.top — archive gateway {clock}".rstrip(),
        "",
        f"req/s {_rate(snap, prev, 'gateway.requests', dt):>8.1f}   "
        f"resp/s {_rate(snap, prev, 'gateway.responses', dt):>8.1f}   "
        f"queue {snap.gauge('gateway.queue_depth'):>4.0f} "
        f"(hw {snap.gauge('gateway.queue_depth_highwater'):.0f})",
        f"requests {requests}   coalesced {c('gateway.coalesced')} "
        f"({c('gateway.coalesced') / max(requests, 1) * 100:.1f}%)   "
        f"dispatches/req "
        f"{c('gateway.kernel_dispatches') / responses:.2f}   "
        f"cache hit "
        f"{cache_hits / cache_total * 100 if cache_total else 0.0:.1f}%",
        f"latency p50 {snap.quantile('gateway.latency_s', 50) * 1e3:.1f} ms"
        f"   p99 {snap.quantile('gateway.latency_s', 99) * 1e3:.1f} ms   "
        f"timeouts {c('gateway.timeouts')}   "
        f"rejected {c('gateway.rejected')}   errors {c('gateway.errors')}   "
        f"flight dumps {c('flight.dumps') + c('gateway.flight_dumps')}",
        "",
    ]
    breakdown = breakdown_from_snapshot(snap)
    if breakdown:
        lines.append(render_stage_table(breakdown))
    else:
        lines.append("(no gateway.stage.* histograms — request tracing off?)")
    return "\n".join(lines) + "\n"


def _load_snapshot_file(path: str) -> ObsSnapshot:
    with open(path, "r", encoding="utf-8") as f:
        data = json.load(f)
    if "counters" not in data and isinstance(data.get("obs"), dict):
        data = data["obs"]
    if "counters" not in data:
        raise ValueError(
            f"{path} holds no obs snapshot (no 'counters' key and no "
            f"embedded 'obs' payload)")
    return ObsSnapshot.from_dict(data)


def _run_demo(interval: float, iterations: Optional[int],
              out=sys.stdout) -> int:
    import os
    import tempfile
    import threading

    from repro.data.synth import CorpusSpec, write_corpus
    from repro.index import QueryRequest, build_index
    from repro.serve import ArchiveGateway

    patterns = (b"nginx", b"crawl", b"archive", b"absent-needle!")
    with tempfile.TemporaryDirectory(prefix="repro-obs-top-") as tmp:
        paths = []
        for i in range(2):
            p = os.path.join(tmp, f"shard-{i}.warc.gz")
            write_corpus(p, CorpusSpec(n_pages=30, seed=i), "gzip")
            paths.append(p)
        index = build_index(paths)
        stop = threading.Event()
        with ArchiveGateway(index, cache_bytes=1 << 20) as gw:

            def client(seed: int) -> None:
                k = seed
                while not stop.is_set():
                    req = QueryRequest(patterns[k % len(patterns)], top_k=3)
                    k += 1
                    try:
                        gw.submit(req).result(600)
                    except Exception:
                        return

            clients = [threading.Thread(target=client, args=(i,), daemon=True)
                       for i in range(4)]
            for t in clients:
                t.start()
            try:
                prev, t_prev, n = None, time.perf_counter(), 0
                while iterations is None or n < iterations:
                    time.sleep(interval)
                    snap = gw.snapshot()
                    now = time.perf_counter()
                    out.write(_CLEAR if out.isatty() else "")
                    out.write(render(snap, prev, now - t_prev,
                                     clock=time.strftime("%H:%M:%S")))
                    out.flush()
                    prev, t_prev = snap, now
                    n += 1
            except KeyboardInterrupt:
                pass
            finally:
                stop.set()
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top",
        description="Live terminal dashboard for the archive gateway.")
    ap.add_argument("--demo", action="store_true",
                    help="drive a synthetic traced gateway and watch it")
    ap.add_argument("--file", default=None,
                    help="render one frame from a saved snapshot JSON")
    ap.add_argument("--interval", type=float, default=1.0,
                    help="poll interval in seconds (demo mode)")
    ap.add_argument("--iterations", type=int, default=None,
                    help="stop after N frames (demo mode; default: run "
                         "until interrupted)")
    args = ap.parse_args(argv)
    if bool(args.demo) == bool(args.file):
        ap.error("choose exactly one of --demo / --file")
    if args.file:
        try:
            snap = _load_snapshot_file(args.file)
        except ValueError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2
        sys.stdout.write(render(snap))
        return 0
    return _run_demo(args.interval, args.iterations)


if __name__ == "__main__":
    raise SystemExit(main())
