"""``repro.obs`` — unified observability layer.

One process-default :class:`~repro.obs.registry.Registry` holds counters,
gauges and bounded-reservoir histograms for everything in this process:
the zero-copy parser's CopyStats/ErrorLedger totals, trace spans
(``repro.obs.trace``, disabled by default), and the always-on kernel
dispatch profiler (``repro.obs.kernels``). Child processes publish their
own registries through shared-memory stats blocks
(``repro.obs.shmstats``); the pool supervisor and the readahead decoder
teardown harvest them, so a merged :class:`ObsSnapshot` spans the whole
process tree. Export as JSON (:meth:`ObsSnapshot.to_json`), Prometheus
text (:func:`render_prometheus`), or via ``python -m repro.obs.dump``.

Request-scoped tracing (PR 8) layers on top: span trees
(``repro.obs.trace``), the always-on bounded flight recorder with
anomaly auto-dump (``repro.obs.flight``), Chrome-trace / stage-breakdown
exporters (``repro.obs.export``) and the live ``python -m repro.obs.top``
dashboard.
"""
from __future__ import annotations

from typing import Iterable, Optional

from repro.obs.registry import (
    HISTOGRAM_CAP,
    ObsSnapshot,
    Registry,
    percentile,
    render_prometheus,
)
from repro.obs import export, flight, trace

__all__ = [
    "HISTOGRAM_CAP",
    "ObsSnapshot",
    "Registry",
    "export",
    "flight",
    "merge",
    "percentile",
    "registry",
    "render_prometheus",
    "reset",
    "set_registry",
    "snapshot",
    "trace",
]

_default = Registry(source="parent")


def registry() -> Registry:
    """The process-default registry every always-on producer writes to."""
    return _default


def set_registry(reg: Registry) -> Registry:
    """Swap the process-default registry (pool workers install a fresh
    one after fork so inherited parent counters don't double-count).
    Returns the previous registry."""
    global _default
    prev = _default
    _default = reg
    return prev


def snapshot(source: Optional[str] = None) -> ObsSnapshot:
    """Snapshot the process-default registry."""
    return _default.snapshot(source=source)


def merge(snaps: Iterable[ObsSnapshot]) -> ObsSnapshot:
    return ObsSnapshot.merge(snaps)


def reset() -> None:
    """Clear the process-default registry (tests and benches)."""
    _default.reset()
