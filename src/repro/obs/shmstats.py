"""Seqlock-framed shared-memory stats blocks for child processes.

A pool worker (or the readahead decoder child) publishes cumulative
:class:`~repro.obs.registry.ObsSnapshot` pickles into a fixed slot of a
shared-memory segment the *parent* owns; the parent harvests whenever it
likes (supervisor tick, stream end, ``close()``) without any handshake.
Because the parent owns the segment, a SIGKILLed child's last published
snapshot survives it — that is the whole point: worker counters used to
die with the worker.

Slot layout (little-endian)::

    u64 seq     even = stable, odd = write in progress (seqlock)
    u32 len     payload byte length
    len bytes   pickled ObsSnapshot

Writers bump ``seq`` to odd, write payload+len, then bump to even;
readers retry on odd or torn (seq changed mid-read) frames. A snapshot
too large for the slot is dropped on the floor (publishing is best
effort — the counters are cumulative, the next smaller publish or the
final one usually fits; oversize drops are themselves counted by the
writer under ``obs.stats_publish_oversize``).
"""
from __future__ import annotations

import pickle
import struct
from typing import Optional

from repro.obs.registry import ObsSnapshot

__all__ = ["STATS_SLOT_BYTES", "StatsSlotReader", "StatsSlotWriter"]

#: Per-worker slot size. Snapshots are a few KiB of counters; 32 KiB
#: leaves headroom for histogram reservoirs without bloating segments.
STATS_SLOT_BYTES = 32 << 10

_HDR = struct.Struct("<QI")


class StatsSlotWriter:
    """Child-side publisher for one stats slot (a memoryview into shm)."""

    __slots__ = ("_buf", "_seq", "oversize_drops")

    def __init__(self, buf) -> None:
        self._buf = memoryview(buf)
        self._seq = _HDR.unpack_from(self._buf, 0)[0]
        if self._seq & 1:  # stale odd marker from a dead predecessor
            self._seq += 1
        self.oversize_drops = 0

    def publish(self, snap: ObsSnapshot) -> bool:
        payload = pickle.dumps(snap, protocol=pickle.HIGHEST_PROTOCOL)
        cap = len(self._buf) - _HDR.size
        if len(payload) > cap:
            self.oversize_drops += 1
            return False
        seq = self._seq + 1  # odd: write in progress
        _HDR.pack_into(self._buf, 0, seq, len(payload))
        self._buf[_HDR.size:_HDR.size + len(payload)] = payload
        self._seq = seq + 1  # even: stable
        _HDR.pack_into(self._buf, 0, self._seq, len(payload))
        return True

    def close(self) -> None:
        """Release the memoryview export (must precede ``shm.close()``)."""
        self._buf.release()


class StatsSlotReader:
    """Parent-side harvester for one stats slot."""

    __slots__ = ("_buf",)

    def __init__(self, buf) -> None:
        self._buf = memoryview(buf)

    def read(self, retries: int = 8) -> Optional[ObsSnapshot]:
        """Latest stable snapshot in the slot, or ``None`` if the slot is
        empty, torn beyond ``retries``, or holds a corrupt frame."""
        for _ in range(retries):
            seq1, length = _HDR.unpack_from(self._buf, 0)
            if seq1 == 0 and length == 0:
                return None  # never written
            if seq1 & 1:
                continue  # write in progress
            if length > len(self._buf) - _HDR.size:
                return None
            payload = bytes(self._buf[_HDR.size:_HDR.size + length])
            seq2 = _HDR.unpack_from(self._buf, 0)[0]
            if seq1 != seq2:
                continue  # torn: overwritten mid-read
            try:
                snap = pickle.loads(payload)
            except Exception:
                return None
            return snap if isinstance(snap, ObsSnapshot) else None
        return None

    def close(self) -> None:
        """Release the memoryview export (must precede ``shm.close()``)."""
        self._buf.release()
