"""Snapshot export CLI: ``python -m repro.obs.dump``.

Three modes:

* ``python -m repro.obs.dump snapshot.json`` — render a saved
  :meth:`~repro.obs.ObsSnapshot.as_dict` JSON file (e.g. the ``obs``
  section of a ``BENCH_*.json``) as JSON or Prometheus text.
* ``python -m repro.obs.dump --ingest SHARD...`` — sweep the given WARC
  shards with the zero-copy parser and dump the resulting process
  snapshot (ingest counters, kernel dispatches if any fired).
* ``python -m repro.obs.dump --demo`` — one synthetic ingest-to-serve
  run: gzip shards are written, swept serially (readahead decoder
  child), indexed with a 2-worker pool, and queried through an
  :class:`~repro.serve.ArchiveGateway`; the printed snapshot is the
  merge of every layer — parent, pool workers, decoder child, gateway —
  which is also what CI uploads as its Prometheus artifact.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs.registry import ObsSnapshot, render_prometheus


def _demo_snapshot() -> ObsSnapshot:
    """Synthetic ingest-to-serve run; returns the full merged snapshot."""
    import os
    import tempfile

    from repro import obs
    from repro.core.warc.fastwarc import FastWARCIterator
    from repro.data.synth import CorpusSpec, write_corpus
    from repro.index import QueryRequest, build_index
    from repro.serve import ArchiveGateway

    with tempfile.TemporaryDirectory(prefix="repro-obs-demo-") as tmp:
        paths = []
        for i in range(3):
            p = os.path.join(tmp, f"shard-{i}.warc.gz")
            write_corpus(p, CorpusSpec(n_pages=40, seed=i), "gzip")
            paths.append(p)
        # serial readahead sweep: decode runs in a child process whose
        # decoder.* counters are harvested into the parent registry
        for _ in FastWARCIterator(paths[0]):
            pass
        # pooled index build: worker ingest.* counters flow through the
        # pool's stats slots and are absorbed into the process registry
        # at pool close (index.obs is that same snapshot)
        index = build_index(paths, workers=2)
        with ArchiveGateway(index, cache_bytes=1 << 20) as gw:
            for pattern in (b"nginx", b"crawl", b"absent-needle!"):
                gw.submit(QueryRequest(pattern, top_k=3)).result(600)
            # gw.snapshot() = process registry (parent + absorbed decoder
            # child + absorbed pool workers) merged with the gateway's
            # private registry: already the whole tree, counted once
            return gw.snapshot()


def _ingest_snapshot(paths) -> ObsSnapshot:
    from repro import obs
    from repro.core.warc.fastwarc import FastWARCIterator

    for p in paths:
        for _ in FastWARCIterator(p):
            pass
    return obs.snapshot()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.dump",
        description="Render repro observability snapshots.")
    ap.add_argument("snapshot", nargs="?", default=None,
                    help="saved ObsSnapshot JSON file to render")
    ap.add_argument("--ingest", nargs="+", metavar="SHARD", default=None,
                    help="sweep these WARC shards and dump the snapshot")
    ap.add_argument("--demo", action="store_true",
                    help="synthetic ingest-to-serve run (no inputs needed)")
    ap.add_argument("--format", choices=("json", "prom"), default="json")
    ap.add_argument("--out", default=None,
                    help="write here instead of stdout")
    args = ap.parse_args(argv)

    if sum(bool(x) for x in (args.snapshot, args.ingest, args.demo)) != 1:
        ap.error("choose exactly one of: a snapshot file, --ingest, --demo")
    if args.demo:
        snap = _demo_snapshot()
    elif args.ingest:
        snap = _ingest_snapshot(args.ingest)
    else:
        with open(args.snapshot, "r", encoding="utf-8") as f:
            data = json.load(f)
        if "counters" not in data and isinstance(data.get("obs"), dict):
            data = data["obs"]  # a BENCH_*.json: unwrap its obs section
        if "counters" not in data:
            # a BENCH file from before obs embedding (or some unrelated
            # JSON): say so instead of rendering an empty snapshot
            print(f"error: {args.snapshot} holds no obs snapshot (no "
                  f"'counters' key and no embedded 'obs' payload) — "
                  f"regenerate it with benchmarks/run.py --json",
                  file=sys.stderr)
            return 2
        snap = ObsSnapshot.from_dict(data)

    text = render_prometheus(snap) if args.format == "prom" \
        else snap.to_json(indent=2) + "\n"
    if args.out:
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(text)
    else:
        sys.stdout.write(text)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
