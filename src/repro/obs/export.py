"""Span exporters: Chrome ``trace_event`` JSON + per-stage breakdown tables.

Two consumers, two shapes:

* :func:`chrome_trace` turns a span list (usually
  ``flight_recorder.spans()`` or a flight dump) into the Chrome
  ``trace_event`` format — complete (``"ph": "X"``) events with
  microsecond timestamps, one ``tid`` per producing thread, thread-name
  metadata events, and trace/span/parent ids under ``args`` — loadable
  in ``chrome://tracing`` and Perfetto as-is.
* :func:`breakdown_from_snapshot` / :func:`breakdown_from_spans` distill
  *where the time went*: per-stage count, total seconds, p50/p99 and
  share-of-total. The snapshot variant reads the gateway's
  ``gateway.stage.<name>_s`` histograms (complete counts — rings are
  bounded, registries are not) and is what ``benchmarks/serve_bench.py``
  uses to attribute the 64-client cliff; the span variant works on any
  span list (e.g. one trace tree out of a dump).

``share`` is each stage's fraction of the summed stage time. Stages mix
per-request spans (``queue_wait``) with per-batch spans shared by many
requests (``cache_fill``, ``kernel_dispatch``), so shares answer "which
stage burns the wall time" — exactly the attribution question — not
"what does one request pay", which is what the p50/p99 columns are for.
"""
from __future__ import annotations

import json
import os
from typing import Dict, Iterable, List, Mapping, Optional

from repro.obs.registry import ObsSnapshot, percentile
from repro.obs.trace import Span

__all__ = ["breakdown_from_snapshot", "breakdown_from_spans",
           "chrome_trace", "dominant_stage", "render_stage_table",
           "write_chrome_trace"]


def chrome_trace(spans: Iterable[Span], *,
                 process_name: str = "repro") -> dict:
    """Chrome/Perfetto ``trace_event`` JSON object for a span list."""
    pid = os.getpid()
    tids: Dict[str, int] = {}
    events: List[dict] = [{
        "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
        "args": {"name": process_name},
    }]
    body: List[dict] = []
    for s in sorted(spans, key=lambda s: s.t0):
        if s.t1 is None:
            continue
        tid = tids.get(s.thread)
        if tid is None:
            tid = tids[s.thread] = len(tids) + 1
            events.append({"name": "thread_name", "ph": "M", "pid": pid,
                           "tid": tid, "args": {"name": s.thread}})
        args = {"trace_id": s.trace_id, "span_id": s.span_id,
                "parent_id": s.parent_id}
        if s.attrs:
            args.update({k: v for k, v in s.attrs.items()
                         if isinstance(v, (str, int, float, bool))})
        body.append({
            "name": s.name,
            "cat": s.name.split(".", 1)[0],
            "ph": "X",
            "ts": s.as_dict()["t0_us"],
            "dur": (s.t1 - s.t0) * 1e6,
            "pid": pid,
            "tid": tid,
            "args": args,
        })
    return {"traceEvents": events + body, "displayTimeUnit": "ms"}


def write_chrome_trace(path: str, spans: Iterable[Span], **kw) -> str:
    with open(path, "w", encoding="utf-8") as f:
        json.dump(chrome_trace(spans, **kw), f)
        f.write("\n")
    return path


def _finalize(out: Dict[str, dict]) -> Dict[str, dict]:
    total = sum(v["total_s"] for v in out.values())
    for v in out.values():
        v["share"] = v["total_s"] / total if total else 0.0
    return dict(sorted(out.items(), key=lambda kv: -kv[1]["total_s"]))


def breakdown_from_spans(spans: Iterable[Span]) -> Dict[str, dict]:
    """Per-stage attribution from a span list: ``{name: {count,
    total_s, p50_ms, p99_ms, share}}``, sorted by total time."""
    groups: Dict[str, List[float]] = {}
    for s in spans:
        if s.t1 is None:
            continue
        groups.setdefault(s.name, []).append(s.t1 - s.t0)
    out = {}
    for name, durs in groups.items():
        out[name] = {
            "count": len(durs),
            "total_s": sum(durs),
            "p50_ms": percentile(durs, 50) * 1e3,
            "p99_ms": percentile(durs, 99) * 1e3,
        }
    return _finalize(out)


def breakdown_from_snapshot(snap: ObsSnapshot | Mapping,
                            prefix: str = "gateway.stage."
                            ) -> Dict[str, dict]:
    """Per-stage attribution from the stage histograms of a snapshot
    (or its :meth:`~repro.obs.ObsSnapshot.as_dict` form). Histogram
    names ``<prefix><stage>_s`` become stage keys; counts and sums are
    exact (reservoir sampling bounds only the quantile samples)."""
    hists = snap.histograms if isinstance(snap, ObsSnapshot) \
        else snap.get("histograms", {})
    out: Dict[str, dict] = {}
    for name, h in hists.items():
        if not name.startswith(prefix) or not name.endswith("_s"):
            continue
        stage = name[len(prefix):-2]
        samples = sorted(h.get("samples", ()))
        if samples:
            p50, p99 = percentile(samples, 50), percentile(samples, 99)
        else:  # as_dict form: pre-computed quantiles, no raw samples
            p50, p99 = h.get("p50", 0.0), h.get("p99", 0.0)
        out[stage] = {
            "count": h["count"],
            "total_s": h["sum"],
            "p50_ms": p50 * 1e3,
            "p99_ms": p99 * 1e3,
        }
    return _finalize(out)


def dominant_stage(breakdown: Mapping[str, Mapping]) -> Optional[str]:
    """The stage burning the most total time, or ``None`` if empty."""
    if not breakdown:
        return None
    return max(breakdown, key=lambda k: breakdown[k]["total_s"])


def render_stage_table(breakdown: Mapping[str, Mapping]) -> str:
    """Fixed-width text table of a stage breakdown (for `obs.top` and
    humans reading bench logs)."""
    lines = [f"{'stage':<18} {'count':>8} {'p50 ms':>9} {'p99 ms':>9} "
             f"{'total s':>9} {'share':>6}"]
    for name, v in breakdown.items():
        lines.append(
            f"{name:<18} {v['count']:>8} {v['p50_ms']:>9.2f} "
            f"{v['p99_ms']:>9.2f} {v['total_s']:>9.3f} "
            f"{v['share'] * 100:>5.1f}%")
    return "\n".join(lines)
