"""Shared metrics registry: counters, gauges, reservoir histograms.

One :class:`Registry` per process (see :func:`repro.obs.registry`), plus
private instances wherever isolation matters (each ``ArchiveGateway``
owns one so two gateways in a process don't cross-count). Everything is
guarded by a single lock — writers are short (a dict add), so contention
is negligible next to the work being measured.

Histograms are **bounded reservoirs**: exact below ``cap`` samples,
Algorithm-R sampling beyond, with a per-name seeded RNG so the same
observation sequence always yields the same reservoir. Quantiles use the
same linear interpolation the gateway has always reported
(:func:`percentile`), so p50/p99 numbers stay comparable across PRs.

Snapshots (:class:`ObsSnapshot`) are plain picklable data: they cross
process boundaries through the shm stats blocks (`repro.obs.shmstats`),
merge deterministically (counters sum, gauges max, histogram reservoirs
sort-merge then stride-decimate), and render to JSON or Prometheus text.
"""
from __future__ import annotations

import json
import random
import re
import threading
import zlib
from dataclasses import dataclass, field
from typing import Any, Dict, Iterable, List, Mapping, Optional, Tuple

__all__ = [
    "HISTOGRAM_CAP",
    "ObsSnapshot",
    "Registry",
    "percentile",
    "render_prometheus",
]

#: Reservoir bound: histograms are exact below this many observations.
HISTOGRAM_CAP = 4096


def percentile(values: List[float], q: float) -> float:
    """Linear-interpolated percentile (``q`` in [0, 100]) of a list."""
    if not values:
        return 0.0
    data = sorted(values)
    if len(data) == 1:
        return data[0]
    pos = (len(data) - 1) * (q / 100.0)
    lo = int(pos)
    hi = min(lo + 1, len(data) - 1)
    frac = pos - lo
    return data[lo] * (1.0 - frac) + data[hi] * frac


class _Reservoir:
    """Bounded sample reservoir: exact below ``cap``, Algorithm R beyond.

    The RNG is seeded from the histogram *name*, so a fixed observation
    sequence produces a fixed reservoir — snapshot merges and test
    assertions stay deterministic.
    """

    __slots__ = ("cap", "count", "total", "min", "max", "samples", "_rng")

    def __init__(self, name: str, cap: int = HISTOGRAM_CAP):
        self.cap = cap
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self.samples: List[float] = []
        self._rng = random.Random(0x5EED ^ zlib.crc32(name.encode()))

    def observe(self, value: float) -> None:
        self.count += 1
        self.total += value
        if value < self.min:
            self.min = value
        if value > self.max:
            self.max = value
        if len(self.samples) < self.cap:
            self.samples.append(value)
        else:
            j = self._rng.randrange(self.count)
            if j < self.cap:
                self.samples[j] = value

    def summary(self) -> Dict[str, Any]:
        return {
            "count": self.count,
            "sum": self.total,
            "min": self.min if self.count else 0.0,
            "max": self.max if self.count else 0.0,
            "samples": list(self.samples),
        }


def _decimate(sorted_samples: List[float], cap: int) -> List[float]:
    """Deterministic stride-decimation of a sorted sample list to ``cap``.

    Keeps both endpoints, so min/max survive and quantiles stay stable.
    """
    n = len(sorted_samples)
    if n <= cap:
        return sorted_samples
    return [sorted_samples[round(i * (n - 1) / (cap - 1))] for i in range(cap)]


def _merge_hist(a: Mapping[str, Any], b: Mapping[str, Any],
                cap: int = HISTOGRAM_CAP) -> Dict[str, Any]:
    count = a["count"] + b["count"]
    merged = sorted(list(a["samples"]) + list(b["samples"]))
    return {
        "count": count,
        "sum": a["sum"] + b["sum"],
        "min": min(a["min"], b["min"]) if count else 0.0,
        "max": max(a["max"], b["max"]) if count else 0.0,
        "samples": _decimate(merged, cap),
    }


@dataclass
class ObsSnapshot:
    """Point-in-time, picklable view of a registry (or a merge of many).

    ``sources`` records which processes contributed: the parent registry
    snapshots as ``("parent",)``, pool workers as ``worker-<id>.<gen>``,
    the readahead decoder child as ``readahead-decoder``.
    """

    counters: Dict[str, int] = field(default_factory=dict)
    gauges: Dict[str, float] = field(default_factory=dict)
    histograms: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    sources: Tuple[str, ...] = ("parent",)

    def counter(self, name: str, default: int = 0) -> int:
        return self.counters.get(name, default)

    def gauge(self, name: str, default: float = 0.0) -> float:
        return self.gauges.get(name, default)

    def quantile(self, name: str, q: float) -> float:
        h = self.histograms.get(name)
        if not h or not h["samples"]:
            return 0.0
        return percentile(h["samples"], q)

    def merged_with(self, other: "ObsSnapshot") -> "ObsSnapshot":
        """Merge two snapshots: counters sum, gauges take the max,
        histogram reservoirs sort-merge then decimate. Deterministic and
        (up to source ordering) commutative."""
        counters = dict(self.counters)
        for k, v in other.counters.items():
            counters[k] = counters.get(k, 0) + v
        gauges = dict(self.gauges)
        for k, v in other.gauges.items():
            gauges[k] = max(gauges[k], v) if k in gauges else v
        hists = {k: dict(v, samples=list(v["samples"]))
                 for k, v in self.histograms.items()}
        for k, v in other.histograms.items():
            hists[k] = _merge_hist(hists[k], v) if k in hists else \
                dict(v, samples=list(v["samples"]))
        sources = self.sources + tuple(
            s for s in other.sources if s not in self.sources)
        return ObsSnapshot(counters, gauges, hists, sources)

    @classmethod
    def merge(cls, snaps: Iterable["ObsSnapshot"]) -> "ObsSnapshot":
        out = cls(sources=())
        for s in snaps:
            out = out.merged_with(s)
        if not out.sources:
            out.sources = ("parent",)
        return out

    def as_dict(self) -> Dict[str, Any]:
        hists = {}
        for name, h in sorted(self.histograms.items()):
            s = sorted(h["samples"])
            hists[name] = {
                "count": h["count"], "sum": h["sum"],
                "min": h["min"], "max": h["max"],
                "p50": percentile(s, 50.0), "p99": percentile(s, 99.0),
            }
        return {
            "sources": list(self.sources),
            "counters": dict(sorted(self.counters.items())),
            "gauges": dict(sorted(self.gauges.items())),
            "histograms": hists,
        }

    def to_json(self, indent: Optional[int] = None) -> str:
        return json.dumps(self.as_dict(), indent=indent, sort_keys=False)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "ObsSnapshot":
        """Rebuild from :meth:`as_dict` output (quantiles become 2-sample
        reservoirs — enough to re-render, not to re-merge exactly)."""
        hists = {}
        for name, h in d.get("histograms", {}).items():
            samples = h.get("samples")
            if samples is None:
                samples = [h.get("p50", 0.0), h.get("p99", 0.0)]
            hists[name] = {
                "count": h["count"], "sum": h["sum"],
                "min": h["min"], "max": h["max"], "samples": list(samples),
            }
        return cls(dict(d.get("counters", {})), dict(d.get("gauges", {})),
                   hists, tuple(d.get("sources", ("parent",))))

    def to_prometheus(self, prefix: str = "repro") -> str:
        return render_prometheus(self, prefix=prefix)


_PROM_BAD = re.compile(r"[^a-zA-Z0-9_:]")

#: Quantile labels of a rendered summary family (label, percentile).
_PROM_QUANTILES = (("0.5", 50.0), ("0.9", 90.0), ("0.99", 99.0))


def _prom_name(prefix: str, name: str) -> str:
    return _PROM_BAD.sub("_", f"{prefix}_{name}")


def _prom_label_value(value: str) -> str:
    """Escape a label value per the text exposition format: backslash,
    double-quote and line-feed are the three characters the grammar
    escapes (in that order — escaping the escapes first)."""
    return value.replace("\\", "\\\\").replace('"', '\\"') \
                .replace("\n", "\\n")


def render_prometheus(snap: ObsSnapshot, prefix: str = "repro") -> str:
    """Prometheus text exposition of a snapshot.

    Counters and gauges render as their own typed families; every
    reservoir histogram renders as a proper **summary family** — one
    ``# TYPE <name> summary`` header, ``quantile``-labelled sample
    lines (:data:`_PROM_QUANTILES`) plus the exact ``_count`` / ``_sum``
    children the summary type requires. Label values pass through
    :func:`_prom_label_value`, so sources containing ``\\``, ``"`` or
    newlines can't corrupt the exposition."""
    lines: List[str] = []
    src_pn = _prom_name(prefix, "obs_source")
    lines.append(f"# TYPE {src_pn} gauge")
    for src in snap.sources:
        lines.append(f'{src_pn}{{source="{_prom_label_value(src)}"}} 1')
    for name, v in sorted(snap.counters.items()):
        pn = _prom_name(prefix, name)
        lines.append(f"# TYPE {pn} counter")
        lines.append(f"{pn} {v}")
    for name, v in sorted(snap.gauges.items()):
        pn = _prom_name(prefix, name)
        lines.append(f"# TYPE {pn} gauge")
        lines.append(f"{pn} {v:.9g}")
    for name, h in sorted(snap.histograms.items()):
        pn = _prom_name(prefix, name)
        s = sorted(h["samples"])
        lines.append(f"# TYPE {pn} summary")
        for label, q in _PROM_QUANTILES:
            lines.append(
                f'{pn}{{quantile="{label}"}} {percentile(s, q):.9g}')
        lines.append(f"{pn}_count {h['count']}")
        lines.append(f"{pn}_sum {h['sum']:.9g}")
    return "\n".join(lines) + "\n"


class Registry:
    """Thread-safe metrics registry for one process (or one subsystem)."""

    def __init__(self, source: str = "parent"):
        self.source = source
        self._lock = threading.Lock()
        self._counters: Dict[str, int] = {}
        self._gauges: Dict[str, float] = {}
        self._hists: Dict[str, _Reservoir] = {}
        self._extra_sources: List[str] = []

    # -- writers ----------------------------------------------------------
    def counter_add(self, name: str, n: int = 1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    inc = counter_add

    def fold_counters(self, mapping: Mapping[str, int],
                      prefix: str = "") -> None:
        """Bulk-add a dict of counters (e.g. ``CopyStats.as_dict()``)."""
        with self._lock:
            for k, v in mapping.items():
                if v:
                    key = prefix + k
                    self._counters[key] = self._counters.get(key, 0) + int(v)

    def gauge_set(self, name: str, value: float) -> None:
        with self._lock:
            self._gauges[name] = float(value)

    def observe(self, name: str, value: float) -> None:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Reservoir(name)
            h.observe(value)

    def observe_many(self, name: str, values: Iterable[float]) -> None:
        """Bulk-observe under one lock acquisition — the batch-flush path
        for per-read span accumulators (see ``trace.timed_reader``)."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _Reservoir(name)
            for v in values:
                h.observe(v)

    def attach_source(self, name: str) -> None:
        """Record that counters folded in here came from another process
        (e.g. the readahead decoder child)."""
        with self._lock:
            if name not in self._extra_sources:
                self._extra_sources.append(name)

    def absorb(self, snap: ObsSnapshot, prefix: str = "") -> None:
        """Fold a child snapshot into this registry: counters sum,
        gauges take the max, histogram reservoirs sort-merge then
        decimate (the :meth:`ObsSnapshot.merged_with` rules), and the
        snapshot's sources are attached. Call exactly once per child
        snapshot — counters are cumulative, absorbing twice double-counts."""
        self.fold_counters(snap.counters, prefix=prefix)
        with self._lock:
            for k, v in snap.gauges.items():
                key = prefix + k
                self._gauges[key] = max(self._gauges.get(key, v), v)
            for k, h in snap.histograms.items():
                key = prefix + k
                cur = self._hists.get(key)
                if cur is None:
                    cur = self._hists[key] = _Reservoir(key)
                m = _merge_hist(cur.summary(), h) if cur.count else \
                    dict(h, samples=list(h["samples"]))
                cur.count = m["count"]
                cur.total = m["sum"]
                cur.min = m["min"] if m["count"] else float("inf")
                cur.max = m["max"] if m["count"] else float("-inf")
                cur.samples = list(m["samples"])
        for s in snap.sources:
            self.attach_source(s)

    # -- readers ----------------------------------------------------------
    def counter(self, name: str, default: int = 0) -> int:
        with self._lock:
            return self._counters.get(name, default)

    def quantile(self, name: str, q: float) -> float:
        with self._lock:
            h = self._hists.get(name)
            samples = list(h.samples) if h else []
        return percentile(samples, q)

    def hist_count(self, name: str) -> int:
        with self._lock:
            h = self._hists.get(name)
            return h.count if h else 0

    def snapshot(self, source: Optional[str] = None) -> ObsSnapshot:
        with self._lock:
            src = source if source is not None else self.source
            return ObsSnapshot(
                counters=dict(self._counters),
                gauges=dict(self._gauges),
                histograms={k: h.summary() for k, h in self._hists.items()},
                sources=(src, *self._extra_sources),
            )

    def reset(self) -> None:
        with self._lock:
            self._counters.clear()
            self._gauges.clear()
            self._hists.clear()
            self._extra_sources.clear()
