"""Flight recorder: always-on, bounded, per-thread span rings + anomaly dumps.

A :class:`FlightRecorder` keeps the last ``capacity_per_thread``
finished :class:`~repro.obs.trace.Span`\\ s **per writing thread** in
fixed-size ring buffers. Appends are lock-free in the only sense that
matters under the GIL: each ring has exactly one writer (its thread),
an append is two reference stores plus an int bump, and readers never
block writers — a dump may observe a ring mid-rotation and lose the
span being overwritten that instant, which is fine for a diagnostic
artifact. The global lock is touched once per thread *lifetime* (ring
registration), never per span, so the recorder can stay on in the serve
hot path at bounded memory (``capacity_per_thread × threads`` span
objects, no growth).

**Anomaly auto-dump.** :meth:`trip` is the hook the gateway calls when
something the SLO cares about happens (``GatewayTimeout``,
``GatewayOverloaded``, p99 over the SLO gauge, queue-depth high-water):
it writes the last few thousand spans to a JSON file — the flight
recorder's whole reason to exist is that by the time you know a request
was slow, the evidence is normally gone. Dumps are rate-limited
(``min_dump_interval_s``) so an overload storm produces one artifact,
not thousands; suppressed trips are counted
(``flight.trips_suppressed``). Dump files land in ``dump_dir``
(default: ``$REPRO_FLIGHT_DIR`` or ``<tmp>/repro-flight``) and render
into Chrome ``trace_event`` JSON via :mod:`repro.obs.export`.

``python -m repro.obs.flight --demo`` runs a synthetic gateway with an
induced ``GatewayTimeout`` and writes both artifacts (flight dump +
Chrome trace) — CI uploads them from the serve tier.
"""
from __future__ import annotations

import json
import os
import tempfile
import threading
from time import perf_counter, time as _wall
from typing import List, Optional

from repro.obs.trace import Span

__all__ = ["DEFAULT_CAPACITY", "FlightRecorder", "recorder",
           "set_recorder"]

#: Spans retained per writing thread before the ring rotates.
DEFAULT_CAPACITY = 4096


def _default_dump_dir() -> str:
    return os.environ.get("REPRO_FLIGHT_DIR") or \
        os.path.join(tempfile.gettempdir(), "repro-flight")


class _Ring:
    """Single-writer span ring: ``buf[idx % cap]`` slot store + bump."""

    __slots__ = ("buf", "idx", "cap", "thread")

    def __init__(self, cap: int, thread: str):
        self.buf: List[Optional[Span]] = [None] * cap
        self.idx = 0
        self.cap = cap
        self.thread = thread

    def append(self, span: Span) -> None:
        self.buf[self.idx % self.cap] = span
        self.idx += 1

    def items(self) -> List[Span]:
        """Resident spans, oldest first (reader-side; tolerant of a
        concurrent writer rotating under it)."""
        idx, cap = self.idx, self.cap
        if idx <= cap:
            out = self.buf[:idx]
        else:
            cut = idx % cap
            out = self.buf[cut:] + self.buf[:cut]
        return [s for s in out if s is not None]


class FlightRecorder:
    """Bounded always-on span store with rate-limited anomaly dumps."""

    def __init__(self, capacity_per_thread: int = DEFAULT_CAPACITY, *,
                 min_dump_interval_s: float = 30.0,
                 dump_dir: Optional[str] = None,
                 max_dump_spans: int = 8192) -> None:
        self.capacity_per_thread = max(16, int(capacity_per_thread))
        self.min_dump_interval_s = min_dump_interval_s
        self.dump_dir = dump_dir if dump_dir is not None \
            else _default_dump_dir()
        self.max_dump_spans = max_dump_spans
        self._local = threading.local()
        self._rings: List[_Ring] = []
        self._reg_lock = threading.Lock()   # ring registration only
        self._dump_lock = threading.Lock()  # dump serialization only
        self._last_dump = float("-inf")
        self._dump_seq = 0
        self.dump_paths: List[str] = []

    # -- hot path --------------------------------------------------------
    def record(self, span: Span) -> None:
        ring = getattr(self._local, "ring", None)
        if ring is None:
            ring = _Ring(self.capacity_per_thread,
                         threading.current_thread().name)
            self._local.ring = ring
            with self._reg_lock:
                self._rings.append(ring)
        ring.append(span)

    # -- readers ---------------------------------------------------------
    def spans(self, last: Optional[int] = None) -> List[Span]:
        """Resident finished spans across all rings, sorted by start time
        (``last`` keeps only the newest N)."""
        with self._reg_lock:
            rings = list(self._rings)
        out: List[Span] = []
        for ring in rings:
            out.extend(s for s in ring.items() if s.t1 is not None)
        out.sort(key=lambda s: s.t0)
        if last is not None and len(out) > last:
            out = out[-last:]
        return out

    def trace_tree(self, trace_id: int) -> List[Span]:
        """Every resident span of one trace, parents before children."""
        spans = [s for s in self.spans() if s.trace_id == trace_id]
        spans.sort(key=lambda s: (s.parent_id != 0, s.t0))
        return spans

    def clear(self) -> None:
        with self._reg_lock:
            rings = list(self._rings)
        for ring in rings:
            ring.buf = [None] * ring.cap
            ring.idx = 0

    # -- dumping ---------------------------------------------------------
    def trip(self, reason: str, attrs: Optional[dict] = None, *,
             tag: Optional[str] = None) -> Optional[str]:
        """Anomaly hook: dump unless one fired within
        ``min_dump_interval_s``. Returns the dump path, or ``None`` when
        suppressed. Counts ``flight.trips.<reason>`` either way.
        ``tag`` (PR 9: the tripping gateway shard, e.g. ``"shard2"``)
        lands in both the payload and the dump filename, so an operator
        can see *which* shard misbehaved without opening the file."""
        from repro import obs

        obs.registry().counter_add(f"flight.trips.{reason}")
        now = perf_counter()
        with self._dump_lock:
            if now - self._last_dump < self.min_dump_interval_s:
                obs.registry().counter_add("flight.trips_suppressed")
                return None
            self._last_dump = now
        return self.dump(reason=reason, attrs=attrs, tag=tag)

    def dump(self, path: Optional[str] = None, *, reason: str = "manual",
             attrs: Optional[dict] = None,
             tag: Optional[str] = None) -> str:
        """Write the resident spans (newest ``max_dump_spans``) as JSON;
        returns the path written."""
        from repro import obs

        spans = self.spans(last=self.max_dump_spans)
        if path is None:
            os.makedirs(self.dump_dir, exist_ok=True)
            with self._dump_lock:
                self._dump_seq += 1
                seq = self._dump_seq
            stem = reason if tag is None else f"{reason}-{tag}"
            safe = "".join(c if c.isalnum() or c in "-_" else "-"
                           for c in stem)
            path = os.path.join(
                self.dump_dir, f"flight-{os.getpid()}-{seq:04d}-{safe}.json")
        payload = {
            "reason": reason,
            "tag": tag,
            "attrs": attrs or {},
            "wall_time_s": _wall(),
            "pid": os.getpid(),
            "n_spans": len(spans),
            "spans": [s.as_dict() for s in spans],
        }
        tmp = f"{path}.tmp"
        with open(tmp, "w", encoding="utf-8") as f:
            json.dump(payload, f)
            f.write("\n")
        os.replace(tmp, path)  # a reader never sees a half-written dump
        self.dump_paths.append(path)
        obs.registry().counter_add("flight.dumps")
        return path


_default = FlightRecorder()


def recorder() -> FlightRecorder:
    """The process-default flight recorder ``Span.finish`` records into."""
    return _default


def set_recorder(rec: FlightRecorder) -> FlightRecorder:
    """Swap the process-default recorder (tests); returns the previous."""
    global _default
    prev = _default
    _default = rec
    return prev


# -- CLI: ``python -m repro.obs.flight --demo`` ---------------------------

def _demo(out_dir: str) -> tuple:
    """Synthetic traced serve run with induced anomalies: one
    GatewayTimeout, then a sharded-gateway overload soak that trips a
    shard-tagged ``gateway_overloaded`` dump.

    Returns ``(flight_dump_path, chrome_trace_path)`` — the artifacts
    CI uploads from the serve tier (the dump path returned is the
    shard-tagged overload one).
    """
    import tempfile as _tf

    from repro.data.synth import CorpusSpec, write_corpus
    from repro.index import QueryRequest, build_index
    from repro.obs.export import write_chrome_trace
    from repro.serve import (ArchiveGateway, GatewayOverloaded,
                             GatewayTimeout)

    rec = FlightRecorder(min_dump_interval_s=0.0, dump_dir=out_dir)
    with _tf.TemporaryDirectory(prefix="repro-flight-demo-") as tmp:
        paths = []
        for i in range(2):
            p = os.path.join(tmp, f"shard-{i}.warc.gz")
            write_corpus(p, CorpusSpec(n_pages=30, seed=i), "gzip")
            paths.append(p)
        index = build_index(paths)
        with ArchiveGateway(index, cache_bytes=1 << 20,
                            flight_recorder=rec) as gw:
            for pattern in (b"nginx", b"crawl", b"absent-needle!"):
                gw.submit(QueryRequest(pattern, top_k=3)).result(600)
            try:  # induced anomaly: an already-expired deadline
                gw.submit(QueryRequest(b"nginx", top_k=3),
                          deadline_s=-1.0).result(600)
            except GatewayTimeout:
                pass
        # overload soak against a sharded pool: tiny per-shard budgets +
        # a flood of distinct scan identities force at least one typed,
        # shard-tagged GatewayOverloaded rejection (and its dump)
        overloads = 0
        futures = []
        with ArchiveGateway(index, shards=2, max_pending=1,
                            cache_bytes=1 << 20,
                            flight_recorder=rec) as gw:
            for i in range(64):
                try:
                    futures.append(gw.submit(
                        QueryRequest(b"demo-%d" % i, top_k=3),
                        block=False))
                except GatewayOverloaded as exc:
                    overloads += 1
                    assert exc.shard is not None
            for fut in futures:
                fut.result(600)
        assert overloads > 0, "overload soak produced no rejection"
    dump_path = rec.dump_paths[-1] if rec.dump_paths else \
        rec.dump(reason="demo")
    chrome_path = os.path.join(out_dir, "chrome-trace.json")
    write_chrome_trace(chrome_path, rec.spans())
    return dump_path, chrome_path


def main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.flight",
        description="Flight-recorder artifact generator.")
    ap.add_argument("--demo", action="store_true",
                    help="traced serve run with an induced GatewayTimeout")
    ap.add_argument("--out-dir", default="flight-artifacts",
                    help="directory for the dump + Chrome trace JSON")
    args = ap.parse_args(argv)
    if not args.demo:
        ap.error("only --demo is supported")
    os.makedirs(args.out_dir, exist_ok=True)
    dump_path, chrome_path = _demo(args.out_dir)
    print(f"wrote {dump_path}")
    print(f"wrote {chrome_path}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
