"""Stage tracing for the ingest hot path.

Spans are recorded as duration histograms (``span.<name>_s``) plus a
count counter in the process-default registry. Tracing is **off by
default** and the instrumented call sites are written so the disabled
cost is one truth-test per *batch* (or per iterator construction), never
per record — the zero-copy loop's ≤2% overhead gate in
``benchmarks/ingest_bench.py`` holds the line.

Span names in use across the repo:

=========================  =================================================
``ingest.fill``            raw reads refilling the uncompressed RecordBuffer
``ingest.decode_member``   inline (non-readahead) member decode-into-arena
``ingest.decode_wait``     parse loop blocked waiting on the readahead
                           decoder (small = good overlap)
``ingest.arena_land``      memcpy landing a decoded shm batch in the arena
``ingest.parse_batch``     parsing the records of one landed member batch
``kernel.dispatch``        one Pallas kernel dispatch (see obs.kernels)
=========================  =================================================
"""
from __future__ import annotations

import os
from time import perf_counter
from typing import Iterator

__all__ = ["add", "add_many", "count", "enable", "enabled", "span",
           "timed_reader"]

_ENABLED = os.environ.get("REPRO_OBS_TRACE", "") not in ("", "0")


def enabled() -> bool:
    """Is span recording on? Call sites capture this once per iterator or
    per batch — never per record."""
    return _ENABLED


def enable(on: bool = True) -> bool:
    """Turn span recording on/off; returns the previous setting."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def add(name: str, seconds: float, n: int = 1) -> None:
    """Record a span duration directly (for call sites that time with
    ``perf_counter`` themselves)."""
    from repro import obs

    reg = obs.registry()
    reg.observe(f"span.{name}_s", seconds)
    if n:
        reg.counter_add(f"span.{name}.count", n)


def add_many(name: str, durations) -> None:
    """Record a batch of span durations under one registry lock."""
    if not durations:
        return
    from repro import obs

    reg = obs.registry()
    reg.observe_many(f"span.{name}_s", durations)
    reg.counter_add(f"span.{name}.count", len(durations))


def count(name: str, n: int = 1) -> None:
    from repro import obs

    obs.registry().counter_add(name, n)


class span:
    """``with trace.span("ingest.parse_batch"): ...`` — records even when
    tracing was enabled after construction; guard with
    ``trace.enabled()`` at the call site for the zero-cost path."""

    __slots__ = ("name", "_t0")

    def __init__(self, name: str):
        self.name = name

    def __enter__(self) -> "span":
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        add(self.name, perf_counter() - self._t0)


class timed_reader:
    """File-object proxy that attributes ``read``/``readinto`` time to a
    span. Only ever wrapped around the raw source when tracing is
    enabled, so the disabled path never sees an extra call frame.

    Reads on the zero-copy loop can be per-record-frequent, so durations
    accumulate locally and flush to the registry in batches of
    ``_FLUSH_EVERY`` (one lock acquisition per batch) and at EOF — the
    ≤2% tracing-tax gate in ``benchmarks/ingest_bench.py`` is what this
    buffering buys. A generator torn down mid-stream can strand up to
    one unflushed batch; span *counts* are best-effort by design."""

    _FLUSH_EVERY = 64

    __slots__ = ("_f", "_name", "_pending")

    def __init__(self, f, name: str = "ingest.fill"):
        self._f = f
        self._name = name
        self._pending: list = []

    def _note(self, dt: float, eof: bool) -> None:
        self._pending.append(dt)
        if eof or len(self._pending) >= self._FLUSH_EVERY:
            self.flush()

    def flush(self) -> None:
        if self._pending:
            add_many(self._name, self._pending)
            self._pending = []

    def read(self, n: int = -1):
        t0 = perf_counter()
        out = self._f.read(n)
        self._note(perf_counter() - t0, not out)
        return out

    def readinto(self, b) -> int:
        t0 = perf_counter()
        out = self._f.readinto(b)
        self._note(perf_counter() - t0, not out)
        return out

    def __getattr__(self, attr):
        return getattr(self._f, attr)


def timed_iter(it: Iterator, name: str) -> Iterator:
    """Yield from ``it``, attributing the time blocked in ``next()`` to
    span ``name`` (used for decoder get-waits)."""
    while True:
        t0 = perf_counter()
        try:
            item = next(it)
        except StopIteration:
            add(name, perf_counter() - t0)
            return
        add(name, perf_counter() - t0)
        yield item
