"""Stage tracing: flat timed sections *and* request-scoped span trees.

Two tiers share this module:

* **Flat spans** (PR 7) — durations recorded as histograms
  (``span.<name>_s``) plus a count counter in the process-default
  registry. Tracing is **off by default** and the instrumented call
  sites are written so the disabled cost is one truth-test per *batch*
  (or per iterator construction), never per record — the zero-copy
  loop's ≤2% overhead gate in ``benchmarks/ingest_bench.py`` holds the
  line.
* **Span trees** (PR 8) — :class:`Span` carries ``trace_id`` /
  ``span_id`` / ``parent_id`` so one request's time decomposes into true
  parent/child stages, across thread boundaries: the submitting thread
  opens the root span, stashes it on the ticket, and the scheduler
  thread opens children against that explicit parent
  (:func:`start_span`). Within one thread the current span propagates
  through a ``contextvars.ContextVar`` (:func:`current_span`,
  :class:`use_span`). Finished spans land in the flight recorder
  (:mod:`repro.obs.flight`) — bounded per-thread rings, always cheap —
  and the *owner* of the span decides which registry (if any) gets its
  duration histogram; the gateway routes stage durations into its
  private registry as ``gateway.stage.<name>_s``.

Span names in use across the repo:

=========================  =================================================
``ingest.fill``            raw reads refilling the uncompressed RecordBuffer
``ingest.decode_member``   inline (non-readahead) member decode-into-arena
``ingest.decode_wait``     parse loop blocked waiting on the readahead
                           decoder (small = good overlap)
``ingest.arena_land``      memcpy landing a decoded shm batch in the arena
``ingest.parse_batch``     parsing the records of one landed member batch
``kernel.dispatch``        one Pallas kernel dispatch (see obs.kernels)
``serve.prefill``          LM serve engine: prompt prefill of one batch
``serve.decode``           LM serve engine: decode loop of one batch
``gw.request``             gateway request root (submit → resolution)
``gw.admission``           submit body: route + coalesce probe + queue put
``gw.queue_wait``          queue put → drained by the owning shard
``gw.coalesce_attach``     attach to an in-flight identical scan
``gw.scan_batch``          shard batch root (one drained batch)
``gw.batch_form``          shed expired + group by scan key + publish
``gw.prefilter``           plan: literal/signature prefilter → candidates
``gw.cache_fill``          chunk payload fetch (cache hits + decompress)
``gw.kernel_dispatch``     one shared multi-pattern kernel dispatch
``gw.host_verify``         host-side verify/regex gate over a chunk
``gw.respond``             ranking + resolving every waiter's future
``gw.timeout``             marker: request resolved with GatewayTimeout
``gw.redrive``             marker: orphan re-routed after a shard death
=========================  =================================================

Since PR 9 the gateway is sharded: scheduler-side spans
(``gw.scan_batch``, ``gw.kernel_dispatch``) and routed submit spans
(``gw.admission``, ``gw.queue_wait``, ``gw.coalesce_attach``) carry a
``shard`` attribute, and anomaly flight dumps are shard-tagged.
"""
from __future__ import annotations

import contextvars
import itertools
import os
import threading
import time as _time
from time import perf_counter
from typing import Iterator, Optional, Tuple, Union

__all__ = ["ROOT", "Span", "add", "add_many", "count", "current_span",
           "enable", "enabled", "perf_to_wall_us", "span", "start_span",
           "timed_reader", "use_span"]

_ENABLED = os.environ.get("REPRO_OBS_TRACE", "") not in ("", "0")


def enabled() -> bool:
    """Is span recording on? Call sites capture this once per iterator or
    per batch — never per record."""
    return _ENABLED


def enable(on: bool = True) -> bool:
    """Turn span recording on/off; returns the previous setting."""
    global _ENABLED
    prev = _ENABLED
    _ENABLED = bool(on)
    return prev


def add(name: str, seconds: float, n: int = 1) -> None:
    """Record a span duration directly (for call sites that time with
    ``perf_counter`` themselves)."""
    from repro import obs

    reg = obs.registry()
    reg.observe(f"span.{name}_s", seconds)
    if n:
        reg.counter_add(f"span.{name}.count", n)


def add_many(name: str, durations) -> None:
    """Record a batch of span durations under one registry lock."""
    if not durations:
        return
    from repro import obs

    reg = obs.registry()
    reg.observe_many(f"span.{name}_s", durations)
    reg.counter_add(f"span.{name}.count", len(durations))


def count(name: str, n: int = 1) -> None:
    from repro import obs

    obs.registry().counter_add(name, n)


class span:
    """``with trace.span("ingest.parse_batch"): ...`` — records even when
    tracing was enabled after construction; guard with
    ``trace.enabled()`` at the call site for the zero-cost path."""

    __slots__ = ("name", "_t0")

    def __init__(self, name: str):
        self.name = name

    def __enter__(self) -> "span":
        self._t0 = perf_counter()
        return self

    def __exit__(self, *exc) -> None:
        add(self.name, perf_counter() - self._t0)


class timed_reader:
    """File-object proxy that attributes ``read``/``readinto`` time to a
    span. Only ever wrapped around the raw source when tracing is
    enabled, so the disabled path never sees an extra call frame.

    Reads on the zero-copy loop can be per-record-frequent, so durations
    accumulate locally and flush to the registry in batches of
    ``_FLUSH_EVERY`` (one lock acquisition per batch) and at EOF — the
    ≤2% tracing-tax gate in ``benchmarks/ingest_bench.py`` is what this
    buffering buys. A generator torn down mid-stream can strand up to
    one unflushed batch; span *counts* are best-effort by design."""

    _FLUSH_EVERY = 64

    __slots__ = ("_f", "_name", "_pending")

    def __init__(self, f, name: str = "ingest.fill"):
        self._f = f
        self._name = name
        self._pending: list = []

    def _note(self, dt: float, eof: bool) -> None:
        self._pending.append(dt)
        if eof or len(self._pending) >= self._FLUSH_EVERY:
            self.flush()

    def flush(self) -> None:
        if self._pending:
            add_many(self._name, self._pending)
            self._pending = []

    def read(self, n: int = -1):
        t0 = perf_counter()
        out = self._f.read(n)
        self._note(perf_counter() - t0, not out)
        return out

    def readinto(self, b) -> int:
        t0 = perf_counter()
        out = self._f.readinto(b)
        self._note(perf_counter() - t0, not out)
        return out

    def __getattr__(self, attr):
        return getattr(self._f, attr)


# -- span trees (PR 8) ----------------------------------------------------

# wall-clock anchor: spans time with perf_counter (monotonic, cheap) and
# convert to wall microseconds only at export time, via one pair of
# epoch samples taken at import
_EPOCH_PERF = perf_counter()
_EPOCH_WALL = _time.time()

#: monotonically increasing ids; ``itertools.count().__next__`` is
#: GIL-atomic, so ids are unique across threads without a lock
_NEXT_ID = itertools.count(1).__next__


def perf_to_wall_us(t_perf: float) -> float:
    """Convert a ``perf_counter`` instant to wall-clock microseconds."""
    return (_EPOCH_WALL + (t_perf - _EPOCH_PERF)) * 1e6


class Span:
    """One timed stage in a trace tree.

    ``trace_id`` groups every span of one logical request (or one
    scheduler batch); ``parent_id`` is the ``span_id`` of the enclosing
    stage (``0`` for roots). Spans are started by :func:`start_span`
    and closed with :meth:`finish`, which appends them to a flight
    recorder ring (:mod:`repro.obs.flight`). A span may be started on
    one thread and finished on another — ``thread`` records the
    *starting* thread, which is the one whose time the span attributes.
    """

    __slots__ = ("name", "trace_id", "span_id", "parent_id", "t0", "t1",
                 "thread", "attrs")

    def __init__(self, name: str, trace_id: int, span_id: int,
                 parent_id: int, t0: float, thread: str,
                 attrs: Optional[dict] = None):
        self.name = name
        self.trace_id = trace_id
        self.span_id = span_id
        self.parent_id = parent_id
        self.t0 = t0
        self.t1: Optional[float] = None
        self.thread = thread
        self.attrs = attrs

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else perf_counter()) - self.t0

    def set_attr(self, key: str, value) -> None:
        if self.attrs is None:
            self.attrs = {}
        self.attrs[key] = value

    def context(self) -> Tuple[int, int]:
        """``(trace_id, span_id)`` — the hand-off token for children
        started on another thread."""
        return (self.trace_id, self.span_id)

    def finish(self, t1: Optional[float] = None, *,
               recorder=None) -> float:
        """Close the span and record it; returns the duration in seconds.

        ``recorder=None`` uses the process-default flight recorder;
        pass ``recorder=False`` to close without recording (tests).
        Idempotent: a second ``finish`` only returns the duration.
        """
        if self.t1 is not None:
            return self.t1 - self.t0
        self.t1 = t1 if t1 is not None else perf_counter()
        if recorder is not False:
            if recorder is None:
                from repro.obs import flight

                recorder = flight.recorder()
            recorder.record(self)
        return self.t1 - self.t0

    def as_dict(self) -> dict:
        d = {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "thread": self.thread,
            "t0_us": perf_to_wall_us(self.t0),
            "dur_us": (self.t1 - self.t0) * 1e6 if self.t1 is not None
                      else None,
        }
        if self.attrs:
            d["attrs"] = dict(self.attrs)
        return d

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        state = "open" if self.t1 is None else f"{self.duration_s * 1e3:.3f}ms"
        return (f"Span({self.name!r}, trace={self.trace_id}, "
                f"id={self.span_id}, parent={self.parent_id}, {state})")


_current_span: "contextvars.ContextVar[Optional[Span]]" = \
    contextvars.ContextVar("repro_obs_current_span", default=None)

#: Sentinel parent: root a fresh trace even when a current span exists.
ROOT: Tuple = ()

ParentLike = Union[Span, Tuple[int, int], None]


def current_span() -> Optional[Span]:
    """The thread's (really: context's) innermost active span, if any."""
    return _current_span.get()


def start_span(name: str, parent: ParentLike = None, *,
               t0: Optional[float] = None,
               attrs: Optional[dict] = None) -> Span:
    """Open a span.

    ``parent`` may be a :class:`Span`, a ``(trace_id, span_id)`` context
    tuple (cross-thread hand-off), or ``None`` — then the contextvar's
    current span is the parent, and if there is none either, this span
    roots a fresh trace. ``t0`` backdates the start (used for
    ``gw.queue_wait``, whose start is the submit instant recorded on
    the ticket)."""
    if parent is None:
        parent = _current_span.get()
    if parent is None or parent == ():  # () == ROOT: force a fresh trace
        trace_id, parent_id = _NEXT_ID(), 0
    elif isinstance(parent, Span):
        trace_id, parent_id = parent.trace_id, parent.span_id
    else:
        trace_id, parent_id = parent
    return Span(name, trace_id, _NEXT_ID(), parent_id,
                t0 if t0 is not None else perf_counter(),
                threading.current_thread().name, attrs)


class use_span:
    """Context manager installing ``span`` as the context's current span
    (children started with ``parent=None`` nest under it); optionally
    finishes it on exit (``finish=True``)."""

    __slots__ = ("_span", "_finish", "_recorder", "_token")

    def __init__(self, span_: Span, *, finish: bool = False, recorder=None):
        self._span = span_
        self._finish = finish
        self._recorder = recorder

    def __enter__(self) -> Span:
        self._token = _current_span.set(self._span)
        return self._span

    def __exit__(self, *exc) -> None:
        _current_span.reset(self._token)
        if self._finish:
            self._span.finish(recorder=self._recorder)


def timed_iter(it: Iterator, name: str) -> Iterator:
    """Yield from ``it``, attributing the time blocked in ``next()`` to
    span ``name`` (used for decoder get-waits)."""
    while True:
        t0 = perf_counter()
        try:
            item = next(it)
        except StopIteration:
            add(name, perf_counter() - t0)
            return
        add(name, perf_counter() - t0)
        yield item
