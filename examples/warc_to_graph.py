"""WARC shards → web graph → GatedGCN: the paper's parser feeding the GNN stack.

Extracts the host-level link graph from a sharded (synthetic) crawl
archive with the optimized parser — per-shard partial graphs built in
worker processes and merged with host-id remapping
(`web_graph_from_warcs`, DESIGN.md §5/§6) — then runs a GatedGCN forward
over it, the classic web-graph analytics use of WARC data.

Run:  PYTHONPATH=src python examples/warc_to_graph.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import web_graph_from_warcs
from repro.data.synth import CorpusSpec, write_corpus
from repro.models.gnn import GatedGCNConfig, forward, init_params


def main():
    with tempfile.TemporaryDirectory() as d:
        shards = []
        for i in range(4):
            path = os.path.join(d, f"crawl-{i:02d}.warc.gz")
            write_corpus(path, CorpusSpec(n_pages=50, seed=21 + i), "gzip")
            shards.append(path)
        g = web_graph_from_warcs(shards, workers=2)

    n = len(g["hosts"])
    print(f"web graph over {len(shards)} shards: "
          f"{n} hosts, {g['edge_src'].size} links")
    out_degrees = np.bincount(g["edge_src"], minlength=n)
    for host, deg in zip(g["hosts"], out_degrees):
        print(f"  {host:24s} out-degree {int(deg)}")

    cfg = GatedGCNConfig("webgraph", n_layers=4, d_hidden=16, d_feat=8,
                         n_classes=3)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits = forward(params, feats,
                     jnp.asarray(g["edge_src"]), jnp.asarray(g["edge_dst"]),
                     cfg)
    print(f"\nGatedGCN over the crawl graph: logits {logits.shape}, "
          f"finite: {bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
