"""WARC → web graph → GatedGCN: the paper's parser feeding the GNN stack.

Extracts the host-level link graph from a (synthetic) crawl archive with
the optimized parser, then runs a GatedGCN forward over it — the classic
web-graph analytics use of WARC data (DESIGN.md §5).

Run:  PYTHONPATH=src python examples/warc_to_graph.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pipeline import web_graph_from_warc
from repro.data.synth import CorpusSpec, generate_warc
from repro.models.gnn import GatedGCNConfig, forward, init_params


def main():
    data = generate_warc(CorpusSpec(n_pages=200, seed=21), "gzip")
    g = web_graph_from_warc(data)
    n = len(g["hosts"])
    print(f"web graph: {n} hosts, {g['edge_src'].size} links")
    for h in g["hosts"]:
        out_deg = int((g["edge_src"] == g["hosts"].index(h)).sum())
        print(f"  {h:24s} out-degree {out_deg}")

    cfg = GatedGCNConfig("webgraph", n_layers=4, d_hidden=16, d_feat=8,
                         n_classes=3)
    rng = np.random.default_rng(0)
    feats = jnp.asarray(rng.normal(size=(n, 8)), jnp.float32)
    params = init_params(jax.random.PRNGKey(0), cfg)
    logits = forward(params, feats,
                     jnp.asarray(g["edge_src"]), jnp.asarray(g["edge_dst"]),
                     cfg)
    print(f"\nGatedGCN over the crawl graph: logits {logits.shape}, "
          f"finite: {bool(jnp.isfinite(logits).all())}")


if __name__ == "__main__":
    main()
