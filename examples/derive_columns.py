"""Derive a columnar store from WARC shards, then race both query paths.

The parse-once workflow (DESIGN.md §13): one derivation sweep runs the
zero-copy parser over every shard and emits a `.repcol` store whose
payloads already sit in the kernels' packed row-group layout. After
that, full-corpus pattern scans never touch the WARC files again — the
query engine dispatches row-group kernels straight over the mmapped
matrices, while the classic CDX path must seek, inflate, and re-pack
every candidate per query.

Usage:

    # derive from a synthetic 4-shard corpus and race a broad scan
    PYTHONPATH=src python examples/derive_columns.py

    # your own shards, persisted store, your own query
    PYTHONPATH=src python examples/derive_columns.py \\
        --shards crawl-*.warc.gz --store corpus.repcol \\
        --pattern "HTTP/1.1" --workers 2

The store is saved to ``--store`` (default: alongside the first shard)
and reloaded on later runs, so repeat searches skip the derivation.
"""
import argparse
import os
import tempfile
import time

import numpy as np

from repro.columnar import ColumnStore, derive
from repro.data.synth import CorpusSpec, write_corpus
from repro.index import QueryEngine, build_index


def _synthetic_shards(directory: str, n_shards: int = 4) -> list[str]:
    paths = []
    for i in range(n_shards):
        p = os.path.join(directory, f"crawl-{i:02d}.warc.gz")
        write_corpus(p, CorpusSpec(n_pages=40, seed=31 + i), "gzip")
        paths.append(p)
    return paths


def _best_s(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Derive a columnar store and race column-scan vs "
                    "CDX+seek queries")
    ap.add_argument("--shards", nargs="*", default=None,
                    help="WARC files (default: generate a synthetic corpus)")
    ap.add_argument("--store", default=None,
                    help="columnar store path (derived and saved if missing)")
    ap.add_argument("--pattern", default="HTTP/1.1",
                    help="byte pattern for the race (default: a broad one "
                         "the signature pre-filter cannot narrow)")
    ap.add_argument("--workers", type=int, default=0,
                    help="derivation worker processes (0 = serial)")
    args = ap.parse_args()

    tmp = None
    shards = args.shards
    if not shards:
        tmp = tempfile.TemporaryDirectory()
        shards = _synthetic_shards(tmp.name)
        print(f"generated {len(shards)} synthetic shards in {tmp.name}")

    store_path = args.store or os.path.join(
        os.path.dirname(shards[0]) or ".", "corpus.repcol")
    store = None
    if os.path.exists(store_path):
        store = ColumnStore(store_path)
        if list(store.shard_paths) != shards:  # covers a different corpus
            print(f"store {store_path} covers different shards; re-deriving")
            store.close()
            store = None
        else:
            print(f"loaded store: {len(store)} records from {store_path}")
    if store is None:
        t0 = time.perf_counter()
        store = derive(shards, store_path, workers=args.workers)
        dt = time.perf_counter() - t0
        print(f"derived {len(store)} records across {len(shards)} shards "
              f"in {dt:.2f}s -> {store_path} "
              f"({os.path.getsize(store_path) / 1024:.1f} KiB, "
              f"{store.n_rowgroups} row-groups, "
              f"pad waste {store.pad_waste_ratio():.2f})")

    # the store carries the full CDX index: no separate build needed for
    # the columnar engine; the baseline engine rebuilds it from the WARCs
    index = build_index(shards)
    pattern = args.pattern.encode()

    cdx = QueryEngine(index)
    col = QueryEngine.from_store(store)
    base_hits = cdx.search(pattern)  # warm both: kernel shapes, readers
    col_hits = col.search(pattern)
    assert len(base_hits) == len(col_hits) and all(
        x.index_row == y.index_row and x.excerpt == y.excerpt
        and np.array_equal(x.positions, y.positions)
        for x, y in zip(base_hits, col_hits)), "paths disagree"
    print(f"\npattern {args.pattern!r}: {len(col_hits)} matching records, "
          f"both paths byte-identical")

    t_cdx = _best_s(lambda: cdx.search(pattern))
    t_col = _best_s(lambda: col.search(pattern))
    print(f"  CDX+seek : {t_cdx * 1e3:7.1f} ms/query")
    print(f"  columnar : {t_col * 1e3:7.1f} ms/query  "
          f"({t_cdx / t_col:.1f}x)")

    # copy ledger: the columnar path's scan stage reads the mmap in
    # place — payloads are materialized only for store fetches (hit
    # verification/excerpts on long-literal or regex plans)
    for name, eng in (("CDX+seek", cdx), ("columnar", col)):
        s = eng.stats
        q = max(s["queries"], 1)
        print(f"  {name:9s} ledger: "
              f"{s['records_scanned'] / q:.0f} records scanned/query, "
              f"{s['kernel_dispatches'] / q:.1f} dispatches/query, "
              f"{s['store_fetches'] / q:.1f} payload copies/query")

    cdx.close()
    col.close()
    # the from_store engine's index *is* a view of the store's mapping;
    # drop every reference (eng still aliases it from the ledger loop)
    # before close() or the borrow rule (rightly) refuses
    del col, eng
    store.close()
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
