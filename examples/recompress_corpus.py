"""Recompression analytics: measure the paper's storage/speed trade-off.

Generates one corpus, stores it under all four codecs, and reports the
(size, parse-throughput) frontier — the quantitative version of the
paper's conclusion that LZ4's +30-40 % storage buys large analytics
speedups (in this offline Python runtime, zstd is the C-speed fast codec;
the from-scratch LZ4 is measured too and honestly slower — see DESIGN.md
§8.2).

Run:  PYTHONPATH=src python examples/recompress_corpus.py
"""
import time

from repro.core.warc import FastWARCIterator
from repro.data.synth import CorpusSpec, generate_warc, records_in


def main():
    spec = CorpusSpec(n_pages=400, seed=11)
    total = records_in(spec)
    plain = generate_warc(spec, "none")
    print(f"{total} records, {len(plain)/1e6:.2f} MB uncompressed\n")
    print(f"{'codec':8s} {'size MB':>8s} {'vs gzip':>8s} "
          f"{'parse rec/s':>12s} {'vs gzip':>8s}")

    sizes, speeds = {}, {}
    for codec in ("gzip", "none", "lz4", "zstd"):
        data = generate_warc(spec, codec)
        sizes[codec] = len(data)
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            n = sum(1 for _ in FastWARCIterator(data, parse_http=True))
            best = min(best, time.perf_counter() - t0)
            assert n == total
        speeds[codec] = total / best
    for codec in ("gzip", "none", "lz4", "zstd"):
        print(f"{codec:8s} {sizes[codec]/1e6:8.2f} "
              f"{sizes[codec]/sizes['gzip']:8.2f} "
              f"{speeds[codec]:12.0f} {speeds[codec]/speeds['gzip']:8.2f}")

    ratio = sizes["zstd"] / sizes["gzip"]
    speedup = speeds["zstd"] / speeds["gzip"]
    print(f"\nfast-codec trade (zstd): {ratio:.2f}x storage for "
          f"{speedup:.2f}x parse throughput — the paper's LZ4 conclusion, "
          f"reproduced with the codec that has a C decompressor here")


if __name__ == "__main__":
    main()
