"""Keyword search over WARC shards via the CDX index + query engine.

The WarcSearcher-style workload (grep a crawl archive) on the
`repro.index` subsystem: build a columnar CDX index once, then serve
pattern queries that never decompress records the n-gram signature
pre-filter rules out; surviving candidates are fetched by offset
(constant-time random access) and scanned in batched
`find_pattern_mask_batch` kernel dispatches.

Usage:

    # search a synthetic 4-shard corpus for two patterns
    PYTHONPATH=src python examples/search_warcs.py

    # your own shards, your own patterns, persisted index
    PYTHONPATH=src python examples/search_warcs.py \\
        --shards crawl-*.warc.gz --index corpus.cdx \\
        --pattern "nginx/1.17" --pattern "text/html" --top-k 5

    # restrict to HTTP 200 responses and reuse a saved index
    PYTHONPATH=src python examples/search_warcs.py \\
        --shards crawl-*.warc.gz --index corpus.cdx --status 200

The index is saved to ``--index`` (default: alongside the first shard)
and reloaded on later runs, so repeat searches skip the build sweep.
"""
import argparse
import os
import tempfile

from repro.data.synth import CorpusSpec, write_corpus
from repro.index import (
    CdxIndex,
    HeaderFilter,
    IndexQueryService,
    QueryRequest,
    build_index,
)


def _synthetic_shards(directory: str, n_shards: int = 4) -> list[str]:
    paths = []
    for i in range(n_shards):
        p = os.path.join(directory, f"crawl-{i:02d}.warc.gz")
        write_corpus(p, CorpusSpec(n_pages=40, seed=31 + i), "gzip")
        paths.append(p)
    return paths


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Indexed pattern search over WARC shards")
    ap.add_argument("--shards", nargs="*", default=None,
                    help="WARC files (default: generate a synthetic corpus)")
    ap.add_argument("--index", default=None,
                    help="CDX index path (built and saved if missing)")
    ap.add_argument("--pattern", action="append", default=None,
                    help="byte pattern(s) to search (repeatable)")
    ap.add_argument("--regex", action="append", default=None,
                    help="bytes regex(es) to search (repeatable); required "
                         "literals drive the pre-filter, re verifies")
    ap.add_argument("--status", type=int, default=None,
                    help="restrict to records with this HTTP status")
    ap.add_argument("--top-k", type=int, default=5)
    ap.add_argument("--workers", type=int, default=2,
                    help="index-build worker processes (0 = serial)")
    args = ap.parse_args()

    tmp = None
    shards = args.shards
    if not shards:
        tmp = tempfile.TemporaryDirectory()
        shards = _synthetic_shards(tmp.name)
        print(f"generated {len(shards)} synthetic shards in {tmp.name}")

    index_path = args.index or os.path.join(
        os.path.dirname(shards[0]) or ".", "corpus.cdx")
    index = None
    if os.path.exists(index_path):
        index = CdxIndex.load(index_path)
        if index.shard_paths != shards:  # stale: indexes a different corpus
            print(f"index {index_path} covers different shards; rebuilding")
            index = None
        else:
            print(f"loaded index: {len(index)} records from {index_path}")
    if index is None:
        index = build_index(shards, workers=args.workers)
        nbytes = index.save(index_path)
        print(f"indexed {len(index)} records across {len(shards)} shards "
              f"-> {index_path} ({nbytes / 1024:.1f} KiB)")

    filters = HeaderFilter(status=args.status) \
        if args.status is not None else None
    # defaults demo both query kinds; either explicit flag suppresses
    # the other kind's default
    patterns = [p.encode() for p in (
        args.pattern if args.pattern is not None
        else ([] if args.regex else ["web archive", "nginx/1.17"]))]
    regexes = [r.encode() for r in (
        args.regex if args.regex is not None
        else ([] if args.pattern else [r"nginx/1\.1[0-9]"]))]
    with IndexQueryService(index) as service:
        responses = service.serve(
            [QueryRequest(pat, filters=filters, top_k=args.top_k)
             for pat in patterns]
            + [QueryRequest(rx, filters=filters, top_k=args.top_k,
                            regex=True) for rx in regexes])
        for resp in responses:
            pat = resp.request.pattern.decode("latin-1")
            kind = "regex " if resp.request.regex else ""
            print(f"\n=== {kind}{pat!r}: {resp.total_matches} matching "
                  f"records ({resp.latency_s * 1e3:.1f} ms)")
            for hit in resp.hits:
                print(f"  {hit.n_matches:4d}x  "
                      f"{hit.uri.decode('latin-1') or '<no uri>':48s} "
                      f"{os.path.basename(hit.shard)}@{hit.offset}")
                print(f"         ...{hit.excerpt.decode('latin-1')!r}...")
        stats = service.engine.stats
        print(f"\nengine: {stats['records_scanned']} records scanned for "
              f"{stats['sig_candidates']} candidates of "
              f"{stats['header_candidates']} selected "
              f"({stats['kernel_dispatches']} kernel dispatches, "
              f"{stats['batches']} batches)")
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
