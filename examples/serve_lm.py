"""Serve a small LM with batched requests through the KV-cache engine.

Trains the reduced byte-level LM briefly on WARC pipeline output (so it
emits corpus-like bytes), then serves a batch of prompts through
``repro.serve.engine`` — the same decode_step the dry-run lowers with a
32k cache on the production mesh.

Run:  PYTHONPATH=src python examples/serve_lm.py
"""
import os
import tempfile

import jax

from repro.configs import get_spec
from repro.data.synth import CorpusSpec, write_corpus
from repro.launch.train import train_lm
from repro.models import transformer as tf_mod
from repro.serve.engine import Request, ServeEngine
from repro.train import checkpoint as ckpt
from repro.train.step import init_train_state


def main():
    workdir = tempfile.mkdtemp(prefix="serve_lm_")
    shards = []
    for i in range(2):
        p = os.path.join(workdir, f"shard{i}.warc.gz")
        write_corpus(p, CorpusSpec(n_pages=100, seed=i), "gzip")
        shards.append(p)

    ckpt_dir = os.path.join(workdir, "ckpt")
    print("briefly pre-training the reduced LM on the WARC pipeline...")
    train_lm(arch="fastwarc_lm", shards=shards, steps=120, batch=8,
             seq_len=256, ckpt_dir=ckpt_dir, ckpt_every=120, reduced=True,
             log_every=40)

    cfg = get_spec("fastwarc_lm").reduced
    state = init_train_state(
        tf_mod.init_params(jax.random.PRNGKey(0), cfg))
    state, _ = ckpt.restore(ckpt_dir, state)

    engine = ServeEngine(cfg, state["params"], batch_size=4, max_seq=256,
                         temperature=0.8)
    requests = [Request(b"the web archive ", max_new_tokens=48),
                Request(b"search and analytics ", max_new_tokens=48),
                Request(b"content of the page ", max_new_tokens=48),
                Request(b"a record format ", max_new_tokens=48)]
    done = engine.serve(requests)
    for r in done:
        print(f"\nprompt : {r.prompt.decode()}"
              f"\noutput : {r.text.decode('utf-8', 'replace')!r}")
    s = engine.stats
    print(f"\n{s['tokens_generated']} tokens in {s['decode_s']:.1f}s "
          f"({s['tokens_generated']/s['decode_s']:.1f} tok/s, "
          f"batch={engine.batch_size})")


if __name__ == "__main__":
    main()
