"""End-to-end driver: WARC corpus → FastWARC pipeline → LM training.

The paper's deployment context, fully wired: synthesize a multi-shard
Common-Crawl-like corpus, stream it through the optimized parser +
HTML-to-text + byte tokenizer + sequence packer, and train the
``fastwarc_lm`` config for a few hundred steps with checkpointing and
exact data-pipeline resume. Asserts the loss actually falls.

Run:  PYTHONPATH=src python examples/train_lm_on_warc.py [--steps 300]
      (--full trains the 100M-param config; default is the reduced one
       so the example finishes in minutes on CPU)
"""
import argparse
import os
import tempfile

from repro.data.synth import CorpusSpec, write_corpus
from repro.launch.train import train_lm


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--full", action="store_true",
                    help="train the 100M-param config instead of reduced")
    ap.add_argument("--workdir", default=None)
    args = ap.parse_args()

    workdir = args.workdir or tempfile.mkdtemp(prefix="fastwarc_lm_")
    shards = []
    for i in range(4):
        path = os.path.join(workdir, f"crawl-{i:05d}.warc.gz")
        if not os.path.exists(path):
            write_corpus(path, CorpusSpec(n_pages=150, seed=100 + i), "gzip")
        shards.append(path)
    print(f"corpus: {len(shards)} shards in {workdir}")

    stats = train_lm(
        arch="fastwarc_lm",
        shards=shards,
        steps=args.steps,
        batch=args.batch,
        seq_len=args.seq_len,
        ckpt_dir=os.path.join(workdir, "ckpt"),
        ckpt_every=100,
        reduced=not args.full,
    )
    print(f"\ntrained {stats['steps']} steps at "
          f"{stats['tokens_per_s']:.0f} tok/s: "
          f"loss {stats['first_loss']:.3f} -> {stats['final_loss']:.3f}")
    assert stats["final_loss"] < stats["first_loss"] * 0.8, \
        "loss did not fall — training is broken"
    print("loss fell ✓ (byte-level LM is learning the corpus)")


if __name__ == "__main__":
    main()
