"""Concurrent archive querying through the async gateway (DESIGN.md §8).

Simulates overlapping multi-tenant traffic against an indexed corpus:
N client threads fire a Zipf-flavoured mix of pattern and regex queries
at `repro.serve.archive.ArchiveGateway`, which coalesces identical
in-flight scans, batches candidates from *different* queries into
shared multi-pattern kernel dispatches, and serves repeat payloads from
a byte-budgeted LRU — then prints the metrics that prove it.

Usage:

    # synthetic corpus, 8 clients x 12 requests
    PYTHONPATH=src python examples/archive_gateway.py

    # your shards, heavier traffic, bigger cache
    PYTHONPATH=src python examples/archive_gateway.py \\
        --shards crawl-*.warc.gz --clients 32 --per-client 16 \\
        --cache-mb 256
"""
import argparse
import os
import tempfile
import threading
import time

import numpy as np

from repro.data.synth import CorpusSpec, write_corpus
from repro.index import QueryRequest, build_index
from repro.serve import ArchiveGateway


def _synthetic_shards(directory: str, n_shards: int = 4) -> list[str]:
    paths = []
    for i in range(n_shards):
        p = os.path.join(directory, f"crawl-{i:02d}.warc.gz")
        write_corpus(p, CorpusSpec(n_pages=40, seed=31 + i), "gzip")
        paths.append(p)
    return paths


_POOL = [
    QueryRequest(b"nginx/1.17", top_k=3),
    QueryRequest(b"web archive", top_k=3),
    QueryRequest(b"crawl", top_k=3),
    QueryRequest(b"absent-needle!", top_k=3),
    QueryRequest(rb"nginx/1\.1[0-9]", top_k=3, regex=True),
]


def main() -> None:
    ap = argparse.ArgumentParser(
        description="Async gateway demo: coalescing + shared dispatch")
    ap.add_argument("--shards", nargs="*", default=None,
                    help="WARC files (default: generate a synthetic corpus)")
    ap.add_argument("--clients", type=int, default=8)
    ap.add_argument("--per-client", type=int, default=12)
    ap.add_argument("--cache-mb", type=int, default=64)
    args = ap.parse_args()

    tmp = None
    shards = args.shards
    if not shards:
        tmp = tempfile.TemporaryDirectory()
        shards = _synthetic_shards(tmp.name)
        print(f"generated {len(shards)} synthetic shards in {tmp.name}")
    index = build_index(shards, workers=2)
    print(f"indexed {len(index)} records across {len(shards)} shards")

    with ArchiveGateway(index, cache_bytes=args.cache_mb << 20,
                        max_pending=args.clients * args.per_client) as gw:
        def client(cid: int) -> None:
            # per-thread generator: numpy Generators are not thread-safe
            rng = np.random.default_rng(cid)
            ranks = np.minimum(rng.zipf(1.4, args.per_client) - 1,
                               len(_POOL) - 1)
            futures = [gw.submit(_POOL[r]) for r in ranks]
            for fut in futures:
                fut.result(600)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(c,))
                   for c in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        snap = gw.metrics.snapshot(gw.cache)

    total = args.clients * args.per_client
    print(f"\n{total} requests from {args.clients} clients in {wall:.2f}s "
          f"({total / wall:.1f} req/s)")
    print(f"  unique scans executed   : {snap['unique_scans']} "
          f"(coalesce rate {snap['coalesce_rate']:.0%})")
    print(f"  kernel dispatches       : {snap['kernel_dispatches']} "
          f"({snap['dispatches_per_request']:.2f} per request)")
    print(f"  records scanned/request : "
          f"{snap['records_scanned_per_request']:.1f}")
    print(f"  cache                   : {snap['cache_hit_rate']:.0%} hit "
          f"rate, {snap['cache_bytes_cached'] / 1024:.0f} KiB resident, "
          f"{snap['cache_evictions']} evictions")
    print(f"  latency                 : p50 {snap['latency_p50_ms']:.0f} ms, "
          f"p99 {snap['latency_p99_ms']:.0f} ms")
    if tmp is not None:
        tmp.cleanup()


if __name__ == "__main__":
    main()
