"""Quickstart: the paper's system in 60 lines.

1. Generate a synthetic Common-Crawl-like WARC file (gzip members).
2. Parse it with the FastWARC-style iterator vs the WARCIO baseline,
   printing records/s for both (the paper's Table 1 axis).
3. Recompress gzip -> LZ4 with the from-scratch codec and parse that too
   (the paper's concluding recommendation).
4. Print the merged observability snapshot the run accumulated — parent
   counters plus the readahead decoder child's, harvested over shared
   memory (DESIGN.md §11).

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import io
import time

from repro.core.warc import (
    FastWARCIterator,
    WARCIOArchiveIterator,
    WarcRecordType,
    WarcWriter,
)
from repro.core.warc.writer import reserialize
from repro.data.synth import CorpusSpec, generate_warc, records_in


def timed(label, fn):
    t0 = time.perf_counter()
    n = fn()
    dt = time.perf_counter() - t0
    print(f"  {label:34s} {n:6d} records  {n/dt:10.0f} rec/s")
    return n / dt


def main():
    spec = CorpusSpec(n_pages=300, seed=7)
    warc_gz = generate_warc(spec, "gzip")
    total = records_in(spec)
    print(f"synthetic corpus: {total} records, "
          f"{len(warc_gz)/1e6:.1f} MB gzip'd")

    print("\n-- gzip --")
    base = timed("WARCIO baseline (+http)",
                 lambda: sum(1 for _ in WARCIOArchiveIterator(
                     warc_gz, parse_http=True)))
    fast = timed("FastWARC (+http)",
                 lambda: sum(1 for _ in FastWARCIterator(
                     warc_gz, parse_http=True)))
    print(f"  speedup: {fast/base:.2f}x")

    print("\n-- response-only filtering (cheap skipping) --")
    it = FastWARCIterator(warc_gz, parse_http=True,
                          record_types=WarcRecordType.response)
    n_resp = sum(1 for _ in it)
    print(f"  yielded {n_resp} responses, skipped {it.records_skipped} "
          f"records without parsing them")

    print("\n-- zero-copy parse arena (borrowed views, DESIGN.md §9) --")
    warc_plain = generate_warc(spec, "none")
    for label, zero_copy in (("legacy bytes-slicing", False),
                             ("zero-copy arena", True)):
        it = FastWARCIterator(warc_plain, parse_http=False,
                              zero_copy=zero_copy)
        n = sum(1 for _ in it)
        print(f"  {label:22s} {it.copy_stats.bytes_copied / n:8.0f} "
              f"bytes copied/record ({it.copy_stats.copies} copies)")
    # content_view() is borrow-only: it aliases the parser's arena and must
    # not outlive the iteration step. detach() copies a record out so it
    # survives arena recycling (the one copy is tallied in copy_stats).
    it = FastWARCIterator(warc_plain, parse_http=False,
                          arena_bytes=32 * 1024)  # small: force recycling
    kept = None
    for rec in it:  # one pass: detach the first response, drop the rest
        if kept is None and rec.record_type == WarcRecordType.response:
            kept = rec.detach()
    assert it.copy_stats.arena_reuses > 0
    print(f"  detached record still readable after "
          f"{it.copy_stats.arena_reuses} arena recycles: "
          f"{len(kept.content)} bytes, {kept.target_uri}")

    print("\n-- recompress gzip -> lz4 (paper's conclusion) --")
    sink = io.BytesIO()
    w = WarcWriter(sink, "lz4")
    for record in FastWARCIterator(warc_gz, parse_http=False):
        w.write_serialized(reserialize(record))
    warc_lz4 = sink.getvalue()
    print(f"  sizes: gzip {len(warc_gz)/1e6:.1f} MB -> "
          f"lz4 {len(warc_lz4)/1e6:.1f} MB "
          f"({len(warc_lz4)/len(warc_gz):.2f}x, paper says +30-40%)")
    timed("FastWARC over lz4 (+http)",
          lambda: sum(1 for _ in FastWARCIterator(warc_lz4, parse_http=True)))
    print("  (our LZ4 codec is pure Python — see EXPERIMENTS.md for the "
          "C-speed zstd numbers that carry the fast-codec claim)")

    print("\n-- observability: everything above, in one snapshot "
          "(DESIGN.md §11) --")
    from repro import obs

    snap = obs.snapshot()
    print(f"  sources: {', '.join(snap.sources)}")
    print(f"  ingest: {snap.counter('ingest.records')} records over "
          f"{snap.counter('ingest.shards')} sweeps, "
          f"{snap.counter('ingest.bytes_copied')/1e6:.1f} MB copied; "
          f"decoder child decoded {snap.counter('decoder.members')} "
          f"members in {snap.counter('decoder.batches')} batches")
    print("  (render any snapshot as JSON or Prometheus text with "
          "`python -m repro.obs.dump`)")


if __name__ == "__main__":
    main()
