"""Ingest-hot-path benchmarks: the ISSUE 4 zero-copy / fused / shm claims.

Three claims measured, not asserted:

* **zero-copy parse** — records/s *and bytes-copied-per-record* of the
  pooled-arena parser (``FastWARCIterator`` default) vs the PR 1-era
  bytes-slicing loop (``zero_copy=False``), both instrumented through
  the shared :class:`~repro.core.warc.streams.CopyStats` ledger. The
  claim is not just "faster" but "the copies are *gone*": the arena
  path's per-record copy budget is a few hundred header bytes, the
  legacy path re-copies payloads multiple times.
* **fused index build** — ``build_index(fused=True)`` (one
  ``digest_signature_batch`` kernel sweep per payload batch) vs the
  two-pass host build (``zlib.adler32`` pass + n-gram signature pass
  per record). Columns are bit-identical; the fused build touches each
  payload byte once. Measured end-to-end (the production call), in
  interpret mode: the win comes from batching away per-record host
  overhead — the per-byte sweep itself is emulated on CPU here and
  only gets its vector-unit payoff on real TPU hardware.
* **pool transport** — the shared-memory ring mechanism vs the PR 1
  pickle queue mechanism, measured single-process and *paired* (each
  rep runs both back-to-back and the reported speedup is the median of
  per-pair ratios): a chunk of synthetic-corpus-sized documents is
  serialized once and then either pushed through a real ``os.pipe`` in
  64 KiB writes and reassembled (what ``mp.Queue`` does) or memcpy'd
  into a ring slot and decoded from a zero-copy view (what the shm
  transport does). Racing actual worker processes on a 2-core shared
  container is scheduler roulette — ratios swing 0.4×–5× run to run —
  so the deterministic mechanism cost is the instrument;
  tests/test_parallel.py pins multi-process correctness of both paths.
* **decode** (ISSUE 5) — per-codec member decompression: the legacy
  member-``bytes`` path (``zero_copy=False``) vs decode-into-arena
  members, with and without the readahead decoder thread. Reported per
  codec: records/s and bytes-copied/record, where the copy metric is
  ``bytes_copied + member_bytes_copied`` off the :class:`CopyStats`
  ledger — the claim is that gzip/LZ4 copy budgets collapse from
  ~full-member-size to the uncompressed path's header-copy budget, and
  that gzip rec/s gains ≥1.3× from overlapping inflate with parsing.
  Arena-decoded output is verified byte-identical to the legacy path
  in-bench before any rate is reported.
* **obs** (ISSUE 7) — the observability tax. The zero-copy uncompressed
  sweep is raced tracing-off vs tracing-on, interleaved with
  alternating order (the ``_decode_race`` best-of idiom: each mode's
  fastest quiet window is the instrument, because per-pair ratios on a
  shared container swing ±10%), and the bench *asserts* the
  best-of-ratio ≤ 1.02: span instrumentation on the hot loop must cost
  ≤2% even when enabled — the disabled default path is a strict subset
  (one ``trace.enabled()`` test per iterator), so the gate covers it a
  fortiori.
* **robustness** (ISSUE 6) — the tolerant-mode tax and the recovery
  payoff. ``tolerant=True`` on a *clean* gzip archive must ride the
  exact same hot path as strict mode (the resync machinery only runs
  after a failure), so its overhead ratio is measured paired with
  strict sweeps and expected ≤ 1.05. The same archive with ~1% of
  members deterministically corrupted is then swept tolerantly:
  reported records/s plus the ledger's accounting (entries, bytes
  quarantined) against the known damage.

Scale with REPRO_BENCH_PAGES (default 400).
"""
from __future__ import annotations

import os
import pickle
import statistics
import tempfile
import time

from repro.core.pipeline import Document
from repro.core.warc import FastWARCIterator
from repro.data.synth import CorpusSpec, generate_warc, write_corpus

_PAGES = int(os.environ.get("REPRO_BENCH_PAGES", "400"))
_N_SHARDS = 8
_DOC_BYTES = 2048        # synthetic-corpus-sized extracted documents
_CHUNK_DOCS = 128        # documents per transported chunk
_PIPE_CHUNK = 64 * 1024  # Linux pipe buffer: mp.Queue's write granularity

_BLOB = bytes(range(256)) * 64  # 16 KiB template for transport payloads


def _best_s(fn, reps: int = 3) -> float:
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        best = min(best, time.perf_counter() - t0)
    return best


# -- parse path ----------------------------------------------------------

def _parse_stats(data: bytes, zero_copy: bool) -> tuple[float, float, int]:
    """(records/s, bytes_copied_per_record, records) for one parse mode."""
    n = 0
    it = None

    def sweep():
        nonlocal n, it
        it = FastWARCIterator(data, parse_http=True, zero_copy=zero_copy)
        n = sum(1 for _ in it)

    best = _best_s(sweep)
    stats = it.copy_stats
    return n / best, stats.bytes_copied / max(n, 1), n


# -- member decode paths (ISSUE 5) ---------------------------------------

def _decode_sweep(data: bytes, reps: int = 3,
                  **kw) -> tuple[float, float, int]:
    """(records/s, copied_bytes/record, records) for one decode mode.

    Timing uses the bare-iteration metric the parse section established;
    byte-identity is checked separately (untimed) by :func:`_snapshot`.
    """
    n = 0
    it = None

    def sweep():
        nonlocal n, it
        it = FastWARCIterator(data, parse_http=True, **kw)
        n = sum(1 for _ in it)

    best = _best_s(sweep, reps=reps)
    stats = it.copy_stats
    copied = stats.bytes_copied + stats.member_bytes_copied
    return n / best, copied / max(n, 1), n


def _decode_race(data: bytes, modes: dict, reps: int = 9) -> dict:
    """Best-of rec/s per mode, sampled round-robin.

    Shared-container CPU availability swings ~1.7× minute to minute;
    interleaving the modes inside each rep gives every mode the same
    chance of a quiet window before the per-mode best is taken (the
    transport bench's paired-measurement rationale).
    """
    times = {name: float("inf") for name in modes}
    counts = {}
    for _ in range(reps):
        for name, kw in modes.items():
            it = FastWARCIterator(data, parse_http=True, **kw)
            t0 = time.perf_counter()
            counts[name] = sum(1 for _ in it)
            times[name] = min(times[name], time.perf_counter() - t0)
    return {name: counts[name] / t for name, t in times.items()}


def _snapshot(data: bytes, **kw) -> list[tuple]:
    # bytes() immediately: arena views are read before slot recycling
    return [(r.record_id, bytes(r.content_view()))
            for r in FastWARCIterator(data, parse_http=True, **kw)]


def _two_proc_scaling() -> float:
    """Aggregate CPU capacity available to two busy processes vs one —
    the hard ceiling on what pipelined (process) readahead can deliver
    on this host. Shared/throttled CI containers sit well below 2.0."""
    import multiprocessing as mp

    def burn(q):
        deadline = time.perf_counter() + 0.4
        x = n = 0
        while time.perf_counter() < deadline:
            for i in range(10000):
                x += i * i
            n += 1
        q.put(n)

    ctx = mp.get_context()
    q = ctx.Queue()
    p = ctx.Process(target=burn, args=(q,))
    p.start()
    p.join()
    single = q.get()
    procs = [ctx.Process(target=burn, args=(q,)) for _ in range(2)]
    for p in procs:
        p.start()
    for p in procs:
        p.join()
    return (q.get() + q.get()) / max(single, 1)


def _decode_rows() -> list[str]:
    rows = [f"ingest,decode,env,two_proc_scaling,"
            f"{_two_proc_scaling():.2f}"]
    spec = CorpusSpec(n_pages=_PAGES, seed=17)
    plain_rps, plain_bpr, _ = _decode_sweep(generate_warc(spec, "none"))
    rows.append(f"ingest,decode,none_arena,records_per_s,{plain_rps:.1f}")
    rows.append(f"ingest,decode,none_arena,bytes_copied_per_record,"
                f"{plain_bpr:.1f}")
    codecs = ["gzip", "lz4"]
    try:
        import zstandard  # noqa: F401
        codecs.append("zstd")
    except ImportError:
        pass
    for codec in codecs:
        # gzip gets a larger corpus: the process-readahead fork/ring
        # setup (~5-8 ms) must amortize the way it does on real
        # (100 MB+) shards, not dominate a 1 MB toy file. LZ4/zstd keep
        # the base scale (the pure-Python LZ4 *compressor* would
        # otherwise dominate bench runtime just generating the input).
        pages = max(5 * _PAGES, 3000) if codec == "gzip" else _PAGES
        data = generate_warc(CorpusSpec(n_pages=pages, seed=17), codec)
        # acceptance gate first: arena decode (± readahead) must be
        # byte-identical to the legacy member path, checked untimed
        legacy_snap = _snapshot(data, zero_copy=False)
        assert _snapshot(data, readahead=False) == legacy_snap, codec
        modes = {"legacy": dict(zero_copy=False),
                 "arena": dict(readahead=False)}
        member_codec = codec != "zstd"  # zstd: no members, no decode stage
        if member_codec:
            assert _snapshot(data, readahead=True) == legacy_snap, codec
            modes["readahead"] = dict(readahead=True)
        rates = _decode_race(data, modes)
        # copy ledgers from one untimed sweep per mode
        _, legacy_bpr, _ = _decode_sweep(data, reps=1, zero_copy=False)
        _, arena_bpr, _ = _decode_sweep(data, reps=1, readahead=False)
        rows.append(f"ingest,decode,{codec}_legacy,records_per_s,"
                    f"{rates['legacy']:.1f}")
        rows.append(f"ingest,decode,{codec}_legacy,bytes_copied_per_record,"
                    f"{legacy_bpr:.1f}")
        rows.append(f"ingest,decode,{codec}_arena,records_per_s,"
                    f"{rates['arena']:.1f}")
        rows.append(f"ingest,decode,{codec}_arena,bytes_copied_per_record,"
                    f"{arena_bpr:.1f}")
        if member_codec:
            rows.append(f"ingest,decode,{codec}_readahead,records_per_s,"
                        f"{rates['readahead']:.1f}")
            rows.append(f"ingest,decode,{codec}_readahead,"
                        f"bytes_copied_per_record,{arena_bpr:.1f}")
            rows.append(f"ingest,decode,{codec}_readahead,speedup_vs_legacy,"
                        f"{rates['readahead'] / rates['legacy']:.2f}")
        rows.append(f"ingest,decode,{codec},verified_identical,1")
        rows.append(f"ingest,decode,{codec}_arena,copy_vs_none_ratio,"
                    f"{arena_bpr / max(plain_bpr, 1e-9):.2f}")
    return rows


# -- observability tax: tracing-off vs tracing-on (ISSUE 7) --------------

def _obs_rows() -> list[str]:
    from repro import obs
    from repro.obs import trace

    data = generate_warc(CorpusSpec(n_pages=_PAGES, seed=29), "none")

    def sweep() -> int:
        return sum(1 for _ in FastWARCIterator(data, parse_http=True))

    prev = trace.enable(False)
    try:
        sweep()
        trace.enable(True)
        n = sweep()  # warm both paths (and the span reservoirs)
        best = {False: float("inf"), True: float("inf")}
        for rep in range(12):  # interleaved best-of: per-pair ratios on
            # this container swing +-10% run to run, far above the tax
            # being measured, so (the _decode_race rationale) each mode
            # takes its fastest quiet window; alternating order kills
            # any cache/GC bias favoring the second sweep of a pair
            order = (False, True) if rep % 2 == 0 else (True, False)
            for on in order:
                trace.enable(on)
                t0 = time.perf_counter()
                sweep()
                best[on] = min(best[on], time.perf_counter() - t0)
    finally:
        trace.enable(prev)
    ratio = best[True] / best[False]
    # the gate trace.py promises: spans on the zero-copy loop cost <=2%
    # even ENABLED; the disabled default is a strict subset of that work
    assert ratio <= 1.02, f"tracing overhead ratio {ratio:.3f} > 1.02"
    fill_spans = obs.snapshot().counter("span.ingest.fill.count")
    return [
        f"ingest,obs,tracing_off,records_per_s,{n / best[False]:.1f}",
        f"ingest,obs,tracing_on,records_per_s,{n / best[True]:.1f}",
        f"ingest,obs,tracing_on,overhead_ratio,{ratio:.3f}",
        f"ingest,obs,tracing_on,fill_spans_recorded,{fill_spans}",
    ]


# -- robustness: tolerant-mode tax + recovery under damage ---------------

def _robustness_rows() -> list[str]:
    from repro.testing.faults import corrupt_warc

    data = generate_warc(CorpusSpec(n_pages=_PAGES, seed=23), "gzip")
    # paired sweeps (the decode race): the clean-archive tolerant tax is
    # a few percent at most, far below this container's minute-to-minute
    # drift — only the interleaved ratio is meaningful
    rates = _decode_race(data, {"strict": {}, "tolerant": dict(tolerant=True)})
    rows = [
        f"ingest,robustness,strict_clean,records_per_s,"
        f"{rates['strict']:.1f}",
        f"ingest,robustness,tolerant_clean,records_per_s,"
        f"{rates['tolerant']:.1f}",
        f"ingest,robustness,tolerant_clean,overhead_ratio,"
        f"{rates['strict'] / rates['tolerant']:.3f}",
    ]
    bad, damage = corrupt_warc(data, fraction=0.01, seed=23)
    it = FastWARCIterator(bad, parse_http=True, tolerant=True)
    t0 = time.perf_counter()
    n = sum(1 for _ in it)
    elapsed = time.perf_counter() - t0
    entries = it.error_ledger.entries()
    rows += [
        f"ingest,robustness,tolerant_corrupted_1pct,records_per_s,"
        f"{n / elapsed:.1f}",
        f"ingest,robustness,tolerant_corrupted_1pct,records_recovered,{n}",
        f"ingest,robustness,tolerant_corrupted_1pct,damaged_members,"
        f"{len(damage)}",
        f"ingest,robustness,tolerant_corrupted_1pct,ledger_entries,"
        f"{len(entries)}",
        f"ingest,robustness,tolerant_corrupted_1pct,bytes_quarantined,"
        f"{sum(e.bytes_skipped for e in entries)}",
    ]
    return rows


# -- transport mechanism bench -------------------------------------------

def _bench_docs() -> list:
    return [Document("https://bench.example/doc",
                     _BLOB[(i * 37) % 4096 + 1:(i * 37) % 4096 + 1
                           + _DOC_BYTES], i)
            for i in range(_CHUNK_DOCS)]


def _pickle_pipe_rate(docs: list, reps: int) -> float:
    """docs/s of the queue mechanism: dumps → pipe syscalls → loads."""
    r, w = os.pipe()
    try:
        t0 = time.perf_counter()
        for _ in range(reps):
            blob = pickle.dumps(docs, protocol=pickle.HIGHEST_PROTOCOL)
            mv = memoryview(blob)
            parts = []
            sent = 0
            while sent < len(blob):
                n = os.write(w, mv[sent:sent + _PIPE_CHUNK])
                sent += n
                parts.append(os.read(r, _PIPE_CHUNK))
            pickle.loads(b"".join(parts))
        return reps * len(docs) / (time.perf_counter() - t0)
    finally:
        os.close(r)
        os.close(w)


def _shm_ring_rate(docs: list, reps: int) -> float:
    """docs/s of the ring mechanism: dumps → slot memcpy → loads(view)."""
    slot = bytearray(4 << 20)
    t0 = time.perf_counter()
    for _ in range(reps):
        blob = pickle.dumps(docs, protocol=pickle.HIGHEST_PROTOCOL)
        slot[:len(blob)] = blob
        pickle.loads(memoryview(slot)[:len(blob)])
    return reps * len(docs) / (time.perf_counter() - t0)


def _transport_rows() -> list[str]:
    docs = _bench_docs()
    _pickle_pipe_rate(docs, 20)
    _shm_ring_rate(docs, 20)  # warm both
    pipe_rates, ring_rates, ratios = [], [], []
    for _ in range(9):  # paired reps: machine drift cancels in the ratio
        p = _pickle_pipe_rate(docs, 40)
        s = _shm_ring_rate(docs, 40)
        pipe_rates.append(p)
        ring_rates.append(s)
        ratios.append(s / p)
    return [
        f"ingest,transport,pickle_pipe,docs_per_s,"
        f"{statistics.median(pipe_rates):.0f}",
        f"ingest,transport,shm_ring,docs_per_s,"
        f"{statistics.median(ring_rates):.0f}",
        f"ingest,transport,shm_ring,speedup,"
        f"{statistics.median(ratios):.2f}",
    ]


def run(quiet: bool = False) -> list[str]:
    rows = [f"ingest,env,host,cpu_count,{os.cpu_count()}"]

    from repro.data.synth import generate_warc

    spec = CorpusSpec(n_pages=_PAGES, seed=11)
    data = generate_warc(spec, "none")

    # 1) zero-copy parse vs legacy bytes-slicing loop
    for label, zero_copy in (("legacy", False), ("zero_copy", True)):
        rps, bpr, n = _parse_stats(data, zero_copy)
        rows.append(f"ingest,parse,{label},records_per_s,{rps:.1f}")
        rows.append(f"ingest,parse,{label},bytes_copied_per_record,{bpr:.1f}")
    legacy_bpr = float(rows[-3].rsplit(",", 1)[1])
    zc_bpr = float(rows[-1].rsplit(",", 1)[1])
    rows.append(f"ingest,parse,zero_copy,copy_reduction,"
                f"{legacy_bpr / max(zc_bpr, 1e-9):.1f}")

    # 2) member decode paths: legacy bytes vs decode-into-arena ± readahead
    rows.extend(_decode_rows())

    # 2b) tolerant-mode tax on clean archives + recovery under damage
    rows.extend(_robustness_rows())

    # 2c) observability tax: paired tracing-off/on race, gated <=1.02
    rows.extend(_obs_rows())

    with tempfile.TemporaryDirectory() as d:
        shard_paths = []
        for i in range(_N_SHARDS):
            p = os.path.join(d, f"s{i}.warc")
            write_corpus(p, CorpusSpec(n_pages=_PAGES // _N_SHARDS, seed=i),
                         "none")
            shard_paths.append(p)

        # 3) pool transport mechanism: pickle+pipe vs shm ring
        rows.extend(_transport_rows())

        # 4) fused vs two-pass index build (bit-identical columns)
        from repro.index import build_index

        index = build_index(shard_paths, fused=True)  # warm compile
        n_rec = len(index)
        t_fused = _best_s(lambda: build_index(shard_paths, fused=True),
                          reps=2)
        t_host = _best_s(lambda: build_index(shard_paths, fused=False),
                         reps=2)
        rows.append(f"ingest,index_build,two_pass,records_per_s,"
                    f"{n_rec / t_host:.1f}")
        rows.append(f"ingest,index_build,fused,records_per_s,"
                    f"{n_rec / t_fused:.1f}")
        rows.append(f"ingest,index_build,fused,speedup,"
                    f"{t_host / t_fused:.2f}")

        # pad-waste gate (ISSUE 10): half-step width/row quantization +
        # sub-block buckets must keep the fused sweep's padding under
        # 50% (the power-of-two ladder wasted 90%); asserted, not just
        # reported, so a bucketing regression fails the bench
        from repro import obs
        from repro.obs.kernels import pad_waste_report

        waste = pad_waste_report(obs.snapshot()).get(
            "digest_signature_batch", {}).get("pad_waste_ratio", 0.0)
        assert waste < 0.5, f"ingest kernel pad-waste {waste:.3f} >= 0.5"
        rows.append(f"ingest,index_build,fused,pad_waste_ratio,{waste:.3f}")

    if not quiet:  # pragma: no cover - CLI convenience
        for row in rows:
            print(row)
    return rows


if __name__ == "__main__":  # pragma: no cover
    run()
