"""End-to-end ingestion benchmark + the paper's compute-time projections.

The paper's headline derived numbers (§FastWARC vs WARCIO): hours saved on
a 64 000-WARC Common Crawl. Those are linear projections from per-file
throughput — reproduced here from our measured records/s:

    hours = n_files · (records_per_file / records_per_s) / 3600
"""
from __future__ import annotations

import os
import time

from repro.core.pipeline import iter_documents
from repro.core.warc import FastWARCIterator, WARCIOArchiveIterator
from repro.data.loader import WarcTokenLoader
from repro.data.synth import CorpusSpec, generate_warc, records_in

_PAGES = int(os.environ.get("REPRO_BENCH_PAGES", "400"))
#: Common Crawl 2021 stats used by the paper's projections
_CC_FILES = 64_000
_CC_RECORDS_PER_FILE = 153_000  # ~3 records/page, ~51k pages per WARC


def _best(fn, reps=3):
    best = float("inf")
    n = 0
    for _ in range(reps):
        t0 = time.perf_counter()
        n = fn()
        best = min(best, time.perf_counter() - t0)
    return n / best


def run(quiet: bool = False) -> list[str]:
    spec = CorpusSpec(n_pages=_PAGES, seed=123)
    rows = []

    # document extraction throughput (parse + http + html->text)
    data = generate_warc(spec, "gzip")
    docs_s = _best(lambda: sum(1 for _ in iter_documents(data)))
    rows.append(f"pipeline,extract_documents,gzip,docs_per_s,{docs_s:.1f}")

    # tokenized training-batch throughput
    import tempfile
    with tempfile.TemporaryDirectory() as d:
        paths = []
        for i in range(2):
            p = os.path.join(d, f"s{i}.warc.gz")
            with open(p, "wb") as f:
                f.write(generate_warc(CorpusSpec(n_pages=_PAGES // 2,
                                                 seed=i), "gzip"))
            paths.append(p)
        loader = WarcTokenLoader(paths, batch=8, seq_len=512, prefetch=4)
        t0 = time.perf_counter()
        n_tok = 0
        for i, b in enumerate(iter(loader)):
            n_tok += b.size
            if i >= 30:
                break
        loader.close()
        tok_s = n_tok / (time.perf_counter() - t0)
    rows.append(f"pipeline,warc_to_tokens,gzip,tokens_per_s,{tok_s:.0f}")

    # the paper's derived projection: hours per Common Crawl
    base_rs = _best(lambda: sum(1 for _ in WARCIOArchiveIterator(data)))
    fast_rs = _best(lambda: sum(1 for _ in FastWARCIterator(
        data, parse_http=False)))
    for name, rs in (("warcio", base_rs), ("fastwarc", fast_rs)):
        hours = _CC_FILES * (_CC_RECORDS_PER_FILE / rs) / 3600
        rows.append(f"pipeline,cc_projection_gzip,{name},hours,{hours:.0f}")
    saved = _CC_FILES * _CC_RECORDS_PER_FILE * (1 / base_rs - 1 / fast_rs) / 3600
    rows.append(f"pipeline,cc_projection_gzip,saved,hours,{saved:.0f}")

    if not quiet:
        for r in rows:
            print(r)
    return rows


if __name__ == "__main__":
    run()
